"""Quickstart: Loki sparse attention end to end on a small model, on CPU.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full pipeline:
  1. train a small Llama-family LM briefly on structured synthetic data
  2. calibrate PCA transforms over its attention keys (paper §3)
  3. report Rank@90 (the low-dimensionality observation, Fig 1/2)
  4. generate with full attention vs Loki (k_f = d_f = 0.25) and compare
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import pca as PCA
from repro.data.synthetic import DataConfig, SyntheticLM, jax_batch
from repro.models import lm
from repro.optim import adamw
from repro.training.step import TrainState, make_train_step


def main():
    cfg = ModelConfig(arch="quickstart", family="dense", n_layers=4,
                      d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
                      vocab=512, mlp="swiglu", dtype="float32")
    dcfg = DataConfig(vocab=512, seq_len=128, global_batch=8, seed=7,
                      n_states=32, temperature=0.22)
    data = SyntheticLM(dcfg)

    # 1. brief training so attention concentrates (what top-k exploits)
    print("== 1. training a ~3M-param model for 120 steps ==")
    tcfg = TrainConfig(lr=3e-3, warmup_steps=10, total_steps=120)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    state = TrainState(params, adamw.init_state(params))
    step = jax.jit(make_train_step(cfg, tcfg))
    t0 = time.time()
    for i in range(120):
        state, m = step(state, jax_batch(data.batch_at(i)))
        if i % 30 == 0:
            print(f"  step {i:4d} loss {float(m['loss']):.3f}")
    print(f"  done in {time.time()-t0:.0f}s, loss {float(m['loss']):.3f}")

    # 2. PCA calibration over captured keys (paper Section 3)
    print("== 2. PCA calibration of attention keys ==")
    batches = [jnp.asarray(data.batch_at(1000 + i)["tokens"])
               for i in range(3)]
    calib = PCA.calibrate_model(state.params, cfg, batches)

    # 3. the paper's observation: keys are low-rank
    r_pre = calib.rank_at(0.90, "pre").mean(axis=1)
    r_post = calib.rank_at(0.90, "post").mean(axis=1)
    print(f"  head_dim = {cfg.resolved_head_dim}")
    print(f"  Rank@90 per layer, pre-rotary : {np.round(r_pre, 1)}")
    print(f"  Rank@90 per layer, post-rotary: {np.round(r_post, 1)}")
    print("  -> keys live in a much lower-dimensional space (Fig 1/2)")

    # 4. generate with full attention vs Loki
    print("== 3. greedy generation: full attention vs Loki ==")
    loki_params = PCA.install_projections(state.params, calib, "pre")
    prompt = jnp.asarray(data.batch_at(5000)["tokens"][:2, :48])

    def generate(params, c, n_new=24):
        lg, cache, pos = lm.prefill(params, c, prompt, smax=96,
                                    cache_dtype=jnp.float32)
        dec = jax.jit(lambda cc, t, p: lm.decode_step(params, c, cc, t, p))
        toks = []
        tok = jnp.argmax(lg, -1)
        for _ in range(n_new):
            toks.append(np.asarray(tok))
            lg, cache = dec(cache, tok, pos)
            pos = pos + 1
            tok = jnp.argmax(lg, -1)
        return np.stack(toks, 1)

    full_out = generate(state.params, cfg)
    loki_cfg = cfg.with_loki(k_f=0.25, d_f=0.25)
    loki_out = generate(loki_params, loki_cfg)
    agree = (full_out == loki_out).mean()
    print(f"  full: {full_out[0][:12]}...")
    print(f"  loki: {loki_out[0][:12]}...")
    print(f"  greedy-token agreement over 24 new tokens: {agree:.2%}")
    print("  (Loki reads ~d_f/2 + k_f = 37.5% of the KV-cache bytes)")


if __name__ == "__main__":
    main()
