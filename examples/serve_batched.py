"""Batched serving with Loki sparse attention (deliverable b).

Runs the slot-based continuous-batching engine over a stream of requests,
once with full attention and once with Loki (k_f = d_f = 0.25), and compares
outputs + decode-tick throughput. The engine has no KV-append cost by design
(preallocated ring cache) — the bottleneck the paper measured as >80% of
HuggingFace decode time (§6.4).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import pca as PCA
from repro.data.synthetic import DataConfig, SyntheticLM, jax_batch
from repro.models import lm
from repro.optim import adamw
from repro.serving.engine import Request, ServingEngine
from repro.training.step import TrainState, make_train_step


def build_model():
    cfg = ModelConfig(arch="serve-demo", family="dense", n_layers=4,
                      d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
                      vocab=512, mlp="swiglu", dtype="float32")
    dcfg = DataConfig(vocab=512, seq_len=128, global_batch=8, seed=7,
                      n_states=32, temperature=0.22)
    data = SyntheticLM(dcfg)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=10, total_steps=100)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    state = TrainState(params, adamw.init_state(params))
    step = jax.jit(make_train_step(cfg, tcfg))
    for i in range(100):
        state, _ = step(state, jax_batch(data.batch_at(i)))
    batches = [jnp.asarray(data.batch_at(1000 + i)["tokens"])
               for i in range(3)]
    calib = PCA.calibrate_model(state.params, cfg, batches)
    return state.params, cfg, calib, data


def main():
    params, cfg, calib, data = build_model()
    loki_params = PCA.install_projections(params, calib, "pre")
    loki_cfg = cfg.with_loki(k_f=0.25, d_f=0.25)

    prompts = [data.batch_at(4000 + i)["tokens"][0, :32 + 8 * i]
               for i in range(6)]
    reqs_full = [Request(rid=i, prompt=p, max_new=16)
                 for i, p in enumerate(prompts)]
    reqs_loki = [Request(rid=i, prompt=p.copy(), max_new=16)
                 for i, p in enumerate(prompts)]

    eng = ServingEngine(params, cfg, n_slots=4, smax=128)
    for r in reqs_full:
        eng.submit(r)
    t0 = time.time()
    eng.run_until_done()
    t_full = time.time() - t0
    print(f"full attention: {len(prompts)} requests, {eng.ticks} ticks, "
          f"{t_full:.1f}s")

    eng2 = ServingEngine(loki_params, loki_cfg, n_slots=4, smax=128)
    for r in reqs_loki:
        eng2.submit(r)
    t0 = time.time()
    eng2.run_until_done()
    t_loki = time.time() - t0
    print(f"loki attention: {len(prompts)} requests, {eng2.ticks} ticks, "
          f"{t_loki:.1f}s")

    agree = np.mean([
        np.mean(np.asarray(a.out[:8]) == np.asarray(b.out[:8]))
        for a, b in zip(reqs_full, reqs_loki)])
    print(f"first-8-token agreement full vs loki: {agree:.2%}")
    print("OK")


if __name__ == "__main__":
    main()
