"""End-to-end fault-tolerant training driver (deliverable b).

Trains a ~100M-parameter (full flag) or ~3M (default, CPU-friendly) dense LM
for a few hundred steps through the production runner: async atomic
checkpointing, an injected node failure mid-run, automatic restart +
bit-exact resume, straggler accounting, and gradient compression on.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--full]
"""
import argparse
import os
import shutil
import tempfile

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.synthetic import DataConfig
from repro.training.runner import (FailureInjector, TrainRunner,
                                   run_with_restarts)


def model_cfg(full: bool) -> ModelConfig:
    if full:    # ~100M params
        return ModelConfig(arch="e2e-100m", family="dense", n_layers=12,
                           d_model=768, n_heads=12, n_kv_heads=4,
                           d_ff=2048, vocab=32768, mlp="swiglu",
                           dtype="float32")
    return ModelConfig(arch="e2e-small", family="dense", n_layers=4,
                       d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
                       vocab=512, mlp="swiglu", dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (slow on CPU)")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step "
                         "(default: mid-run)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_cfg(args.full)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8, seed=7,
                      n_states=32, temperature=0.22)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                       grad_clip=1.0, nan_skip=True,
                       grad_compression="topk", compression_ratio=0.05)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_e2e_")
    fail_at = args.fail_at if args.fail_at is not None else args.steps // 2
    print(f"model={cfg.arch} steps={args.steps} ckpt={ckpt_dir} "
          f"injected-failure@{fail_at}")

    def make_runner():
        return TrainRunner(cfg, tcfg, dcfg, ckpt_dir, ckpt_every=20, keep=2)

    injector = FailureInjector(fail_at=fail_at)
    result = run_with_restarts(make_runner, args.steps, injector=injector)

    losses = [m["loss"] for m in result["metrics"]]
    print(f"survived injected failure; resumed from checkpoint and finished "
          f"{result['final_step']} steps")
    print(f"loss: first={losses[0]:.3f} last={losses[-1]:.3f} "
          f"stragglers={result['stragglers']}")
    assert losses[-1] < losses[0], "training did not make progress"
    if args.ckpt_dir is None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("OK")


if __name__ == "__main__":
    main()
