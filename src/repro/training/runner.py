"""Fault-tolerant training runner.

Wraps the jitted train step with:
  * periodic async checkpointing (atomic, keep-N)
  * automatic restore-and-resume after a crash (the data pipeline is a pure
    function of the step, so replay is exact)
  * failure injection for tests (``fail_at`` raises inside the loop, the
    driver restarts the runner and training continues bit-exact)
  * straggler/goodput hooks: per-step wall time is recorded; steps slower
    than ``straggler_factor`` × median are counted and surfaced in metrics
    (on real fleets this feeds the requeue policy; here it feeds tests)
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.synthetic import DataConfig, SyntheticLM, jax_batch
from repro.models import lm
from repro.optim import adamw
from repro.training.step import TrainState, make_train_step


class FailureInjector:
    """Raises RuntimeError the first time step == fail_at."""

    def __init__(self, fail_at: Optional[int] = None):
        self.fail_at = fail_at
        self.fired = False

    def __call__(self, step: int):
        if self.fail_at is not None and step == self.fail_at and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected node failure at step {step}")


class TrainRunner:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 data_cfg: DataConfig, ckpt_dir: str, *,
                 ckpt_every: int = 10, keep: int = 2,
                 straggler_factor: float = 3.0):
        self.cfg, self.tcfg, self.data_cfg = cfg, tcfg, data_cfg
        self.ckpt = CheckpointManager(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
        self.data = SyntheticLM(data_cfg)
        self.straggler_factor = straggler_factor
        self.step_times: List[float] = []
        self.stragglers = 0

    def init_state(self) -> TrainState:
        params = lm.init(jax.random.PRNGKey(self.tcfg.seed), self.cfg)
        return TrainState(params, adamw.init_state(params))

    def run(self, n_steps: int, *, injector: Optional[FailureInjector] = None,
            resume: bool = True) -> Dict[str, Any]:
        state = self.init_state()
        start = 0
        if resume:
            restored_step, state = self.ckpt.restore_latest(state)
            if restored_step is not None:
                start = restored_step
        metrics_log = []
        for step in range(start, n_steps):
            if injector is not None:
                injector(step)
            batch = jax_batch(self.data.batch_at(step))
            t0 = time.time()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times))
            if len(self.step_times) > 5 and dt > self.straggler_factor * med:
                self.stragglers += 1
            metrics_log.append({k: float(v) for k, v in metrics.items()})
            if (step + 1) % self.ckpt_every == 0 or step + 1 == n_steps:
                self.ckpt.save(step + 1, state)
        self.ckpt.wait()
        return {"state": state, "metrics": metrics_log,
                "final_step": n_steps, "stragglers": self.stragglers}


def run_with_restarts(make_runner: Callable[[], TrainRunner], n_steps: int,
                      injector: Optional[FailureInjector] = None,
                      max_restarts: int = 3) -> Dict[str, Any]:
    """Driver loop a cluster scheduler would run: restart on failure, resume
    from the latest intact checkpoint."""
    attempts = 0
    while True:
        runner = make_runner()
        try:
            return runner.run(n_steps, injector=injector)
        except RuntimeError:
            attempts += 1
            if attempts > max_restarts:
                raise
