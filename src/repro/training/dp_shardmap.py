"""Explicit cross-pod data-parallel training via shard_map.

The pjit path (training/step.py) lets GSPMD schedule gradient reductions.
This variant makes the *cross-pod* reduction explicit with shard_map over the
``pod`` mesh axis so the wire format can be controlled per-link:

  * top-k sparsification with per-pod **error feedback** (Stich et al.) —
    the residual of what wasn't sent accumulates in fp32 and is added to the
    next step's gradient, so compression error is O(1) over training instead
    of O(T);
  * the psum/pmean operand is the sparse update (value+index wire format on
    real hardware; the HLO collective operand shows the byte reduction);
  * params/optimizer state stay replicated across pods (pure DP — within-pod
    FSDP/TP composes underneath on the remaining mesh axes).

State layout: error-feedback buffers carry a leading ``(n_pods, ...)`` axis
and are shard_map'd over it, so each pod keeps its own residual.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import lm  # noqa: F401  (re-exported convenience)
from repro.optim import adamw
from repro.optim.compression import topk_compress, topk_decompress
from repro.training.step import loss_fn


class DPState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    err: Any         # per-leaf fp32 residuals, leading (n_pods,) axis


def init_dp_state(params, n_pods: int) -> DPState:
    err = jax.tree.map(
        lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)
    return DPState(params, adamw.init_state(params), err)


def _compress_sync(g, err, ratio: float, axis: str):
    """Error-feedback top-k compress, pmean over `axis`, densify.

    g: local gradient; err: this pod's residual (same shape as g).
    Returns (synced_grad, new_err). Small leaves sync densely."""
    if g.size < 1024:
        return jax.lax.pmean(g, axis), err
    corrected = g.astype(jnp.float32) + err
    vals, idx, size = topk_compress(corrected, ratio)
    sent = topk_decompress(vals, idx, size).reshape(g.shape)
    new_err = corrected - sent
    synced = jax.lax.pmean(sent, axis)
    return synced.astype(g.dtype), new_err


def make_dp_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                       axis: str = "pod"):
    """shard_map train step: batch + error state sharded over `axis`,
    params/opt replicated; gradients compressed-synced across `axis`."""

    def per_pod(params, opt, err, batch):
        # err arrives as (1, ...) slices of the stacked residuals
        err = jax.tree.map(lambda e: e[0], err)
        grads, (loss, aux) = jax.grad(loss_fn, has_aux=True)(
            params, batch, cfg, tcfg)
        if tcfg.grad_compression == "topk":
            flat_g, tdef = jax.tree_util.tree_flatten(grads)
            flat_e = jax.tree_util.tree_flatten(err)[0]
            out_g, out_e = [], []
            for g, e in zip(flat_g, flat_e):
                sg, se = _compress_sync(g, e, tcfg.compression_ratio, axis)
                out_g.append(sg)
                out_e.append(se)
            grads = jax.tree_util.tree_unflatten(tdef, out_g)
            new_err = jax.tree_util.tree_unflatten(tdef, out_e)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
            new_err = err
        loss = jax.lax.pmean(loss, axis)

        new_params, new_opt, gnorm = adamw.apply_updates(
            params, grads, opt, tcfg)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": new_opt.step}
        new_err = jax.tree.map(lambda e: e[None], new_err)
        return new_params, new_opt, new_err, metrics

    from jax.experimental.shard_map import shard_map
    smapped = shard_map(
        per_pod, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis)),
        out_specs=(P(), P(), P(axis), P()),
        check_rep=False)

    def step(state: DPState, batch):
        p, o, e, m = smapped(state.params, state.opt, state.err, batch)
        return DPState(p, o, e), m

    return jax.jit(step, donate_argnums=(0,))
