"""Jit-able train / eval / serve step functions.

``make_train_step`` builds the canonical SPMD step: loss -> grad -> AdamW,
with optional gradient accumulation (microbatching), remat policy, NaN-skip,
and cross-pod gradient compression (see optim.compression).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import layers as L
from repro.models import lm
from repro.optim import adamw
from repro.optim.compression import compressed_psum


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def loss_fn(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    logits, aux = lm.forward(
        params, batch["tokens"], cfg,
        frames=batch.get("frames"), patches=batch.get("patches"),
        remat=tcfg.remat)
    loss = L.softmax_xent(logits, batch["labels"], z_loss=tcfg.z_loss,
                          mask=batch.get("mask"))
    return loss + 1e-2 * aux, (loss, aux)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    def grads_of(params, batch):
        g, (loss, aux) = jax.grad(loss_fn, has_aux=True)(
            params, batch, cfg, tcfg)
        return g, loss, aux

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state.params
        if tcfg.microbatch and tcfg.microbatch < batch["tokens"].shape[0]:
            mb = tcfg.microbatch
            n = batch["tokens"].shape[0] // mb
            shaped = jax.tree.map(
                lambda x: x.reshape(n, mb, *x.shape[1:]), batch)

            def acc_body(carry, micro):
                g_acc, l_acc, a_acc = carry
                g, loss, aux = grads_of(params, micro)
                return (jax.tree.map(jnp.add, g_acc, g),
                        l_acc + loss, a_acc + aux), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (zeros, jnp.float32(0), jnp.float32(0)), shaped)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss, aux = loss / n, aux / n
        else:
            grads, loss, aux = grads_of(params, batch)

        if tcfg.grad_compression != "none":
            grads = compressed_psum(grads, tcfg)

        new_params, new_opt, gnorm = adamw.apply_updates(
            params, grads, state.opt, tcfg)

        if tcfg.nan_skip:
            ok = jnp.isfinite(gnorm) & jnp.isfinite(loss)
            new_params = jax.tree.map(
                lambda new, old: jnp.where(ok, new, old), new_params, params)
            new_opt = jax.tree.map(
                lambda new, old: jnp.where(ok, new, old), new_opt, state.opt)

        metrics = {"loss": loss, "aux": aux, "grad_norm": gnorm,
                   "step": new_opt.step}
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_eval_step(cfg: ModelConfig, tcfg: Optional[TrainConfig] = None):
    tcfg = tcfg or TrainConfig(z_loss=0.0)

    def eval_step(params, batch):
        logits, _ = lm.forward(params, batch["tokens"], cfg,
                               frames=batch.get("frames"),
                               patches=batch.get("patches"))
        loss = L.softmax_xent(logits, batch["labels"],
                              mask=batch.get("mask"))
        acc = jnp.mean(
            (jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
        return {"loss": loss, "ppl_proxy": jnp.exp(loss), "acc": acc}

    return eval_step


def make_serve_steps(cfg: ModelConfig, smax: int):
    """(prefill_fn, decode_fn) for the serving engine / dry-run."""
    def prefill_fn(params, tokens, frames=None, patches=None):
        return lm.prefill(params, cfg, tokens, smax,
                          frames=frames, patches=patches)

    def decode_fn(params, cache, token, pos_len):
        return lm.decode_step(params, cfg, cache, token, pos_len)

    return prefill_fn, decode_fn
