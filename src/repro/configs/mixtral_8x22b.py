"""Mixtral-8x22B: 8-expert top-2 MoE, GQA, SWA. [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig, MoEConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768,
        mlp="swiglu", sliding_window=4096, rope_theta=1e6,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="mixtral-8x22b-smoke", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        mlp="swiglu", sliding_window=64, dtype="float32",
        # capacity_factor = n_experts makes smoke routing drop-free, so the
        # capacity-batched train/prefill path and the per-token gather decode
        # path agree exactly (prefill/decode parity tests rely on this; the
        # full config keeps the published 1.25)
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=256,
                      capacity_factor=4.0))
