"""Granite-MoE-3B-A800M: 40-expert top-8 fine-grained MoE.
[hf:ibm-granite/granite-3.0 family; hf]"""
from repro.configs.base import ModelConfig, MoEConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
        n_heads=24, n_kv_heads=8, d_ff=512, vocab=49155,
        mlp="swiglu",
        moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="granite-moe-3b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab=512,
        mlp="swiglu", dtype="float32",
        moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=64))
