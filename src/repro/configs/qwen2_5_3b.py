"""Qwen2.5-3B: dense GQA (kv=2), QKV bias. [hf:Qwen/Qwen2.5-0.5B family; hf]"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
        n_heads=16, n_kv_heads=2, d_ff=11008, vocab=151936,
        mlp="swiglu", qkv_bias=True, rope_theta=1e6)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2.5-3b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=1, d_ff=256, vocab=512,
        mlp="swiglu", qkv_bias=True, dtype="float32")
