"""Gemma-7B: GeGLU, head_dim=256 (q_dim 4096 != d_model 3072). [arXiv:2403.08295]"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="gemma-7b", family="dense", n_layers=28, d_model=3072,
        n_heads=16, n_kv_heads=16, head_dim=256, d_ff=24576, vocab=256000,
        mlp="geglu")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="gemma-7b-smoke", family="dense", n_layers=2, d_model=96,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
        mlp="geglu", dtype="float32")
