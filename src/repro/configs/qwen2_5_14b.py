"""Qwen2.5-14B: dense GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B family; hf]"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=13824, vocab=152064,
        mlp="swiglu", qkv_bias=True, rope_theta=1e6)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2.5-14b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        mlp="swiglu", qkv_bias=True, dtype="float32")
