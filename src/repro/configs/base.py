"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. The config is a
plain frozen dataclass (hashable -> usable as a jit static arg) and fully
determines parameter shapes, block composition and sharding-relevant dims.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0          # per-expert hidden dim
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba (S6) / xLSTM state settings."""
    state_dim: int = 16           # N: per-channel state size (mamba) / head qk dim (mlstm)
    expand: int = 2               # d_inner = expand * d_model (mamba)
    conv_width: int = 4
    n_heads: int = 4              # mlstm/slstm heads


#: storage bytes per element for each PageLayout dtype
LAYOUT_ITEMSIZE = {"fp32": 4, "fp16": 2, "bf16": 2, "int8": 1, "fp8": 1}
_LAYOUT_QMAX = {"int8": 127.0, "fp8": 448.0}   # fp8 = e4m3 max normal


@dataclasses.dataclass(frozen=True)
class PageLayout:
    """Declarative physical layout of paged KV-cache components.

    Single source of truth for page allocation, the store path (prefill
    chunk / decode append) and every read path (XLA views and the Pallas
    decode kernels). One layout per CacheSpec component; ``StateSlot``
    stays full-precision native and takes no layout.

    dtype  — page storage dtype: fp32 | fp16 | bf16 | int8 | fp8 (e4m3).
             Quantized dtypes store one f32 amax scale per page next to
             the page table (Double Sparsity, arXiv 2408.07092).
    basis  — "native" stores keys as produced; "pca" stores keys already
             projected into the calibrated PCA basis (SALS, arXiv
             2510.24273). Exact at full rank by Lemma 4.1 (orthogonal P
             preserves q·k); queries are rotated at read time and the
             back-projection folds into the attention epilogue (softmax
             weights are basis-free, V stays native).
    rank   — latent K width under basis="pca": keep only the leading r
             PCA dims (0 = full head_dim). V is never truncated.
    scale_granularity — only "page" is implemented: one scale per
             physical page per pool (K and V scales are separate).
    """
    dtype: str = "fp32"
    basis: str = "native"
    rank: int = 0
    scale_granularity: str = "page"

    def __post_init__(self):
        if self.dtype not in LAYOUT_ITEMSIZE:
            raise ValueError(f"PageLayout dtype {self.dtype!r}; "
                             f"have {sorted(LAYOUT_ITEMSIZE)}")
        if self.basis not in ("native", "pca"):
            raise ValueError(f"PageLayout basis {self.basis!r}")
        if self.rank and self.basis != "pca":
            raise ValueError("PageLayout rank requires basis='pca'")
        if self.rank < 0:
            raise ValueError("PageLayout rank must be >= 0")
        if self.scale_granularity != "page":
            raise ValueError("only per-page scales are implemented")

    # ------------------------------------------------------------ queries

    @property
    def quantized(self) -> bool:
        return self.dtype in _LAYOUT_QMAX

    @property
    def qmax(self) -> float:
        """Largest representable magnitude of the quantized dtype."""
        return _LAYOUT_QMAX[self.dtype]

    @property
    def itemsize(self) -> int:
        return LAYOUT_ITEMSIZE[self.dtype]

    def k_width(self, head_dim: int) -> int:
        """Stored K feature width: latent rank under pca, else head_dim."""
        if self.basis == "pca" and self.rank:
            return min(self.rank, head_dim)
        return head_dim

    def bytes_per_page_row(self, head_dim: int, n_kv_heads: int) -> int:
        """K+V bytes of one token row (scales amortize over the page)."""
        per = self.itemsize * n_kv_heads
        return per * (self.k_width(head_dim) + head_dim)

    # ------------------------------------------------------------- parse

    @classmethod
    def parse(cls, s: str) -> "PageLayout":
        """Parse ``"fp16"`` / ``"fp16:pca"`` / ``"int8:pca:r=32"`` specs."""
        parts = [p for p in s.strip().split(":") if p]
        if not parts:
            return cls()
        dtype, basis, rank = parts[0], "native", 0
        for tok in parts[1:]:
            if tok in ("native", "pca"):
                basis = tok
            elif tok.startswith("r="):
                rank = int(tok[2:])
            else:
                raise ValueError(f"bad layout token {tok!r} in {s!r}")
        return cls(dtype=dtype, basis=basis, rank=rank)

    def describe(self) -> str:
        r = f":r={self.rank}" if self.rank else ""
        return f"{self.dtype}:{self.basis}{r}"


@dataclasses.dataclass(frozen=True)
class LokiConfig:
    """Paper technique knobs (Section 4)."""
    enabled: bool = False
    d_f: float = 0.25             # fraction of head_dim used for approximate scores
    k_f: float = 0.25             # fraction of tokens kept for exact attention
    transform: str = "pre"        # calibration covariance source: "pre"|"post" rotary
    block_size: int = 128         # block granularity of the TPU (Pallas) select path
    token_granular: bool = True   # XLA path: paper-faithful token-level top-k
    min_k: int = 16               # never select fewer than this many tokens
    local_window: int = 16        # always-keep recency window (attention-sink safety)
    # distributed selection: split the cache into n_chunks sequence chunks and
    # take top-(k/n_chunks) per chunk. Aligned with the kv_seq sharding this
    # keeps every gather shard-local (no cross-device cache movement) — the
    # TPU-native adaptation of the paper's token top-k (DESIGN.md §3).
    # 0 = global top-k (paper-faithful; GSPMD-hostile at scale).
    n_chunks: int = 0
    # decode-kernel backend for the block-granular path (DESIGN.md §5):
    #   "auto"   — Pallas on TPU, jnp/XLA elsewhere
    #   "pallas" — force the fused kernels (interpret-mode off-TPU)
    #   "xla"    — force the pure-jnp reference path
    backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str = "model"
    family: str = "dense"         # dense|moe|hybrid|ssm|encdec|vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 256
    vocab: int = 256
    mlp: str = "swiglu"           # swiglu|geglu|sq_relu|gelu
    norm: str = "rms"             # rms|ln
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rope: bool = True
    sliding_window: int = 0       # 0 = disabled (mixtral SWA)
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    loki: LokiConfig = dataclasses.field(default_factory=LokiConfig)
    # physical layout of paged KV pages (serving); default is today's
    # fp32/native layout so training and the dense engine are untouched
    page_layout: PageLayout = dataclasses.field(default_factory=PageLayout)
    # per-layer latent-K ranks (Loki §4.2: the key spectrum varies by
    # layer). None = page_layout.rank everywhere; a tuple of n_layers ints
    # overrides the stored K width layer by layer (pca basis only). Pools
    # are allocated at the max width; narrower layers zero-mask the tail
    # dims at write time, which is self-consistent truncation (zeroed dims
    # contribute nothing to q̂·k̂).
    page_ranks: Optional[Tuple[int, ...]] = None
    # per-layer sliding windows for architectures that mix SWA and
    # full-attention layers (mixtral-SWA interleave, hymba's global/local
    # split). Entry i is layer i's window; 0 = full attention. None =
    # ``sliding_window`` uniformly. Layers with equal windows form one
    # page-table group (cache_spec.table_groups): window groups recycle
    # pages per layer while the full-attention group shares one table.
    window_layers: Optional[Tuple[int, ...]] = None
    # decode attention policy: full|loki|loki_block|exact_topk|pcaattn|h2o
    policy: str = "full"
    # hybrid: which layers are attention (hymba runs attn ∥ mamba inside a block)
    hybrid_parallel: bool = False
    # ssm (xlstm): 1-in-`slstm_every` blocks is an sLSTM block, rest mLSTM
    slstm_every: int = 0
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500           # whisper: fixed 30s -> 1500 frames
    # vlm
    vision_tokens: int = 0        # patch embeddings prepended by the stub frontend
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    def attn_policy(self) -> str:
        return self.policy

    def with_policy(self, policy: str, **loki_kw) -> "ModelConfig":
        lk = dataclasses.replace(
            self.loki, enabled=policy in ("loki", "loki_block"), **loki_kw)
        return dataclasses.replace(self, policy=policy, loki=lk)

    def with_loki(self, **kw) -> "ModelConfig":
        lk = dataclasses.replace(self.loki, enabled=True, **kw)
        return dataclasses.replace(self, policy="loki", loki=lk)

    def with_layout(self, layout) -> "ModelConfig":
        if isinstance(layout, str):
            layout = PageLayout.parse(layout)
        return dataclasses.replace(self, page_layout=layout)

    def with_ranks(self, ranks) -> "ModelConfig":
        """Per-layer latent-K ranks (forces a pca-basis layout)."""
        ranks = tuple(int(r) for r in ranks)
        if len(ranks) != self.n_layers:
            raise ValueError(f"page_ranks needs {self.n_layers} entries, "
                             f"got {len(ranks)}")
        if any(r <= 0 for r in ranks):
            raise ValueError("page_ranks entries must be positive")
        lay = self.page_layout
        if lay.basis != "pca":
            lay = dataclasses.replace(lay, basis="pca",
                                      rank=max(ranks))
        return dataclasses.replace(self, page_layout=lay,
                                   page_ranks=ranks)

    def layer_window(self, i: int) -> int:
        """Effective sliding window of layer ``i`` (0 = full attention)."""
        if self.window_layers is not None:
            return self.window_layers[i]
        return self.sliding_window

    def with_window_layers(self, windows) -> "ModelConfig":
        """Per-layer sliding windows (0 entries = full-attention layers)."""
        windows = tuple(int(w) for w in windows)
        if len(windows) != self.n_layers:
            raise ValueError(f"window_layers needs {self.n_layers} entries, "
                             f"got {len(windows)}")
        if any(w < 0 for w in windows):
            raise ValueError("window_layers entries must be >= 0")
        return dataclasses.replace(self, window_layers=windows)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatch: int = 0           # 0 = no accumulation
    remat: str = "none"           # none|full|dots
    z_loss: float = 1e-4
    seed: int = 0
    # distributed-optimization knobs
    grad_compression: str = "none"   # none|topk|int8 (cross-pod reduction)
    compression_ratio: float = 0.01  # topk: fraction of grads communicated
    nan_skip: bool = True            # skip steps with non-finite grads
