"""Nemotron-4-15B: GQA, squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=24576, vocab=256000,
        mlp="sq_relu", norm="ln")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="nemotron-4-15b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        mlp="sq_relu", norm="ln", dtype="float32")
