"""Hymba-1.5B: hybrid — attention heads in parallel with mamba (SSM) heads
inside each block; GQA kv=5. Meta-tokens omitted (DESIGN.md). [arXiv:2411.13676]"""
from repro.configs.base import ModelConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
        n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001,
        mlp="swiglu", hybrid_parallel=True,
        ssm=SSMConfig(state_dim=16, expand=2, conv_width=4))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="hymba-1.5b-smoke", family="hybrid", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
        mlp="swiglu", hybrid_parallel=True, dtype="float32",
        ssm=SSMConfig(state_dim=8, expand=2, conv_width=4))
