"""LLaVA-NeXT (Mistral-7B backbone): anyres tiling stubbed — input_specs()
provides precomputed patch embeddings. [hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
        mlp="swiglu", vision_tokens=576)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="llava-next-mistral-7b-smoke", family="vlm", n_layers=2,
        d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        mlp="swiglu", vision_tokens=16, dtype="float32")
