"""Llama-2-13B — the paper's kernel-benchmark model (Fig. 6/7). [arXiv:2307.09288]"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="llama2-13b", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=40, d_ff=13824, vocab=32000, mlp="swiglu")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="llama2-13b-smoke", family="dense", n_layers=2, d_model=160,
        n_heads=5, n_kv_heads=5, d_ff=320, vocab=512, mlp="swiglu",
        dtype="float32")
