"""Llama-2-7B — the paper's primary evaluation model. [arXiv:2307.09288]"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="llama2-7b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=11008, vocab=32000, mlp="swiglu")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="llama2-7b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, mlp="swiglu",
        dtype="float32")
