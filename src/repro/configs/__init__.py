"""Config registry: ``get_config(arch)`` / ``get_smoke_config(arch)``.

One module per assigned architecture (plus the paper's own Llama-2 models);
each exposes ``full_config()`` (exact published dims) and ``smoke_config()``
(same family, tiny dims, runnable on CPU).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (LokiConfig, ModelConfig, MoEConfig,
                                ShapeConfig, SHAPES, SSMConfig, TrainConfig,
                                shape_by_name)

ARCH_MODULES: Dict[str, str] = {
    "whisper-small": "repro.configs.whisper_small",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "gemma-7b": "repro.configs.gemma_7b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    # the paper's own evaluation models
    "llama2-7b": "repro.configs.llama2_7b",
    "llama2-13b": "repro.configs.llama2_13b",
}

ARCHS: List[str] = list(ARCH_MODULES)
ASSIGNED_ARCHS: List[str] = ARCHS[:10]


def _mod(arch: str):
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCHS}")
    return importlib.import_module(ARCH_MODULES[arch])


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).full_config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).smoke_config()


__all__ = [
    "ARCHS", "ASSIGNED_ARCHS", "LokiConfig", "ModelConfig", "MoEConfig",
    "SHAPES", "SSMConfig", "ShapeConfig", "TrainConfig", "get_config",
    "get_smoke_config", "shape_by_name",
]
