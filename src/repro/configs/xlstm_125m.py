"""xLSTM-125M: mLSTM + sLSTM blocks (no attention, no KV cache — Loki is
inapplicable by construction, see DESIGN.md §Arch-applicability).
[arXiv:2405.04517]"""
from repro.configs.base import ModelConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="xlstm-125m", family="ssm", n_layers=12, d_model=768,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
        rope=False, slstm_every=6,          # ~7:1 mLSTM:sLSTM mix
        ssm=SSMConfig(state_dim=16, n_heads=4))


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="xlstm-125m-smoke", family="ssm", n_layers=4, d_model=64,
        n_heads=2, n_kv_heads=2, d_ff=0, vocab=512,
        rope=False, slstm_every=2, dtype="float32",
        ssm=SSMConfig(state_dim=8, n_heads=2))
