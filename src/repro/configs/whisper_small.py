"""Whisper-small: encoder-decoder; conv audio frontend stubbed (input_specs()
provides precomputed frame embeddings, enc_seq=1500). Sinusoidal positions,
LayerNorm, GELU. [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="whisper-small", family="encdec", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865,
        mlp="gelu", norm="ln", rope=False,
        is_encoder_decoder=True, enc_layers=12, enc_seq=1500)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="whisper-small-smoke", family="encdec", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        mlp="gelu", norm="ln", rope=False, dtype="float32",
        is_encoder_decoder=True, enc_layers=2, enc_seq=30)
