"""Gradient compression for slow cross-pod links.

Two schemes, both applied *inside* the jitted step:

* ``topk``  — per-leaf magnitude top-k sparsification with **error feedback**
  carried in fp32 (Stich et al.); only the selected values+indices would cross
  the pod link on real hardware. In the GSPMD dry-run we express it as
  sparsify -> psum -> densify so the collective operand shrinks by the
  compression ratio (visible in the HLO collective-bytes analysis).
* ``int8`` — per-chunk symmetric quantization before the reduce, dequantize
  after; 4x byte reduction at <0.5% relative error (tested).

Note on semantics: when the step runs under pjit, per-device gradients are
already mean-reduced by GSPMD. ``compressed_psum`` therefore *re-expresses*
the cross-pod share of that reduction: it is applied to the (already
data-parallel) gradient and is exact-shape-preserving, so it composes with
any partitioning. Error feedback state is module-level static per leaf only
in the shard_map training variant (training/dp_shardmap.py); in the pjit
path we apply pure compression (compress -> decompress) which models the
wire format and lets tests measure the numerical error it introduces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def topk_compress(g: jax.Array, ratio: float):
    """Keep the top `ratio` fraction by magnitude. Returns (values, idx, shape)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    return vals, idx, flat.size


def topk_decompress(vals, idx, size):
    return jnp.zeros((size,), vals.dtype).at[idx].set(vals)


def int8_compress(g: jax.Array, chunk: int = 256):
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % chunk
    flat = jnp.pad(flat, (0, pad))
    c = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(c), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(c / jnp.maximum(scale, 1e-12)), -127, 127
                 ).astype(jnp.int8)
    return q, scale, g.shape, pad


def int8_decompress(q, scale, shape, pad):
    c = q.astype(jnp.float32) * scale
    flat = c.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum(grads, tcfg: TrainConfig):
    """Apply the configured wire-format compression to every gradient leaf."""
    if tcfg.grad_compression == "topk":
        def leaf(g):
            if g.ndim == 0 or g.size < 1024:
                return g
            vals, idx, size = topk_compress(g, tcfg.compression_ratio)
            return topk_decompress(vals, idx, size).reshape(g.shape)
        return jax.tree.map(leaf, grads)
    if tcfg.grad_compression == "int8":
        def leaf(g):
            if g.ndim == 0:
                return g
            return int8_decompress(*int8_compress(g)).astype(g.dtype)
        return jax.tree.map(leaf, grads)
    return grads


def error_feedback_compress(g, err, ratio):
    """Top-k with error feedback: returns (wire_values, wire_idx, new_err).

    Used by the shard_map DP variant where per-pod state is explicit."""
    corrected = g.astype(jnp.float32) + err
    vals, idx, size = topk_compress(corrected, ratio)
    sent = topk_decompress(vals, idx, size).reshape(g.shape)
    return vals, idx, corrected - sent
