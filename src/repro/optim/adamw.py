"""AdamW + schedules, pure pytree implementation (no optax offline).

PCA projection matrices (params paths containing 'pca') are calibration
artifacts, not trainable weights — they get zero updates and no optimizer
state contribution beyond placeholders.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def _is_frozen(path) -> bool:
    return any(getattr(k, "key", None) == "pca" for k in path)


def init_state(params) -> AdamWState:
    zeros = jax.tree_util.tree_map_with_path(
        lambda p, x: jnp.zeros_like(x) if not _is_frozen(p)
        else jnp.zeros((), x.dtype), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def cosine_schedule(cfg: TrainConfig):
    def lr_at(step):
        step = step.astype(jnp.float32)
        warm = cfg.lr * step / max(cfg.warmup_steps, 1)
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * cfg.lr * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr_at


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params, grads, state: AdamWState, cfg: TrainConfig):
    """One AdamW step. Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = cosine_schedule(cfg)(step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, mu, nu):
        if _is_frozen(path):
            return p, mu, nu
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        u = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * u).astype(p.dtype), mu, nu

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, mu, nu: upd(path, p, g, mu, nu),
        params, grads, state.mu, state.nu)
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), gnorm
