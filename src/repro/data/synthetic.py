"""Deterministic synthetic token pipeline.

Offline container => no real corpora. The generator produces a *structured*
Markov-ish token stream (not uniform noise) so that perplexity/top-k
benchmarks have signal: a small trained model actually concentrates attention
mass, which is what Loki's top-k selection exploits.

Properties the framework relies on:
  * fully deterministic given (seed, step)  -> exact resume after restart
  * per-host sharding by process index      -> multi-host data parallel
  * O(1) state (the iterator *is* the step) -> checkpoint-free data resume
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 512
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 1234
    n_states: int = 64          # markov states; lower = more predictable
    temperature: float = 0.7


class SyntheticLM:
    """Order-1 Markov chain over a random stochastic matrix + positional
    repetition structure (forces long-range attention: token t attends to
    t - period)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        logits = rng.randn(cfg.n_states, cfg.vocab) / cfg.temperature
        self.emit = _softmax(logits)
        trans = rng.randn(cfg.n_states, cfg.n_states) / cfg.temperature
        self.trans = _softmax(trans)
        self.period = max(cfg.seq_len // 4, 8)

    def batch_at(self, step: int, host: int = 0, n_hosts: int = 1
                 ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // n_hosts
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 613 + host * 7919) % (2**31 - 1))
        b, s = per_host, cfg.seq_len + 1
        states = rng.randint(0, cfg.n_states, size=(b,))
        toks = np.empty((b, s), np.int32)
        for t in range(s):
            # emit
            probs = self.emit[states]
            c = probs.cumsum(axis=1)
            u = rng.rand(b, 1)
            toks[:, t] = (u < c).argmax(axis=1)
            # every `period` steps, copy an old token (long-range structure)
            if t >= self.period and t % self.period == 0:
                toks[:, t] = toks[:, t - self.period]
            # transition
            tc = self.trans[states].cumsum(axis=1)
            states = (rng.rand(b, 1) < tc).argmax(axis=1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iterate(self, start_step: int = 0, host: int = 0, n_hosts: int = 1
                ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, host, n_hosts)
            step += 1


def _softmax(x):
    e = np.exp(x - x.max(axis=-1, keepdims=True))
    return e / e.sum(axis=-1, keepdims=True)


def jax_batch(batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
    return {k: jnp.asarray(v) for k, v in batch.items()}
