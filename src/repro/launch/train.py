"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 50 --mesh 1x1 --ckpt-dir /tmp/run0

On a real TPU fleet this binary runs per-host under the cluster scheduler
(jax.distributed.initialize picks hosts up); here it runs single-process.
The mesh is (data, model); params/optimizer state are sharded by the logical
axis rules (FSDP over data, TP over model), the batch over data. Restart the
same command after a failure and it resumes from the latest checkpoint.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 4x2")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", default="none", choices=["none", "dots",
                                                        "full"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--force-devices", type=int, default=0,
                    help="force N host devices (set BEFORE jax init)")
    args = ap.parse_args()
    if args.force_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.force_devices}")

    from jax.sharding import NamedSharding
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import TrainConfig
    from repro.data.synthetic import DataConfig, SyntheticLM
    from repro.models import lm
    from repro.optim import adamw
    from repro.sharding import axes as AX
    from repro.sharding.rules import spec_for, use_mesh
    from repro.training.step import TrainState, make_train_step

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    tcfg = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps, microbatch=args.microbatch,
                       remat=args.remat,
                       grad_compression=args.grad_compression)
    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh(dshape, ("data", "model"),
                         devices=jax.devices()[: int(np.prod(dshape))])
    print(f"arch={cfg.arch} mesh={dshape} devices={mesh.devices.size} "
          f"steps={args.steps}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch, seed=7,
                      n_states=32, temperature=0.22)
    data = SyntheticLM(dcfg)

    with use_mesh(mesh):
        params = lm.init(jax.random.PRNGKey(tcfg.seed), cfg)
        state = TrainState(params, adamw.init_state(params))
        # shard the state onto the mesh per the logical axis rules
        p_axes = AX.param_axes_tree(jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params))

        def shard_like(ax, arr):
            return jax.device_put(
                arr, NamedSharding(mesh, spec_for(ax, arr.shape, mesh)))

        def fix(ax, a):
            return ax if len(ax) == len(a.shape) else (None,) * len(a.shape)

        st_axes = TrainState(p_axes, type(state.opt)(
            (None,), p_axes, p_axes))
        st_axes = jax.tree.map(
            fix, st_axes, state,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        state = jax.tree.map(shard_like, st_axes, state,
                             is_leaf=lambda x: isinstance(x, tuple) and all(
                                 isinstance(e, (str, type(None)))
                                 for e in x))

        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        start, state = ckpt.restore_latest(state)
        start = start or 0
        if start:
            print(f"resumed from checkpoint step {start}")

        step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
        t0 = time.time()
        for step in range(start, args.steps):
            batch = {k: jax.device_put(
                jnp.asarray(v),
                NamedSharding(mesh, spec_for(
                    ("batch", "seq"), v.shape, mesh)))
                for k, v in data.batch_at(step).items()}
            state, metrics = step_fn(state, batch)
            if step % 10 == 0 or step + 1 == args.steps:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({(time.time()-t0):.1f}s)")
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                ckpt.save(step + 1, state)
        ckpt.wait()
    print("done")


if __name__ == "__main__":
    main()
