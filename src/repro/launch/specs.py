"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

No device allocation — everything here is abstract. The same specs feed
jit(...).lower() for the dry-run and the roofline derivation.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import lm
from repro.optim import adamw
from repro.training.step import TrainState


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": SDS((b, s), jnp.int32),
        "labels": SDS((b, s), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        specs["frames"] = SDS((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.vision_tokens:
        specs["patches"] = SDS((b, cfg.vision_tokens, cfg.d_model),
                               jnp.bfloat16)
    return specs


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(lm.init, cfg=cfg), jax.random.key(0))


def state_specs(cfg: ModelConfig):
    p = params_specs(cfg)
    opt = jax.eval_shape(adamw.init_state, p)
    return TrainState(p, opt)


def cache_specs(cfg: ModelConfig, batch: int, smax: int,
                dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(lm.init_cache, cfg, batch, smax, dtype))


def cast_serving_params(params):
    """Serving-time weight cast: linear/embedding weights to bf16 (halves
    param HBM traffic per decode step -- Perf L4); PCA projections and any
    non-float leaves stay as-is (basis precision for Lemma 4.1 exactness)."""
    def f(path, a):
        name = getattr(path[-1], "key", str(path[-1])) if path else ""
        if name == "pca" or not jnp.issubdtype(a.dtype, jnp.floating):
            return a
        return a.astype(jnp.bfloat16)
    return jax.tree_util.tree_map_with_path(f, params)


def serve_params_specs(cfg: ModelConfig):
    return jax.eval_shape(cast_serving_params, params_specs(cfg))


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(cache, token, pos_len) for one serve_step with a seq_len-deep cache."""
    b, s = shape.global_batch, shape.seq_len
    cache = cache_specs(cfg, b, s)
    return cache, SDS((b,), jnp.int32), SDS((b,), jnp.int32)


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    specs = [SDS((b, s), jnp.int32)]
    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = SDS((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.vision_tokens:
        kw["patches"] = SDS((b, cfg.vision_tokens, cfg.d_model),
                            jnp.bfloat16)
    return specs, kw
