"""Production serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --policy loki --requests 6 --max-new 16

Builds the serving engine with the selected attention policy
(full | loki | loki_block | exact_topk | h2o | pcaattn), calibrates PCA
transforms on the fly for Loki policies, and reports per-tick latency and
throughput over a synthetic request stream.

Every knob lives in :class:`ServeConfig`, a frozen dataclass with four
sections — ``engine`` (arch / policy / backend / slots), ``pool`` (page
size, pool size, prefill chunk), ``scheduler`` (policy, per-tick token
budgets, prefix cache) and ``layout`` (the per-component PageLayout spec,
e.g. ``int8:pca:r=32``) — consumed by both engine kinds and printed in
full by ``--dryrun``. The argparse flags are thin aliases over its fields.

``--engine paged`` (default) serves from the paged KV-cache with the
chunked-prefill scheduler (serving/scheduler.py). The allowed set is
derived from the per-layer CacheSpec registry (serving/cache_spec.py), so
*every* family serves paged — hybrid (hymba) and ssm (xlstm) carry their
recurrent state in per-slot StateSlots, whisper's encoder K/V is written
once at admission, and mixtral's sliding-window layers recycle pages that
slide out of the window. Only policies whose caches cannot rebuild exact
prefix attention (h2o, pcaattn) fall back to the dense slot engine.

``--layout`` selects the physical page layout (DESIGN.md §10): storage
dtype (fp32 | fp16 | bf16 | int8 | fp8), storage basis (native | pca —
keys written to pages already projected to the PCA basis, exact at full
rank by Lemma 4.1), and an optional latent rank ``r=N`` truncating the
stored key width. Quantized dtypes carry one f32 scale per physical page
beside the page table; the decode kernels dequantize in their DMA
epilogue.

``--dryrun`` prints the per-layer CacheSpec table for the chosen arch and
policy (what state each layer holds, page budgets, recycle window, bytes
per page under the layout), the full ServeConfig, and exits without
touching the accelerator.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ModelConfig, PageLayout, TrainConfig
from repro.core import pca as PCA
from repro.data.synthetic import DataConfig, SyntheticLM, jax_batch
from repro.models import lm
from repro.optim import adamw
from repro.serving import cache_spec as CS
from repro.serving import faults as FI
from repro.serving.engine import Engine, Request, ServingEngine
from repro.serving.lifecycle import Deadline, summarize
from repro.serving.scheduler import PAGED_POLICIES, PagedServingEngine
from repro.training.step import TrainState, make_train_step


# ------------------------------------------------------------ ServeConfig

@dataclasses.dataclass(frozen=True)
class EngineSection:
    """What runs: model, attention policy, kernel backend, batch shape."""
    arch: str = "qwen2.5-3b"
    smoke: bool = True
    kind: str = "paged"            # paged | dense
    policy: str = "loki"
    k_f: float = 0.25
    d_f: float = 0.25
    backend: str = "auto"          # auto | pallas | xla
    n_slots: int = 4
    smax: int = 128


@dataclasses.dataclass(frozen=True)
class PoolSection:
    """Paged-engine pool shape (0 = derive from the spec table)."""
    page_size: int = 0             # tokens per page (0 = loki block_size)
    n_pages: int = 0               # pool size (0 = fit all slots)
    prefill_chunk: int = 32
    device_pages: int = 0          # tiered pool (§13): HBM frames; 0 = off
    max_inflight: int = 2          # bounded async fetch queue depth


@dataclasses.dataclass(frozen=True)
class SchedulerSection:
    """Tick policy: admission order, per-tick token budgets, sharing."""
    policy: str = "fifo"           # fifo | priority
    prefill_budget: int = 0        # prompt tok/tick (0 = one chunk)
    decode_budget: int = 0         # live slots decoded/tick (0 = all)
    prefix_cache: bool = True


@dataclasses.dataclass(frozen=True)
class LifecycleSection:
    """Request-lifecycle hardening knobs (DESIGN.md §11)."""
    admission: str = "strict"      # strict | lenient (oversized requests)
    faults: str = ""               # FaultPlan.parse spec; '' = off
    audit: bool = False            # per-tick invariant auditor
    shed_after: int = 0            # preemptions before SHED (0 = never)
    ttft_deadline: float = 0.0     # s to first token (0 = none)
    total_deadline: float = 0.0    # s to completion (0 = none)

    def fault_plan(self) -> Optional[FI.FaultPlan]:
        return FI.FaultPlan.parse(self.faults) if self.faults else None

    def request_deadline(self) -> Optional[Deadline]:
        if not (self.ttft_deadline or self.total_deadline):
            return None
        return Deadline(ttft=self.ttft_deadline or None,
                        total=self.total_deadline or None)


@dataclasses.dataclass(frozen=True)
class LayoutSection:
    """Physical page layout spec, ``PageLayout.parse`` syntax
    (e.g. ``fp16``, ``fp32:pca``, ``int8:pca:r=32``); '' = default."""
    spec: str = ""

    def page_layout(self) -> PageLayout:
        return PageLayout.parse(self.spec) if self.spec else PageLayout()


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """One object holding every serving knob; the CLI flags are aliases.

    ``resolve_model()`` folds the policy and layout into a ModelConfig and
    ``build_engine()`` constructs whichever engine the spec table allows —
    the rest of the launcher (and any harness) only talks to the
    :class:`~repro.serving.engine.Engine` protocol it returns."""
    engine: EngineSection = dataclasses.field(default_factory=EngineSection)
    pool: PoolSection = dataclasses.field(default_factory=PoolSection)
    scheduler: SchedulerSection = dataclasses.field(
        default_factory=SchedulerSection)
    layout: LayoutSection = dataclasses.field(default_factory=LayoutSection)
    lifecycle: LifecycleSection = dataclasses.field(
        default_factory=LifecycleSection)
    requests: int = 6
    max_new: int = 16
    warm_steps: int = 60

    @classmethod
    def from_args(cls, a: argparse.Namespace) -> "ServeConfig":
        return cls(
            engine=EngineSection(
                arch=a.arch, smoke=a.smoke, kind=a.engine, policy=a.policy,
                k_f=a.k_f, d_f=a.d_f, backend=a.backend,
                n_slots=a.n_slots, smax=a.smax),
            pool=PoolSection(page_size=a.page_size, n_pages=a.n_pages,
                             prefill_chunk=a.prefill_chunk,
                             device_pages=a.device_pages,
                             max_inflight=a.max_inflight),
            scheduler=SchedulerSection(
                policy=a.sched_policy, prefill_budget=a.prefill_budget,
                decode_budget=a.decode_budget,
                prefix_cache=a.prefix_cache == "on"),
            layout=LayoutSection(spec=a.layout),
            lifecycle=LifecycleSection(
                admission=a.admission, faults=a.faults, audit=a.audit,
                shed_after=a.shed_after, ttft_deadline=a.ttft_deadline,
                total_deadline=a.total_deadline),
            requests=a.requests, max_new=a.max_new,
            warm_steps=a.warm_steps)

    def resolve_model(self) -> ModelConfig:
        cfg = (get_smoke_config if self.engine.smoke
               else get_config)(self.engine.arch)
        policy = self.engine.policy
        if cfg.family == "ssm" and policy != "full":
            print(f"note: {self.engine.arch} has no KV cache; policy "
                  "forced to full")
            policy = "full"
        if policy != "full":
            cfg = cfg.with_policy(policy, k_f=self.engine.k_f,
                                  d_f=self.engine.d_f)
        lay = self.layout.page_layout()
        if lay != PageLayout():
            cfg = cfg.with_layout(lay)
        return cfg

    def build_engine(self, params, cfg: ModelConfig) -> Tuple[Engine, bool]:
        """Construct the engine the spec table allows; (engine, paged?)."""
        pageable, why = CS.pageable(cfg)
        paged = self.engine.kind == "paged" and pageable
        if self.engine.kind == "paged" and not paged:
            print(f"note: {why}; falling back to the dense engine")
        lc = self.lifecycle
        if paged:
            eng = PagedServingEngine(
                params, cfg, n_slots=self.engine.n_slots,
                smax=self.engine.smax,
                page_size=self.pool.page_size or None,
                n_pages=self.pool.n_pages or None,
                prefill_chunk=self.pool.prefill_chunk,
                backend=self.engine.backend,
                policy=self.scheduler.policy,
                prefill_budget=self.scheduler.prefill_budget or None,
                decode_budget=self.scheduler.decode_budget or None,
                prefix_cache=self.scheduler.prefix_cache,
                admission=lc.admission,
                shed_after=lc.shed_after or None,
                faults=lc.fault_plan(), audit=lc.audit,
                device_pages=self.pool.device_pages or None,
                max_inflight=self.pool.max_inflight)
        else:
            eng = ServingEngine(params, cfg, n_slots=self.engine.n_slots,
                                smax=self.engine.smax,
                                backend=self.engine.backend,
                                admission=lc.admission)
        return eng, paged

    def describe(self, cfg: ModelConfig) -> str:
        """The --dryrun report: every section, plus derived quantities."""
        lay = cfg.page_layout
        ps = self.pool.page_size or cfg.loki.block_size
        lines = [CS.format_spec_table(cfg, self.engine.smax, ps)]
        ok, why = CS.pageable(cfg)
        lines.append("engine: paged" if ok and self.engine.kind == "paged"
                     else "engine: dense" if self.engine.kind == "dense"
                     else f"engine: dense fallback — {why}")
        lines.append(
            f"scheduler: policy={self.scheduler.policy} prefill_budget="
            f"{self.scheduler.prefill_budget or self.pool.prefill_chunk} "
            f"tok/tick decode_budget="
            f"{self.scheduler.decode_budget or self.engine.n_slots} "
            "tok/tick")
        can_share, share_why = CS.prefix_shareable(cfg)
        if not self.scheduler.prefix_cache:
            lines.append("prefix-cache: off (by flag)")
        elif can_share:
            lines.append("prefix-cache: on (page-granular, COW tail, LRU "
                         "eviction before preemption)")
        else:
            lines.append(f"prefix-cache: bypassed — {share_why}")
        bpr = lay.bytes_per_page_row(cfg.resolved_head_dim, cfg.n_kv_heads)
        lines.append(
            f"layout: {lay.describe()} — {bpr * ps} B/page/layer"
            + (" (per-page f32 scales beside the table)"
               if lay.quantized else ""))
        if self.pool.device_pages:
            d = CS.latent_score_width(cfg)
            lines.append(
                f"tiered pool: {self.pool.device_pages} device frames, "
                f"host offload beyond, rank-{d} latent sidecar resident, "
                f"<= {self.pool.max_inflight} fetches in flight "
                "(demote-before-preempt)")
        lc = self.lifecycle
        plan = lc.fault_plan()
        lines.append(
            f"lifecycle: admission={lc.admission}"
            + (f" shed_after={lc.shed_after}" if lc.shed_after else "")
            + (f" ttft_deadline={lc.ttft_deadline}s" if lc.ttft_deadline
               else "")
            + (f" total_deadline={lc.total_deadline}s" if lc.total_deadline
               else "")
            + (f" faults=[{plan.describe()}]" if plan is not None else "")
            + (" audit=per-tick" if lc.audit else ""))
        lines.append("paged-servable archs (default policy): "
                     + ", ".join(CS.servable_archs()))
        return "\n".join(lines)


def _frames(cfg, seed: int, batch: int = 1):
    """Deterministic stand-in encoder frames (offline container: no audio
    frontend; the conv stem is stubbed, see configs/whisper_small.py)."""
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (batch, cfg.enc_seq, cfg.d_model),
                             jnp.float32)


def build_parser() -> argparse.ArgumentParser:
    """Thin aliases over ServeConfig's fields (see ServeConfig.from_args)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--policy", default="loki",
                    choices=["full", "loki", "loki_block", "exact_topk",
                             "h2o", "pcaattn"])
    ap.add_argument("--k-f", type=float, default=0.25)
    ap.add_argument("--d-f", type=float, default=0.25)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "xla"],
                    help="decode kernel backend for loki_block "
                         "(core/dispatch.py; auto = Pallas on TPU)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--smax", type=int, default=128)
    ap.add_argument("--engine", default="paged", choices=["paged", "dense"],
                    help="paged = page-pool cache + chunked-prefill "
                         "scheduler (serving/scheduler.py); dense = the "
                         "preallocated slot cache")
    ap.add_argument("--page-size", type=int, default=0,
                    help="tokens per KV page (0 = loki block_size)")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="page pool size (0 = fit all slots at their "
                         "spec-table page bound)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens prefetched per tick (paged engine)")
    ap.add_argument("--device-pages", type=int, default=0,
                    help="tiered KV pool (DESIGN.md §13): full-D K/V "
                         "frames kept in device memory; pages beyond "
                         "spill to pinned host buffers and promote back "
                         "through the Loki-guided fetch queue (0 = "
                         "single-tier; needs a loki policy)")
    ap.add_argument("--max-inflight", type=int, default=2,
                    help="outstanding async host->device fetches of the "
                         "tiered pool (bounded staging budget)")
    ap.add_argument("--sched-policy", default="fifo",
                    choices=["fifo", "priority"],
                    help="paged-engine SchedulerPolicy (serving/policy.py);"
                         " priority admits by Request.priority and may "
                         "preempt a lower class for a slot")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="prompt tokens computed per tick across chunks/"
                         "slots (0 = one chunk per tick)")
    ap.add_argument("--decode-budget", type=int, default=0,
                    help="live slots decoded per tick (0 = all)")
    ap.add_argument("--prefix-cache", default="on", choices=["on", "off"],
                    help="share identical prompt-prefix pages across "
                         "requests (auto-bypassed for configs whose spec "
                         "table marks components unshareable)")
    ap.add_argument("--layout", default="",
                    help="PageLayout spec 'dtype[:basis][:r=N]' — dtype "
                         "fp32|fp16|bf16|int8|fp8, basis native|pca, "
                         "latent rank r (pca only); e.g. 'int8:pca:r=32'. "
                         "Empty = fp32 native (bit-identical to PR 5)")
    ap.add_argument("--admission", default="strict",
                    choices=["strict", "lenient"],
                    help="strict FAILs requests whose prompt + max_new "
                         "can never fit smax at submit(); lenient keeps "
                         "the legacy truncate/cap degraded modes")
    ap.add_argument("--faults", default="",
                    help="deterministic fault-injection spec "
                         "(serving/faults.py), e.g. "
                         "'seed=3,nan_logits=0.05,kernel_fail@7'; sites: "
                         + ", ".join(FI.FaultPlan.SITES))
    ap.add_argument("--audit", action="store_true",
                    help="run the pool/slot/table invariant auditor after "
                         "every tick (raises AuditError on violation)")
    ap.add_argument("--shed-after", type=int, default=0,
                    help="preemptions a request survives before being "
                         "shed (terminal SHED + retry-after hint); "
                         "0 = never shed")
    ap.add_argument("--ttft-deadline", type=float, default=0.0,
                    help="per-request seconds-to-first-token budget "
                         "(0 = none)")
    ap.add_argument("--total-deadline", type=float, default=0.0,
                    help="per-request total wall budget in seconds "
                         "(0 = none)")
    ap.add_argument("--warm-steps", type=int, default=60,
                    help="brief training so generation has signal")
    ap.add_argument("--dryrun", action="store_true",
                    help="print the per-layer CacheSpec table and the "
                         "full ServeConfig, then exit")
    return ap


def main():
    args = build_parser().parse_args()
    sc = ServeConfig.from_args(args)
    cfg = sc.resolve_model()

    if args.dryrun:
        print(sc.describe(cfg))
        return

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=96, global_batch=8, seed=7,
                      n_states=32, temperature=0.22)
    data = SyntheticLM(dcfg)

    def batch_with_extras(i):
        batch = jax_batch(data.batch_at(i))
        if cfg.is_encoder_decoder:
            batch["frames"] = _frames(cfg, i, batch["tokens"].shape[0])
        return batch

    params = lm.init(jax.random.PRNGKey(0), cfg)
    if sc.warm_steps:
        tcfg = TrainConfig(lr=3e-3, warmup_steps=5,
                           total_steps=sc.warm_steps)
        state = TrainState(params, adamw.init_state(params))
        step = jax.jit(make_train_step(cfg, tcfg))
        for i in range(sc.warm_steps):
            state, m = step(state, batch_with_extras(i))
        params = state.params
        print(f"warmed {sc.warm_steps} steps, loss "
              f"{float(m['loss']):.3f}")

    needs_pca = (cfg.attn_policy() in ("loki", "loki_block", "pcaattn")
                 or cfg.page_layout.basis == "pca")
    if needs_pca:
        batches = [jnp.asarray(data.batch_at(1000 + i)["tokens"])
                   for i in range(2)]
        frames = (_frames(cfg, 0, batches[0].shape[0])
                  if cfg.is_encoder_decoder else None)
        calib = PCA.calibrate_model(params, cfg, batches, frames=frames)
        params = PCA.install_projections(params, calib, "pre")
        print("PCA calibration installed")

    eng, paged = sc.build_engine(params, cfg)
    if paged:
        extra = (f" window={eng.window} (recycling)" if eng.window else "")
        share = ("on" if eng.prefix_caching else
                 f"bypassed ({eng.prefix_cache_reason})"
                 if sc.scheduler.prefix_cache else "off")
        print(f"paged engine: page_size={eng.page_size} "
              f"pool={eng.pool.n_pages} pages "
              f"(budget {eng.req_budget}/request){extra} "
              f"layout={cfg.page_layout.describe()} "
              f"policy={eng.policy.name} "
              f"budgets={eng.budget.prefill_tokens}p/"
              f"{eng.budget.decode_tokens}d tok/tick "
              f"prefix-cache={share}")
    # the priority policy needs classes to tell apart: spread the demo
    # stream over two of them (even rids are urgent)
    deadline = sc.lifecycle.request_deadline()
    reqs = [Request(rid=i,
                    prompt=data.batch_at(4000 + i)["tokens"][0, :24 + 4 * i],
                    max_new=sc.max_new,
                    priority=(i + 1) % 2
                    if sc.scheduler.policy == "priority" else 0,
                    deadline=deadline,
                    frames=(np.asarray(_frames(cfg, 4000 + i)[0])
                            if cfg.is_encoder_decoder else None))
            for i in range(sc.requests)]
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    eng.drain()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"policy={cfg.attn_policy()} served {len(reqs)} requests "
          f"({toks} tokens) in {eng.ticks} ticks, {dt:.1f}s "
          f"-> {toks/dt:.1f} tok/s, {1e3*dt/max(eng.ticks,1):.0f} ms/tick")
    st = eng.stats()
    line = f"lifecycle: {summarize(reqs)}"
    for k in ("n_stalled", "n_shed", "n_quarantined",
              "n_backend_fallbacks"):
        if st.get(k):
            line += f" {k}={st[k]}"
    if st.get("faults"):
        line += f" faults={st['faults']}"
    print(line)
    for r in reqs:
        if str(r.status) not in ("done",):
            print(f"  req{r.rid}: {r.status} — {r.detail}")
    if paged and eng.prefix_caching:
        print(f"prefix cache: {eng.n_prefix_hit_tokens} hit tokens, "
              f"{eng.n_prefill_computed_tokens} computed "
              f"(hit rate {eng.prefix_hit_rate():.2f}), "
              f"{eng.n_cow_copies} COW copies, "
              f"{eng.pool.n_evicted} evictions")
    if st.get("tiered"):
        ti = st["tiered"]
        print(f"tiered pool: {ti['device_pages']} device frames, "
              f"{ti['n_demoted']} demoted / {ti['n_promoted']} promoted, "
              f"prefetch hit rate {ti['prefetch_hit_rate']:.2f}, "
              f"{ti['n_sync_fetches']} sync fetches, "
              f"{ti['n_decode_reruns']} decode reruns")
    for r in reqs[:2]:
        print(f"  req{r.rid}: {np.asarray(r.out)[:10]}")
    print("done")


if __name__ == "__main__":
    main()
