"""Production serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --policy loki --requests 6 --max-new 16

Builds the serving engine with the selected attention policy
(full | loki | loki_block | exact_topk | h2o | pcaattn), calibrates PCA
transforms on the fly for Loki policies, and reports per-tick latency and
throughput over a synthetic request stream.

``--engine paged`` (default) serves from the paged KV-cache with the
chunked-prefill scheduler (serving/scheduler.py). The allowed set is
derived from the per-layer CacheSpec registry (serving/cache_spec.py), so
*every* family serves paged — hybrid (hymba) and ssm (xlstm) carry their
recurrent state in per-slot StateSlots, whisper's encoder K/V is written
once at admission, and mixtral's sliding-window layers recycle pages that
slide out of the window. Only policies whose caches cannot rebuild exact
prefix attention (h2o, pcaattn) fall back to the dense slot engine.

``--sched-policy`` picks the paged engine's SchedulerPolicy (fifo |
priority), ``--prefill-budget``/``--decode-budget`` cap per-tick work in
tokens (vLLM-style), and ``--prefix-cache`` toggles page-granular prompt
prefix sharing (COW on the partial tail page; auto-bypassed for configs
whose spec table marks components unshareable).

``--dryrun`` prints the per-layer CacheSpec table for the chosen arch and
policy (what state each layer holds, page budgets, recycle window), the
scheduler policy + token budgets + prefix-cache config, and exits without
touching the accelerator.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import TrainConfig
from repro.core import pca as PCA
from repro.data.synthetic import DataConfig, SyntheticLM, jax_batch
from repro.models import lm
from repro.optim import adamw
from repro.serving import cache_spec as CS
from repro.serving.engine import Request, ServingEngine
from repro.serving.scheduler import PAGED_POLICIES, PagedServingEngine
from repro.training.step import TrainState, make_train_step


def _frames(cfg, seed: int, batch: int = 1):
    """Deterministic stand-in encoder frames (offline container: no audio
    frontend; the conv stem is stubbed, see configs/whisper_small.py)."""
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (batch, cfg.enc_seq, cfg.d_model),
                             jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--policy", default="loki",
                    choices=["full", "loki", "loki_block", "exact_topk",
                             "h2o", "pcaattn"])
    ap.add_argument("--k-f", type=float, default=0.25)
    ap.add_argument("--d-f", type=float, default=0.25)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "xla"],
                    help="decode kernel backend for loki_block "
                         "(core/dispatch.py; auto = Pallas on TPU)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--smax", type=int, default=128)
    ap.add_argument("--engine", default="paged", choices=["paged", "dense"],
                    help="paged = page-pool cache + chunked-prefill "
                         "scheduler (serving/scheduler.py); dense = the "
                         "preallocated slot cache")
    ap.add_argument("--page-size", type=int, default=0,
                    help="tokens per KV page (0 = loki block_size)")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="page pool size (0 = fit all slots at their "
                         "spec-table page bound)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens prefetched per tick (paged engine)")
    ap.add_argument("--sched-policy", default="fifo",
                    choices=["fifo", "priority"],
                    help="paged-engine SchedulerPolicy (serving/policy.py);"
                         " priority admits by Request.priority and may "
                         "preempt a lower class for a slot")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="prompt tokens computed per tick across chunks/"
                         "slots (0 = one chunk per tick)")
    ap.add_argument("--decode-budget", type=int, default=0,
                    help="live slots decoded per tick (0 = all)")
    ap.add_argument("--prefix-cache", default="on", choices=["on", "off"],
                    help="share identical prompt-prefix pages across "
                         "requests (auto-bypassed for configs whose spec "
                         "table marks components unshareable)")
    ap.add_argument("--warm-steps", type=int, default=60,
                    help="brief training so generation has signal")
    ap.add_argument("--dryrun", action="store_true",
                    help="print the per-layer CacheSpec table, scheduler "
                         "policy, token budgets and prefix-cache config, "
                         "then exit")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if cfg.family == "ssm" and args.policy != "full":
        print(f"note: {args.arch} has no KV cache; policy forced to full")
        args.policy = "full"
    if args.policy != "full":
        cfg = cfg.with_policy(args.policy, k_f=args.k_f, d_f=args.d_f)

    if args.dryrun:
        ps = args.page_size or cfg.loki.block_size
        print(CS.format_spec_table(cfg, args.smax, ps))
        ok, why = CS.pageable(cfg)
        print("engine: paged" if ok else f"engine: dense fallback — {why}")
        print(f"scheduler: policy={args.sched_policy} "
              f"prefill_budget={args.prefill_budget or args.prefill_chunk} "
              f"tok/tick decode_budget={args.decode_budget or args.n_slots} "
              "tok/tick")
        can_share, share_why = CS.prefix_shareable(cfg)
        if args.prefix_cache == "off":
            print("prefix-cache: off (by flag)")
        elif can_share:
            print("prefix-cache: on (page-granular, COW tail, LRU "
                  "eviction before preemption)")
        else:
            print(f"prefix-cache: bypassed — {share_why}")
        print("paged-servable archs (default policy): "
              + ", ".join(CS.servable_archs()))
        return

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=96, global_batch=8, seed=7,
                      n_states=32, temperature=0.22)
    data = SyntheticLM(dcfg)

    def batch_with_extras(i):
        batch = jax_batch(data.batch_at(i))
        if cfg.is_encoder_decoder:
            batch["frames"] = _frames(cfg, i, batch["tokens"].shape[0])
        return batch

    params = lm.init(jax.random.PRNGKey(0), cfg)
    if args.warm_steps:
        tcfg = TrainConfig(lr=3e-3, warmup_steps=5,
                           total_steps=args.warm_steps)
        state = TrainState(params, adamw.init_state(params))
        step = jax.jit(make_train_step(cfg, tcfg))
        for i in range(args.warm_steps):
            state, m = step(state, batch_with_extras(i))
        params = state.params
        print(f"warmed {args.warm_steps} steps, loss "
              f"{float(m['loss']):.3f}")

    if args.policy in ("loki", "loki_block", "pcaattn"):
        batches = [jnp.asarray(data.batch_at(1000 + i)["tokens"])
                   for i in range(2)]
        frames = (_frames(cfg, 0, batches[0].shape[0])
                  if cfg.is_encoder_decoder else None)
        calib = PCA.calibrate_model(params, cfg, batches, frames=frames)
        params = PCA.install_projections(params, calib, "pre")
        print("PCA calibration installed")

    # allowed set from the CacheSpec registry, not a family allowlist
    pageable, why = CS.pageable(cfg)
    paged = args.engine == "paged" and pageable
    if args.engine == "paged" and not paged:
        print(f"note: {why}; falling back to the dense engine")
    if paged:
        eng = PagedServingEngine(
            params, cfg, n_slots=args.n_slots, smax=args.smax,
            page_size=args.page_size or None,
            n_pages=args.n_pages or None,
            prefill_chunk=args.prefill_chunk, backend=args.backend,
            policy=args.sched_policy,
            prefill_budget=args.prefill_budget or None,
            decode_budget=args.decode_budget or None,
            prefix_cache=args.prefix_cache == "on")
        extra = (f" window={eng.window} (recycling)" if eng.window else "")
        share = ("on" if eng.prefix_caching else
                 f"bypassed ({eng.prefix_cache_reason})"
                 if args.prefix_cache == "on" else "off")
        print(f"paged engine: page_size={eng.page_size} "
              f"pool={eng.pool.n_pages} pages "
              f"(budget {eng.req_budget}/request){extra} "
              f"policy={eng.policy.name} "
              f"budgets={eng.budget.prefill_tokens}p/"
              f"{eng.budget.decode_tokens}d tok/tick "
              f"prefix-cache={share}")
    else:
        eng = ServingEngine(params, cfg, n_slots=args.n_slots,
                            smax=args.smax, backend=args.backend)
    # the priority policy needs classes to tell apart: spread the demo
    # stream over two of them (even rids are urgent)
    reqs = [Request(rid=i,
                    prompt=data.batch_at(4000 + i)["tokens"][0, :24 + 4 * i],
                    max_new=args.max_new,
                    priority=(i + 1) % 2 if args.sched_policy == "priority"
                    else 0,
                    frames=(np.asarray(_frames(cfg, 4000 + i)[0])
                            if cfg.is_encoder_decoder else None))
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    eng.run_until_done()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"policy={args.policy} served {len(reqs)} requests "
          f"({toks} tokens) in {eng.ticks} ticks, {dt:.1f}s "
          f"-> {toks/dt:.1f} tok/s, {1e3*dt/max(eng.ticks,1):.0f} ms/tick")
    if paged and eng.prefix_caching:
        print(f"prefix cache: {eng.n_prefix_hit_tokens} hit tokens, "
              f"{eng.n_prefill_computed_tokens} computed "
              f"(hit rate {eng.prefix_hit_rate():.2f}), "
              f"{eng.n_cow_copies} COW copies, "
              f"{eng.pool.n_evicted} evictions")
    for r in reqs[:2]:
        print(f"  req{r.rid}: {np.asarray(r.out)[:10]}")
    print("done")


if __name__ == "__main__":
    main()
