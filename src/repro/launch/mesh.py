"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. The dry-run launcher force-creates 512 host devices (see
dryrun.py) before calling this.

Mesh axes:
  pod   — pure data parallelism across pods (slow ICI/DCN links); gradient
          compression targets reductions along this axis.
  data  — within-pod data parallel + FSDP shard axis for parameters.
  model — tensor parallel (heads / ffn / vocab / experts) + decode-time
          KV-cache sequence shards.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devs)} present; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for unit tests (requires forced host devices)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_single_device_mesh() -> Mesh:
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
