"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the forced host device count before ANY other import touches jax.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", ""))

# ruff: noqa: E402
import argparse
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config, shape_by_name, SHAPES
from repro.configs.base import TrainConfig
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.sharding import axes as AX
from repro.sharding.rules import spec_for, tree_specs, use_mesh
from repro.training.step import TrainState, make_train_step
from repro.utils.hlo import collective_bytes
from repro.utils.roofline import Roofline, model_flops

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _shardings(tree_of_axes, shapes_tree, mesh):
    def one(ax, sh):
        return NamedSharding(mesh, spec_for(ax, sh.shape, mesh))
    return jax.tree.map(one, tree_of_axes, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def _analyze(lowered, compiled, *, cfg, arch, shape, mesh_name, policy,
             chips, n_layers):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    # xla's cost_analysis counts while bodies once; our loop-weighted HLO
    # analyzer (utils.hlo) is the authoritative source for roofline terms.
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        peak = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
                + mem.output_size_in_bytes + mem.alias_size_in_bytes)
        mem_str = {
            "temp": mem.temp_size_in_bytes,
            "args": mem.argument_size_in_bytes,
            "out": mem.output_size_in_bytes,
            "peak_sum": peak,
        }
    except Exception:
        peak, mem_str = None, {}
    text = compiled.as_text()
    from repro.utils.hlo import analyze as hlo_analyze
    hc = hlo_analyze(text)
    rl = Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, policy=policy,
        flops_per_device=hc.flops, bytes_per_device=hc.bytes_accessed,
        collective_bytes_per_device=hc.collective_bytes,
        model_flops=model_flops(cfg, shape), chips=chips,
        peak_mem_per_device=peak)
    rec = rl.to_dict()
    rec["collectives"] = hc.collectives
    rec["collective_counts"] = hc.collective_counts
    rec["memory_analysis"] = mem_str
    rec["xla_cost_flops_unweighted"] = xla_flops
    rec["xla_cost_bytes_unweighted"] = xla_bytes
    rec["hlo_size"] = len(text)
    return rec


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               policy: str = None, verbose: bool = True,
               extra_cfg=None, loki_kw=None, tcfg_kw=None,
               return_text: bool = False):
    """Lower + compile one cell; returns the roofline record dict."""
    shape = shape_by_name(shape_name)
    cfg = get_config(arch)
    if policy is None:
        policy = "full" if shape.kind != "decode" else default_policy(cfg)
    if shape.kind == "decode" and policy != "full":
        applicable = cfg.family not in ("ssm",)
        if applicable:
            kw = {"d_f": 0.25, "k_f": 0.25}
            if policy == "loki":
                # chunk-local selection aligned with the kv_seq shards:
                # 16 (model) at decode_32k, 256 (data x model) at long_500k
                kw["n_chunks"] = 256 if shape.name == "long_500k" else 16
            if loki_kw:
                kw.update(loki_kw)
            cfg = cfg.with_policy(policy, **kw)
        else:
            policy = "full"
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size

    p_shapes = S.params_specs(cfg)
    p_axes = AX.param_axes_tree(p_shapes)
    p_sh = _shardings(p_axes, p_shapes, mesh)

    t0 = time.time()
    if shape.kind == "train":
        tcfg = TrainConfig(**{"remat": "dots", **(tcfg_kw or {})})
        st_shapes = S.state_specs(cfg)
        st_axes = TrainState(p_axes, type(st_shapes.opt)(
            (None,),
            jax.tree.map(lambda a: a, p_axes),
            jax.tree.map(lambda a: a, p_axes)))
        # frozen pca leaves in opt state are scalars; fix axes by shape

        def fix(ax, sh):
            return ax if len(ax) == len(sh.shape) else (None,) * len(sh.shape)
        st_axes = jax.tree.map(
            fix, st_axes, st_shapes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        st_sh = _shardings(st_axes, st_shapes, mesh)
        b_shapes = S.batch_specs(cfg, shape)
        b_axes = AX.batch_axes(b_shapes)
        b_sh = _shardings(b_axes, b_shapes, mesh)
        step = make_train_step(cfg, tcfg)
        with use_mesh(mesh):
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None), donate_argnums=(0,))
            lowered = jitted.lower(st_shapes, b_shapes)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        args, kw = S.prefill_input_specs(cfg, shape)
        tok_sh = NamedSharding(mesh, spec_for(
            ("batch", "seq"), args[0].shape, mesh))
        frames = kw.get("frames")
        patches = kw.get("patches")
        extra_specs = [v for v in (frames, patches) if v is not None]
        extra_sh = [NamedSharding(mesh, spec_for(("batch", None, None),
                                                 v.shape, mesh))
                    for v in extra_specs]

        def prefill_fn(params, tokens, *extras):
            kwargs = {}
            it = iter(extras)
            if frames is not None:
                kwargs["frames"] = next(it)
            if patches is not None:
                kwargs["patches"] = next(it)
            return lm.prefill(params, cfg, tokens, shape.seq_len, **kwargs)

        with use_mesh(mesh):
            jitted = jax.jit(prefill_fn,
                             in_shardings=(p_sh, tok_sh, *extra_sh),
                             out_shardings=None)
            lowered = jitted.lower(p_shapes, args[0], *extra_specs)
            compiled = lowered.compile()
    else:  # decode
        # serving weights are bf16 (§Perf L4); PCA stays f32
        p_shapes = S.serve_params_specs(cfg)
        p_sh = _shardings(p_axes, p_shapes, mesh)
        cache_shapes, tok_spec, pos_spec = S.decode_input_specs(cfg, shape)
        c_axes = AX.cache_axes_tree(cache_shapes)
        c_sh = _shardings(c_axes, cache_shapes, mesh)
        tok_sh = NamedSharding(mesh, spec_for(("batch",), tok_spec.shape,
                                              mesh))

        def decode_fn(params, cache, token, pos_len):
            return lm.decode_step(params, cfg, cache, token, pos_len)

        with use_mesh(mesh):
            jitted = jax.jit(decode_fn,
                             in_shardings=(p_sh, c_sh, tok_sh, tok_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(p_shapes, cache_shapes, tok_spec, pos_spec)
            compiled = lowered.compile()

    compile_s = time.time() - t0
    rec = _analyze(lowered, compiled, cfg=cfg, arch=arch, shape=shape,
                   mesh_name=mesh_name, policy=policy, chips=chips,
                   n_layers=cfg.n_layers)
    rec["compile_seconds"] = compile_s
    if return_text:
        rec["_text"] = compiled.as_text()
    if verbose:
        print(f"[dryrun] {arch} {shape.name} mesh={mesh_name} "
              f"policy={policy} compile={compile_s:.1f}s "
              f"flops/dev={rec['flops_per_device']:.3g} "
              f"bytes/dev={rec['bytes_per_device']:.3g} "
              f"coll/dev={rec['collective_bytes_per_device']:.3g} "
              f"bottleneck={rec['bottleneck']}")
    return rec


def default_policy(cfg) -> str:
    if cfg.family == "ssm":
        return "full"          # no KV cache; Loki inapplicable
    return "loki"


def save(rec, tag=""):
    os.makedirs(OUT_DIR, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}_{rec['policy']}{tag}.json"
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for sh in SHAPES:
                cells.append((arch, sh.name))
    else:
        shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
        archs = [args.arch] if args.arch else ASSIGNED_ARCHS
        for arch in archs:
            for sh in shapes:
                cells.append((arch, sh))

    failures = []
    for arch, sh in cells:
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
        pol = args.policy
        if args.skip_existing:
            cfgp = get_config(arch)
            p = pol or ("full" if shape_by_name(sh).kind != "decode"
                        else default_policy(cfgp))
            f = os.path.join(OUT_DIR, f"{arch}_{sh}_{mesh_name}_{p}{args.tag}.json")
            if os.path.exists(f):
                print(f"[dryrun] skip existing {arch} {sh}")
                continue
        try:
            rec = lower_cell(arch, sh, multi_pod=args.multi_pod,
                             policy=args.policy)
            save(rec, args.tag)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, sh, repr(e)))
            print(f"[dryrun] FAIL {arch} {sh}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nall cells lowered + compiled OK")


if __name__ == "__main__":
    main()
