"""Resource-flow dataflow over the serving layer (DESIGN.md §12).

Rule ids:

  resource-leak   a ``pool.alloc`` / ``pool.acquire`` /
                  ``pool.register_private`` / ``pool.match_prefix`` /
                  ``pool.promote_begin`` call whose result can leave the
                  enclosing function without being released, stored into
                  engine-owned bookkeeping, or returned to the caller
                  (for ``promote_begin`` the staged frame must reach a
                  ``promote_complete`` / ``promote_abort`` path or a
                  copy launched through a ``self.`` method). The pass
                  runs an
                  obligation-based abstract interpretation over each
                  method body: the bound name carries an obligation that
                  must be discharged on every outgoing path.
  lifecycle-edge  every ``transition(...)`` call site outside
                  lifecycle.py must carry a ``# lifecycle: SRC -> DST``
                  annotation; each declared edge is validated against
                  the *imported* lifecycle.ALLOWED table (so the
                  annotation can never drift from the real machine), and
                  a literal ``Status.X`` argument must be inside the
                  declared destination set.
  pool-internals  code outside paged_cache.py reaching into the pool's
                  private state (``pool._free`` etc.) — the auditor's
                  read-only views are the supported surface.

Obligations are discharged by:
  * passing the name to a release op (``pool.release`` / ``pool.free`` /
    ``pool.reclaim_private`` / ``pool.promote_complete`` /
    ``pool.promote_abort`` / ``pool.demote``) or to a method that
    transitively releases its parameter;
  * storing it (or a container holding it) into engine-owned state — any
    assignment/``append``/``extend`` rooted at ``self.``;
  * returning it (ownership moves to the caller);
  * passing it to another ``self.`` method (ownership transfer — callees
    are themselves checked).
``x is None`` / truthiness guards cancel the obligation on the branch
where the acquire failed.
"""
from __future__ import annotations

import ast
import itertools
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, dotted_name
from repro.serving import lifecycle as LC

RULES = ("resource-leak", "lifecycle-edge", "pool-internals")

_ACQUIRE = ("alloc", "acquire", "register_private", "match_prefix",
            "promote_begin")
_RELEASE = ("release", "free", "reclaim_private",
            "promote_complete", "promote_abort", "demote")
_POOL_PRIVATE = ("_free", "_ref", "_index", "_lru", "_by_page",
                 "_children", "_tier", "_frame_of", "_free_frames",
                 "_inflight", "_pinned", "_tier_free", "_pending")


def run(sources: Sequence[Tuple[str, str, ast.Module]],
        rules: Optional[Iterable[str]] = None) -> List[Finding]:
    active = set(rules) if rules is not None else set(RULES)
    out: List[Finding] = []
    for path, src, tree in sources:
        lines = src.splitlines()
        base = path.rsplit("/", 1)[-1]
        if "resource-leak" in active and base != "paged_cache.py":
            out += check_leaks(path, tree)
        if "lifecycle-edge" in active and base != "lifecycle.py":
            out += check_lifecycle_edges(path, lines, tree)
        if "pool-internals" in active and base != "paged_cache.py":
            out += _check_pool_internals(path, tree)
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


# ============================================================ leak check

def _is_pool_call(node: ast.Call, ops: Tuple[str, ...]) -> Optional[str]:
    """'alloc' when node is self.pool.alloc(...) / pool.alloc(...)."""
    name = dotted_name(node.func)
    parts = name.split(".")
    if len(parts) >= 2 and parts[-1] in ops \
            and parts[-2] in ("pool", "_pool"):
        return parts[-1]
    return None


def _releasing_methods(cls: ast.ClassDef) -> Set[str]:
    """Methods that release (one of) their parameters, transitively —
    passing an obligated value to one of these discharges it."""
    methods = {m.name: m for m in cls.body
               if isinstance(m, ast.FunctionDef)}
    releasing: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for name, fn in methods.items():
            if name in releasing:
                continue
            params = {a.arg for a in fn.args.args} - {"self"}
            if not params:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                hits_release = _is_pool_call(node, _RELEASE) is not None
                called = dotted_name(node.func)
                hits_wrapper = (called.startswith("self.")
                                and called.split(".", 1)[1] in releasing)
                if not (hits_release or hits_wrapper):
                    continue
                arg_names = {n.id for a in node.args
                             for n in ast.walk(a)
                             if isinstance(n, ast.Name)}
                if arg_names & params:
                    releasing.add(name)
                    changed = True
                    break
    return releasing


class _LeakScanner:
    """Abstract interpretation of one method: obligations per path."""

    def __init__(self, path: str, fn: ast.FunctionDef,
                 releasing: Set[str]):
        self.path = path
        self.fn = fn
        self.releasing = releasing
        self.findings: List[Finding] = []

    def scan(self) -> List[Finding]:
        open_at_exit = self._block(self.fn.body, {})
        for name, line in open_at_exit.items():
            self._leak(name, line, "falls off the end of")
        return self.findings

    def _leak(self, name: str, line: int, how: str) -> None:
        self.findings.append(Finding(
            "resource-leak", self.path, line,
            f"pages acquired into `{name}` can leak: the obligation "
            f"{how} `{self.fn.name}` without release/store/return",
            func=self.fn.name))

    # obligations: name -> acquire line. A path that executes
    # return/raise must hold no obligations.

    def _block(self, stmts: Iterable[ast.stmt],
               obligations: Dict[str, int]) -> Dict[str, int]:
        obl = dict(obligations)
        for stmt in stmts:
            obl = self._stmt(stmt, obl)
        return obl

    def _stmt(self, stmt: ast.stmt,
              obl: Dict[str, int]) -> Dict[str, int]:
        if isinstance(stmt, ast.Assign):
            self._discharge_in(stmt.value, obl)
            acq = self._acquire_of(stmt.value)
            tgt = stmt.targets[0] if len(stmt.targets) == 1 else None
            if self._is_self_rooted(tgt):
                # storing into engine-owned state discharges everything
                # flowing in (incl. a fresh acquire)
                for name in self._obligated_sources(stmt.value, obl):
                    obl.pop(name, None)
                return obl
            if acq is not None:
                if isinstance(tgt, ast.Name):
                    obl[tgt.id] = stmt.lineno
                elif isinstance(tgt, ast.Tuple) and tgt.elts \
                        and isinstance(tgt.elts[0], ast.Name):
                    # `pages, cov, tail, parent = pool.match_prefix(...)`
                    obl[tgt.elts[0].id] = stmt.lineno
                else:
                    self._leak("<unbound>", stmt.lineno,
                               "is never bound in")
            else:
                # alias tracking: new = got[0] / keys = list(pages)
                src_names = self._obligated_sources(stmt.value, obl)
                if isinstance(tgt, ast.Name):
                    if src_names:
                        # alias/transfer: `new = got[0]` moves the
                        # obligation to the new name
                        obl[tgt.id] = obl.pop(src_names[0])
                    else:
                        obl.pop(tgt.id, None)
            return obl
        if isinstance(stmt, ast.Expr):
            v = stmt.value
            acq = self._acquire_of(v)
            if acq is not None:
                self._leak(f"<{acq} result>", stmt.lineno,
                           "is discarded immediately in")
            # `keys.append(pool.register_private(p))`: the acquire lands
            # in a local container, which now carries the obligation
            if isinstance(v, ast.Call) and isinstance(v.func, ast.Attribute) \
                    and v.func.attr in ("append", "extend", "insert") \
                    and isinstance(v.func.value, ast.Name) \
                    and any(self._acquire_of(a) is not None
                            for a in v.args if isinstance(a, ast.Call)):
                obl[v.func.value.id] = stmt.lineno
                return obl
            self._discharge_in(v, obl)
            return obl
        if isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self._discharge_in(stmt.value, obl)
                for name in self._obligated_sources(stmt.value, obl):
                    obl.pop(name, None)           # ownership to caller
            for name, line in obl.items():
                self._leak(name, stmt.lineno, "reaches a return inside")
            return {}
        if isinstance(stmt, ast.Raise):
            for name, line in obl.items():
                self._leak(name, stmt.lineno, "reaches a raise inside")
            return {}
        if isinstance(stmt, ast.If):
            self._discharge_in(stmt.test, obl)
            then_obl, else_obl = self._guarded(stmt.test, obl)
            out_then = self._block(stmt.body, then_obl)
            out_else = self._block(stmt.orelse, else_obl)
            return self._merge(out_then, out_else)
        if isinstance(stmt, (ast.For, ast.While)):
            if isinstance(stmt, ast.For):
                self._discharge_in(stmt.iter, obl)
            else:
                self._discharge_in(stmt.test, obl)
            body_out = self._block(stmt.body, dict(obl))
            else_out = self._block(stmt.orelse, dict(obl))
            return self._merge(self._merge(body_out, else_out), obl)
        if isinstance(stmt, ast.Try):
            out = self._block(stmt.body, dict(obl))
            for handler in stmt.handlers:
                out = self._merge(out, self._block(handler.body,
                                                   dict(obl)))
            out = self._block(stmt.finalbody, out)
            return out
        if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            if stmt.value is not None:
                self._discharge_in(stmt.value, obl)
            return obl
        if isinstance(stmt, ast.With):
            return self._block(stmt.body, obl)
        return obl

    def _guarded(self, test: ast.expr, obl: Dict[str, int]
                 ) -> Tuple[Dict[str, int], Dict[str, int]]:
        """`if x is None:` — x's acquire failed on the then-branch, so
        its obligation exists only on the else-branch (and dually for
        truthiness / `is not None` tests)."""
        then_obl, else_obl = dict(obl), dict(obl)
        node = test
        negate = False
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
            node, negate = node.operand, True
        if isinstance(node, ast.Compare) and len(node.ops) == 1 \
                and isinstance(node.comparators[0], ast.Constant) \
                and node.comparators[0].value is None \
                and isinstance(node.left, ast.Name):
            none_branch_is_then = isinstance(node.ops[0], ast.Is)
            if negate:
                none_branch_is_then = not none_branch_is_then
            (then_obl if none_branch_is_then else else_obl).pop(
                node.left.id, None)
        elif isinstance(node, ast.Name):
            # `if pages:` — falsy (failed/empty) on the other branch
            (then_obl if negate else else_obl).pop(node.id, None)
        return then_obl, else_obl

    def _merge(self, a: Dict[str, int],
               b: Dict[str, int]) -> Dict[str, int]:
        out = dict(a)
        for k, v in b.items():
            out.setdefault(k, v)
        return out

    def _acquire_of(self, node: ast.expr) -> Optional[str]:
        if isinstance(node, ast.Call):
            op = _is_pool_call(node, _ACQUIRE)
            if op == "reclaim_private":
                return None
            return op
        return None

    def _obligated_sources(self, node: ast.expr,
                           obl: Dict[str, int]) -> List[str]:
        return [n.id for n in ast.walk(node)
                if isinstance(n, ast.Name) and n.id in obl]

    def _is_self_rooted(self, node: Optional[ast.AST]) -> bool:
        while isinstance(node, (ast.Subscript, ast.Attribute)):
            node = node.value
        return isinstance(node, ast.Name) and node.id == "self"

    def _discharge_in(self, expr: ast.expr,
                      obl: Dict[str, int]) -> None:
        """Release calls, stores into self-owned containers, and
        ownership transfers to other self-methods discharge the
        obligations flowing through ``expr``."""
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            discharges = False
            if _is_pool_call(node, _RELEASE) is not None:
                discharges = True
            called = dotted_name(node.func)
            if called.startswith("self."):
                tail = called.split(".")[-1]
                if tail in self.releasing or len(called.split(".")) > 2 \
                        or tail in ("append", "extend", "insert",
                                    "update", "add"):
                    discharges = True
                else:
                    discharges = True   # ownership moves to the callee
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("append", "extend", "insert",
                                           "add", "update"):
                discharges = True       # stored into a local container;
                #                         the container is then tracked
                #                         only if itself obligated
            if discharges:
                for a in itertools.chain(node.args,
                                         (k.value for k in node.keywords)):
                    for name in self._obligated_sources(a, obl):
                        obl.pop(name, None)


def check_leaks(path: str, tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    for cls in tree.body:
        if not isinstance(cls, ast.ClassDef):
            continue
        releasing = _releasing_methods(cls)
        for m in cls.body:
            if isinstance(m, ast.FunctionDef):
                out += _LeakScanner(path, m, releasing).scan()
    # module-level functions holding pool handles
    for fn in tree.body:
        if isinstance(fn, ast.FunctionDef):
            out += _LeakScanner(path, fn, set()).scan()
    return out


# ======================================================== lifecycle edges

_GROUPS = {
    "live": LC._LIVE,
    "terminal": LC.TERMINAL,
    "*": frozenset(LC.Status),
}


def _parse_states(spec: str) -> Optional[frozenset]:
    names = [s.strip() for s in spec.split("|") if s.strip()]
    out: Set[LC.Status] = set()
    for n in names:
        if n in _GROUPS:
            out |= _GROUPS[n]
        else:
            try:
                out.add(LC.Status[n])
            except KeyError:
                return None
    return frozenset(out) if out else None


def _edge_annotation(lines: List[str],
                     lineno: int) -> Optional[Tuple[str, str]]:
    for ln in (lineno, lineno - 1):
        if 0 < ln <= len(lines) and "# lifecycle:" in lines[ln - 1]:
            spec = lines[ln - 1].split("# lifecycle:", 1)[1].strip()
            if "->" in spec:
                src, dst = spec.split("->", 1)
                return src.strip(), dst.strip()
    return None


def check_lifecycle_edges(path: str, lines: List[str],
                          tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name.split(".")[-1] != "transition" \
                or not name.endswith(("LC.transition", "lifecycle."
                                      "transition", "transition")):
            continue
        # only the lifecycle module's transition (imported as LC. /
        # lifecycle. / bare) counts; unrelated .transition methods with
        # a receiver object are skipped
        if "." in name and name.split(".")[-2] not in ("LC", "lifecycle"):
            continue
        ann = _edge_annotation(lines, node.lineno)
        if ann is None:
            out.append(Finding(
                "lifecycle-edge", path, node.lineno,
                "transition() call without a `# lifecycle: SRC -> DST` "
                "annotation"))
            continue
        src_set = _parse_states(ann[0])
        dst_set = _parse_states(ann[1])
        if src_set is None or dst_set is None:
            out.append(Finding(
                "lifecycle-edge", path, node.lineno,
                f"unparseable lifecycle annotation "
                f"`{ann[0]} -> {ann[1]}`"))
            continue
        illegal = sorted(
            f"{s.name}->{t.name}"
            for s in src_set for t in dst_set
            if t not in LC.ALLOWED[s] and s is not t)
        if illegal:
            out.append(Finding(
                "lifecycle-edge", path, node.lineno,
                f"declared edge(s) not in lifecycle.ALLOWED: "
                f"{', '.join(illegal)}"))
        # a literal Status.X argument must live inside the declared DST
        if len(node.args) >= 2:
            tgt = dotted_name(node.args[1])
            if tgt.startswith("Status.") or ".Status." in tgt:
                sname = tgt.split("Status.")[-1]
                try:
                    status = LC.Status[sname]
                except KeyError:
                    status = None
                if status is not None and status not in dst_set:
                    out.append(Finding(
                        "lifecycle-edge", path, node.lineno,
                        f"transition target Status.{sname} outside the "
                        f"declared destination set {ann[1]!r}"))
    return out


# ========================================================= pool internals

def _check_pool_internals(path: str, tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) \
                and node.attr in _POOL_PRIVATE:
            owner = dotted_name(node.value)
            if owner.split(".")[-1] in ("pool", "_pool"):
                out.append(Finding(
                    "pool-internals", path, node.lineno,
                    f"direct access to pool private state "
                    f"`.{node.attr}` — use the pool API / auditor "
                    "views"))
    return out
