"""AST lint rules over src/repro (DESIGN.md §12).

Rule ids:

  host-sync        device->host synchronization (``.item()``,
                   ``jax.device_get``, ``np.asarray(device_value)``,
                   ``int()/float()/bool()`` on device values) inside a
                   serving engine's tick-reachable methods. Intentional,
                   batched syncs are suppressed with a ``# host-sync:
                   <reason>`` annotation on (or above) the line.
  kernel-op        ops that do not lower through Mosaic — or are host
                   calls — inside a Pallas kernel body (``jnp.sort``,
                   ``lax.top_k``, ``np.*``, ``.item()``, ...).
  tracer-branch    Python ``if``/``while`` (or conditional expression)
                   on a traced value inside a jitted function — the
                   classic ConcretizationTypeError, caught statically.
  wall-clock       direct wall-clock or ``random``-module calls in
                   serving/ (engines must take injected clocks/rngs for
                   determinism). ``# wall-clock: <reason>`` suppresses.
  frozen-mut       attribute assignment on frozen-dataclass instances.
  buffer-donation  a jitted cache-updating program (decode_step /
                   prefill_chunk / copy_cache_page) without
                   ``donate_argnums`` — the old cache buffer is dead the
                   moment the call returns, donating it halves peak HBM
                   for the cache update.

The host-sync pass does a small per-class dataflow: attributes assigned
from ``jnp.*``/jitted programs are device-valued, ones assigned from
``np.*`` are host; locals propagate through assignments inside each
tick-reachable method. ``np.asarray`` on a *host* value is fine — only
syncs on device values are findings.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, annotated, dotted_name

RULES = ("host-sync", "kernel-op", "tracer-branch", "wall-clock",
         "frozen-mut", "buffer-donation")

#: prefixes whose call results live on device
_DEVICE_CALL_PREFIXES = ("jnp.", "jax.lax.", "jax.random.", "jax.numpy.",
                         "jax.tree.", "jax.tree_util.")
#: calls that explicitly move device values to host (and are themselves
#: the thing the host-sync rule polices)
_SYNC_CALLS = ("jax.device_get", "jax.block_until_ready")
#: ops that have no Mosaic lowering (or are host-level) — forbidden
#: inside kernel bodies
_KERNEL_DENY = {
    "jnp.einsum", "jnp.sort", "jnp.argsort", "jnp.take",
    "jnp.take_along_axis", "jnp.nonzero", "jnp.unique", "jnp.asarray",
    "jax.lax.top_k", "jax.lax.sort", "jax.device_get",
}
_WALL_CLOCK = {"time.time", "time.monotonic", "time.perf_counter",
               "time.sleep", "datetime.now", "datetime.datetime.now",
               "datetime.utcnow"}
_CACHE_PROGS = ("decode_step", "prefill_chunk", "copy_cache_page")


def run(sources: Sequence[Tuple[str, str, ast.Module]],
        rules: Optional[Iterable[str]] = None) -> List[Finding]:
    active = set(rules) if rules is not None else set(RULES)
    out: List[Finding] = []
    for path, src, tree in sources:
        lines = src.splitlines()
        if "host-sync" in active:
            out += _check_host_sync(path, lines, tree)
        if "kernel-op" in active:
            out += _check_kernel_ops(path, tree)
        if "tracer-branch" in active:
            out += _check_tracer_branch(path, tree)
        if "wall-clock" in active and _in_serving(path):
            out += _check_wall_clock(path, lines, tree)
        if "frozen-mut" in active:
            out += _check_frozen_mut(path, tree)
        if "buffer-donation" in active:
            out += _check_donation(path, tree)
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


def _in_serving(path: str) -> bool:
    return "serving/" in path or path.startswith("serving")


# ===================================================== host-sync dataflow

def _device_functions(tree: ast.Module) -> Set[str]:
    """Module-level functions whose bodies compute on device (any jnp /
    jax.lax / jax.random call) — their results are treated device-valued
    at call sites (e.g. ``sample_next``)."""
    out: Set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    name = dotted_name(sub.func)
                    if name.startswith(_DEVICE_CALL_PREFIXES):
                        out.add(node.name)
                        break
    return out


class _AttrClasses:
    """Per-class attribute classification: device / host / jitted."""

    def __init__(self, cls: ast.ClassDef, device_funcs: Set[str]):
        self.methods: Dict[str, ast.FunctionDef] = {
            m.name: m for m in cls.body
            if isinstance(m, ast.FunctionDef)}
        self.device: Set[str] = set()
        self.host: Set[str] = set()
        self.jitted: Set[str] = set()
        self._device_funcs = device_funcs
        for m in self.methods.values():
            for node in ast.walk(m):
                if not isinstance(node, (ast.Assign, ast.AugAssign)):
                    continue
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    self._classify(tgt, node.value)

    def _classify(self, tgt: ast.AST, value: ast.AST) -> None:
        names = []
        if isinstance(tgt, (ast.Tuple, ast.List)):
            pairs = list(zip(tgt.elts, [value] * len(tgt.elts)))
        else:
            pairs = [(tgt, value)]
        for t, v in pairs:
            if not (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                continue
            names.append(t.attr)
            called = dotted_name(v.func) if isinstance(v, ast.Call) else ""
            if called in ("jax.jit", "functools.partial"):
                self.jitted.add(t.attr)
            elif self._is_device_expr(v):
                self.device.add(t.attr)
            elif called.startswith(("np.", "numpy.")):
                self.host.add(t.attr)
        # device classification wins over host on conflicting assignments
        self.host -= self.device

    def _is_device_expr(self, node: ast.AST) -> bool:
        env = _Env(self, set(), self._device_funcs)
        return env.is_device(node)


class _Env:
    """Device-valuedness of expressions given local device names."""

    def __init__(self, attrs: _AttrClasses, local_device: Set[str],
                 device_funcs: Set[str]):
        self.attrs = attrs
        self.local = local_device
        self.device_funcs = device_funcs

    def is_device(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.local
        if isinstance(node, ast.Attribute):
            if isinstance(node.value, ast.Name) and node.value.id == "self":
                return node.attr in self.attrs.device
            # .at[...].set(...) chains, .astype, .T ... on device values
            return self.is_device(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_device(node.value)
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name in _SYNC_CALLS or name.startswith(("np.", "numpy.")):
                return False                       # result is host
            if name.startswith(_DEVICE_CALL_PREFIXES):
                return True
            if name in self.device_funcs:
                return True
            if name.startswith("self."):
                attr = name.split(".", 1)[1]
                if attr in self.attrs.jitted:
                    return True
            # method call on a device value (x.astype(...), x.at[i].set())
            if isinstance(node.func, ast.Attribute) \
                    and self.is_device(node.func.value):
                return True
            return False
        if isinstance(node, (ast.BinOp,)):
            return self.is_device(node.left) or self.is_device(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_device(node.operand)
        if isinstance(node, ast.Compare):
            return self.is_device(node.left) or any(
                self.is_device(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(self.is_device(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return self.is_device(node.body) or self.is_device(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_device(e) for e in node.elts)
        return False


def _tick_reachable(attrs: _AttrClasses) -> Set[str]:
    """Methods reachable from tick() through self.<m>() calls — the
    engine hot path the host-sync rule polices."""
    seen: Set[str] = set()
    work = ["tick"]
    while work:
        name = work.pop()
        if name in seen or name not in attrs.methods:
            continue
        seen.add(name)
        for node in ast.walk(attrs.methods[name]):
            if isinstance(node, ast.Call):
                called = dotted_name(node.func)
                if called.startswith("self."):
                    work.append(called.split(".", 1)[1])
    return seen


def _check_host_sync(path: str, lines: List[str],
                     tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    device_funcs = _device_functions(tree)
    for cls in tree.body:
        if not isinstance(cls, ast.ClassDef):
            continue
        attrs = _AttrClasses(cls, device_funcs)
        if "tick" not in attrs.methods:
            continue
        for mname in sorted(_tick_reachable(attrs)):
            out += _scan_method_syncs(path, lines, attrs,
                                      attrs.methods[mname], device_funcs)
    return out


def _scan_method_syncs(path: str, lines: List[str], attrs: _AttrClasses,
                       fn: ast.FunctionDef,
                       device_funcs: Set[str]) -> List[Finding]:
    out: List[Finding] = []
    env = _Env(attrs, set(), device_funcs)

    def flag(node: ast.AST, what: str) -> None:
        if not annotated(lines, node.lineno, "host-sync"):
            out.append(Finding("host-sync", path, node.lineno,
                               f"{what} in tick path ({fn.name}); hoist, "
                               "batch, or annotate `# host-sync: <why>`",
                               func=fn.name))

    def visit(node: ast.AST) -> None:
        # track local device names through (sequentially-scanned)
        # assignments before inspecting the expression itself
        if isinstance(node, ast.Assign):
            tgts = node.targets
            dev = env.is_device(node.value)
            for t in tgts:
                for n in ([t] if isinstance(t, ast.Name) else
                          [e for e in getattr(t, "elts", [])
                           if isinstance(e, ast.Name)]):
                    (env.local.add if dev else env.local.discard)(n.id)
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                flag(node, "`.item()` sync")
            elif name in _SYNC_CALLS:
                flag(node, f"`{name}` sync")
            elif name in ("np.asarray", "np.array", "numpy.asarray") \
                    and node.args and env.is_device(node.args[0]):
                flag(node, f"`{name}` on a device value")
            elif name in ("int", "float", "bool") and node.args \
                    and env.is_device(node.args[0]):
                flag(node, f"`{name}()` on a device value")
        for child in ast.iter_child_nodes(node):
            visit(child)

    for stmt in fn.body:
        visit(stmt)
    return out


# ========================================================== kernel bodies

def _kernel_body_functions(tree: ast.Module) -> Set[str]:
    """Functions that execute inside pallas_call: the kernel argument
    (direct name or functools.partial(name, ...), possibly through a
    local alias), plus module-level helpers they call."""
    defs = {n.name for n in tree.body
            if isinstance(n, ast.FunctionDef)}
    roots: Set[str] = set()

    def peel(node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, ast.Call) \
                and dotted_name(node.func).endswith("partial") \
                and node.args:
            return peel(node.args[0])
        return None

    # local aliases: kernel = functools.partial(_kernel, ...)
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            tgt = peel(node.value)
            if tgt in defs:
                aliases[node.targets[0].id] = tgt
        if isinstance(node, ast.Call) \
                and dotted_name(node.func).endswith("pallas_call") \
                and node.args:
            name = peel(node.args[0])
            if name:
                roots.add(aliases.get(name, name))
    # transitive closure over module-level helpers (_score_and_select)
    by_name = {n.name: n for n in tree.body
               if isinstance(n, ast.FunctionDef)}
    seen: Set[str] = set()
    work = [r for r in roots if r in by_name]
    while work:
        name = work.pop()
        if name in seen:
            continue
        seen.add(name)
        for node in ast.walk(by_name[name]):
            if isinstance(node, ast.Call):
                called = dotted_name(node.func)
                if called in by_name and called not in seen:
                    work.append(called)
    return seen


def _check_kernel_ops(path: str, tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    bodies = _kernel_body_functions(tree)
    if not bodies:
        return out
    by_name = {n.name: n for n in tree.body
               if isinstance(n, ast.FunctionDef)}
    for name in sorted(bodies):
        for node in ast.walk(by_name[name]):
            if not isinstance(node, ast.Call):
                continue
            called = dotted_name(node.func)
            bad = ""
            if called in _KERNEL_DENY:
                bad = f"`{called}` does not lower inside a Pallas kernel"
            elif called.startswith(("np.", "numpy.")):
                bad = f"host numpy call `{called}` inside a kernel body"
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                bad = "`.item()` inside a kernel body"
            if bad:
                out.append(Finding("kernel-op", path, node.lineno,
                                   f"{bad} (kernel {name})", func=name))
    return out


# ========================================================= tracer branches

def _is_jitted(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        name = dotted_name(dec.func if isinstance(dec, ast.Call) else dec)
        if name in ("jax.jit", "jit"):
            return True
        if isinstance(dec, ast.Call) and name.endswith("partial") \
                and dec.args and dotted_name(dec.args[0]) in ("jax.jit",
                                                              "jit"):
            return True
    return False


def _has_traced_call(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = dotted_name(sub.func)
            if name.startswith(_DEVICE_CALL_PREFIXES):
                return True
    return False


def _check_tracer_branch(path: str, tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []

    def scan(fn_body: Iterable[ast.AST], fname: str) -> None:
        for stmt in fn_body:
            for node in ast.walk(stmt):
                if isinstance(node, (ast.If, ast.While)) \
                        and _has_traced_call(node.test):
                    out.append(Finding(
                        "tracer-branch", path, node.lineno,
                        "Python branch on a traced value inside jitted "
                        f"`{fname}` — use jnp.where / lax.cond",
                        func=fname))
                if isinstance(node, ast.IfExp) \
                        and _has_traced_call(node.test):
                    out.append(Finding(
                        "tracer-branch", path, node.lineno,
                        "conditional expression on a traced value inside "
                        f"jitted `{fname}`", func=fname))

    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and _is_jitted(node):
            scan(node.body, node.name)
        if isinstance(node, ast.Call) \
                and dotted_name(node.func) in ("jax.jit", "jit"):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Lambda):
                    scan([arg.body], "<lambda>")
    return out


# ============================================================= wall clock

def _check_wall_clock(path: str, lines: List[str],
                      tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        bad = ""
        if name in _WALL_CLOCK:
            bad = f"wall-clock call `{name}` in serving/"
        elif name.startswith("random."):
            bad = f"`{name}` (unseeded python random) in serving/"
        if bad and not annotated(lines, node.lineno, "wall-clock"):
            out.append(Finding(
                "wall-clock", path, node.lineno,
                f"{bad}; inject a clock/rng for determinism"))
    return out


# ===================================================== frozen dataclasses

def _frozen_classes(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) \
                    and dotted_name(dec.func).endswith("dataclass"):
                for kw in dec.keywords:
                    if kw.arg == "frozen" \
                            and isinstance(kw.value, ast.Constant) \
                            and kw.value.value is True:
                        out.add(node.name)
    return out


def _check_frozen_mut(path: str, tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    frozen = _frozen_classes(tree)
    if not frozen:
        return out
    # 1. self.x = ... inside a frozen class's own methods
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef) or cls.name not in frozen:
            continue
        for m in cls.body:
            if not isinstance(m, ast.FunctionDef) \
                    or m.name == "__post_init__":
                continue
            for node in ast.walk(m):
                if isinstance(node, (ast.Assign, ast.AugAssign)):
                    tgts = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for t in tgts:
                        if isinstance(t, ast.Attribute) \
                                and isinstance(t.value, ast.Name) \
                                and t.value.id == "self":
                            out.append(Finding(
                                "frozen-mut", path, node.lineno,
                                f"assignment to self.{t.attr} inside "
                                f"frozen dataclass {cls.name}",
                                func=m.name))
    # 2. x = Frozen(...); x.attr = ... inside any function
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        instances: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Call) \
                    and dotted_name(node.value.func) in frozen:
                instances.add(node.targets[0].id)
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                tgts = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in tgts:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id in instances:
                        out.append(Finding(
                            "frozen-mut", path, node.lineno,
                            f"mutation of frozen-dataclass instance "
                            f"`{t.value.id}.{t.attr}`", func=fn.name))
    return out


# ========================================================== buffer donation

def _check_donation(path: str, tree: ast.Module) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and dotted_name(node.func) in ("jax.jit", "jit")
                and node.args):
            continue
        target = node.args[0]
        body: Optional[ast.AST] = None
        if isinstance(target, ast.Lambda):
            body = target.body
        elif isinstance(target, ast.Call):
            # see through wrappers: jax.jit(wrap("name", lambda ...), ...)
            for arg in target.args:
                if isinstance(arg, ast.Lambda):
                    body = arg.body
                    break
        if body is None:
            continue
        progs = [dotted_name(c.func).rsplit(".", 1)[-1]
                 for c in ast.walk(body) if isinstance(c, ast.Call)]
        updates = [p for p in progs if p in _CACHE_PROGS]
        if not updates:
            continue
        if not any(kw.arg == "donate_argnums" for kw in node.keywords):
            out.append(Finding(
                "buffer-donation", path, node.lineno,
                f"jitted cache-updating program ({', '.join(updates)}) "
                "without donate_argnums — old cache buffer is dead on "
                "return; donate it"))
    return out
