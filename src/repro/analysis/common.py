"""Shared plumbing for the analysis passes: findings, baselines, sources."""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import pathlib
from typing import Dict, Iterable, List, Optional, Set, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one location."""
    rule: str                    # per-rule id, e.g. "host-sync"
    path: str                    # repo-relative posix path
    line: int                    # 1-based
    message: str
    func: str = ""               # enclosing function, for fingerprints

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def fingerprint(self, source_line: str = "") -> str:
        return fingerprint(self.rule, self.path, self.func, source_line)


def fingerprint(rule: str, path: str, func: str, source_line: str) -> str:
    """Line-number-independent identity of a finding: rule + file +
    enclosing function + the offending source text. Survives unrelated
    edits above the finding; changes when the flagged code changes."""
    h = hashlib.sha256(
        "\x1f".join([rule, path, func, source_line.strip()]).encode()
    ).hexdigest()[:16]
    return f"{rule}:{path}:{func}:{h}"


def finding_fingerprints(findings: Iterable[Finding],
                         root: pathlib.Path) -> List[str]:
    """Fingerprints for a batch of findings, reading each source line."""
    cache: Dict[str, List[str]] = {}
    out = []
    for f in findings:
        if f.path not in cache:
            try:
                cache[f.path] = (root / f.path).read_text().splitlines()
            except OSError:
                cache[f.path] = []
        lines = cache[f.path]
        text = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
        out.append(f.fingerprint(text))
    return out


# ------------------------------------------------------------- baseline

def load_baseline(path: pathlib.Path) -> Set[str]:
    """Accepted-finding fingerprints from the committed baseline file.
    Missing file == empty baseline (the desired steady state)."""
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("fingerprints", []))


def save_baseline(path: pathlib.Path, fingerprints: Iterable[str]) -> None:
    path.write_text(json.dumps(
        {"fingerprints": sorted(set(fingerprints))}, indent=2) + "\n")


# ----------------------------------------------------------- source I/O

def repo_root(start: Optional[pathlib.Path] = None) -> pathlib.Path:
    """Nearest ancestor containing pyproject.toml (the analysis anchors
    paths and the baseline there); falls back to the cwd."""
    p = (start or pathlib.Path.cwd()).resolve()
    for cand in (p, *p.parents):
        if (cand / "pyproject.toml").exists():
            return cand
    return p


def iter_sources(paths: Iterable[pathlib.Path],
                 root: pathlib.Path) -> List[Tuple[str, str, ast.Module]]:
    """(relpath, source, tree) for every .py under ``paths``, parsed once.
    Files that fail to parse yield a synthetic parse-error finding via
    the caller (we just skip them here — pytest catches real syntax
    errors long before this pass runs)."""
    seen: Set[pathlib.Path] = set()
    out: List[Tuple[str, str, ast.Module]] = []
    for base in paths:
        base = base.resolve()
        files = [base] if base.is_file() else sorted(base.rglob("*.py"))
        for f in files:
            if f in seen or f.suffix != ".py":
                continue
            seen.add(f)
            try:
                src = f.read_text()
                tree = ast.parse(src)
            except (OSError, SyntaxError):
                continue
            try:
                rel = f.relative_to(root).as_posix()
            except ValueError:
                rel = f.as_posix()
            out.append((rel, src, tree))
    return out


def dotted_name(node: ast.AST) -> str:
    """'jnp.argmax' for Attribute/Name chains, '' for anything dynamic."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def annotated(source_lines: List[str], lineno: int, tag: str) -> bool:
    """True when ``# <tag>: ...`` rides the flagged line or the comment
    block immediately above it — the suppression mechanism for
    intentional violations (e.g. the one batched device->host sync per
    decode tick). The upward walk stops at the first non-comment line,
    so an annotation never leaks past unrelated code."""
    def has_tag(ln: int) -> bool:
        text = source_lines[ln - 1]
        return f"# {tag}:" in text or f"# {tag} :" in text

    if 0 < lineno <= len(source_lines) and has_tag(lineno):
        return True
    ln = lineno - 1
    while 0 < ln <= len(source_lines) \
            and source_lines[ln - 1].lstrip().startswith("#"):
        if has_tag(ln):
            return True
        ln -= 1
    return False
