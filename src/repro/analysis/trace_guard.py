"""Runtime sentinels for the serving hot path (DESIGN.md §12).

Two guards that the static passes cannot prove from source alone:

  TraceGuard   retrace detection. jax re-traces a jitted program whenever
               an argument's shape/dtype (or a closed-over static) drifts
               — in a serving engine that means a silent recompile every
               tick. The guard wraps the *pre-jit* callable (which runs
               exactly once per trace), and after ``seal()`` any further
               trace raises :class:`RetraceError` naming the program.
               Engines accept ``trace_guard=`` and wrap their compiled
               programs; ``rebuild()`` re-arms it across the legitimate
               backend-fallback re-jit.

  sanitize_tables   interpret-mode page-table sanitizer: bounds-checks
               every live slot's page-table row against the physical
               pool before the kernel consumes it — out-of-range
               indices, trash-page (0) entries under a live position,
               and cross-slot aliasing of unshared pages (the
               ``slot_corrupt`` fault class) all surface as
               :class:`PageTableError` *before* the DMA would have read
               a foreign request's cache.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np


class RetraceError(RuntimeError):
    """A sealed jitted program re-traced (shape/dtype drift after
    warm-up) — the decode hot path was about to recompile silently."""


class PageTableError(RuntimeError):
    """A page-table row references physical pages it cannot legally
    read (out of bounds / trash under a live position / foreign slot's
    unshared page)."""


class TraceGuard:
    """Counts traces of wrapped programs; raises after ``seal()``.

    Usage::

        guard = TraceGuard()
        fn = jax.jit(guard.wrap("decode_step", fn))
        ... warm-up ticks ...
        guard.seal()           # from here, any retrace raises
    """

    def __init__(self) -> None:
        self.traces: Dict[str, int] = {}
        self._sealed = False

    def wrap(self, name: str,
             fn: Callable[..., Any]) -> Callable[..., Any]:
        self.traces.setdefault(name, 0)

        def traced(*args: Any, **kwargs: Any) -> Any:
            self.traces[name] = self.traces.get(name, 0) + 1
            if self._sealed:
                raise RetraceError(
                    f"jitted program {name!r} re-traced after seal "
                    f"(trace #{self.traces[name]}): an argument's "
                    "shape/dtype or a closed-over static drifted in "
                    "the hot path")
            return fn(*args, **kwargs)

        return traced

    def seal(self) -> None:
        """Warm-up is over: any further trace is a bug."""
        self._sealed = True

    def rebuild(self) -> None:
        """A legitimate re-jit is happening (backend fallback re-builds
        the engine's programs): re-open the warm-up window."""
        self._sealed = False

    @property
    def sealed(self) -> bool:
        return self._sealed


def sanitize_tables(page_table: Any, pos: Any, live: Any, *,
                    page_size: int, n_pages: int,
                    shared_ok: Optional[Callable[[int], bool]] = None,
                    raise_on_error: bool = True) -> List[str]:
    """Check every live slot's page-table row before a decode step.

    page_table  (n_slots, max_pages) int — logical -> physical pages
    pos         (n_slots,) int — next write position per slot
    live        (n_slots,) bool — slots in the decode batch
    page_size   tokens per page
    n_pages     physical pool size (pages are ids in [0, n_pages))
    shared_ok   predicate: may this physical page legally appear under
                more than one slot (refcount > 1, e.g. prefix-shared)?
                None treats every cross-slot duplicate as corruption.

    Returns the violation strings (empty == clean); raises
    :class:`PageTableError` with all of them when ``raise_on_error``.
    """
    table = np.asarray(page_table)
    pos_np = np.asarray(pos).astype(np.int64)
    live_np = np.asarray(live).astype(bool)
    problems: List[str] = []
    holders: Dict[int, int] = {}
    for slot in range(table.shape[0]):
        if not live_np[slot]:
            continue
        used = int(-(-int(pos_np[slot] + 1) // page_size))
        row = table[slot]
        bad = np.flatnonzero((row < 0) | (row >= n_pages))
        for i in bad:
            problems.append(
                f"slot {slot}: table[{int(i)}]={int(row[i])} outside "
                f"physical pool [0, {n_pages})")
        for i in range(min(used, row.shape[0])):
            p = int(row[i])
            if p == 0:
                problems.append(
                    f"slot {slot}: live logical page {i} (pos "
                    f"{int(pos_np[slot])}) points at the trash page")
                continue
            if not 0 < p < n_pages:
                continue            # already reported above
            prev = holders.get(p)
            if prev is not None and prev != slot \
                    and not (shared_ok(p) if shared_ok else False):
                problems.append(
                    f"page {p} aliased by slots {prev} and {slot} "
                    "without a shared refcount (slot_corrupt class)")
            holders[p] = slot
    if problems and raise_on_error:
        raise PageTableError("; ".join(problems))
    return problems


def pool_shared_ok(pool: Any) -> Callable[[int], bool]:
    """Adapter: a PagePool's refcount>1 / registered pages may legally
    appear under several slots."""
    def ok(page: int) -> bool:
        try:
            return bool(pool.refcount(page) > 1
                        or pool.is_registered(page))
        except Exception:        # noqa: BLE001 — sanitizer must not throw here
            return False
    return ok
