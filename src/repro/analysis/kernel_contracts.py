"""Static kernel-contract checking (DESIGN.md §12).

Abstract-evals every registered Pallas entry point (kernels/registry.py)
across the tuning-table plan matrix (kernels/tuning.py TUNED) × every
supported PageLayout dtype (configs/base.py LAYOUT_ITEMSIZE, incl. the
int8/fp8 quantized layouts) × stored-key widths (full D and the rank-D/2
latent basis), without compiling or running anything:

  contract-divisibility  S % block_size, page_size % block_size
  contract-sublane       block_size versus the dtype's sublane granule
                         (f32 8, bf16/fp16 16, int8/fp8 32)
  contract-lane          every staged width (d, kdim, D) packs the
                         128-lane tile deterministically (divides or is
                         a multiple of 128)
  contract-vmem          the plan's per-grid-step VMEM footprint
                         (KernelPlan.vmem_bytes — padded tiles, matching
                         the kernel's scratch_shapes) within VMEM_BUDGET
  contract-eval          jax.eval_shape through the real pallas_call:
                         shape/dtype mismatches, BlockSpec
                         inconsistencies and bad scratch shapes surface
                         here with zero device work
  contract-prefetch      the entry point's source really routes its
                         declared scalar-prefetch operands through
                         PrefetchScalarGridSpec, and declared scale
                         sidecars through SMEM BlockSpecs

``jax.eval_shape`` traces the pallas_call abstractly, so a 512k-token
plan costs the same to check as a 4k one.
"""
from __future__ import annotations

import ast
import functools
import inspect
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.common import Finding
from repro.kernels import registry, tuning

#: PageLayout dtype name -> jnp dtype (mirrors configs/base.py)
LAYOUT_DTYPES: Dict[str, Any] = {
    "fp32": jnp.float32,
    "fp16": jnp.float16,
    "bf16": jnp.bfloat16,
    "int8": jnp.int8,
    "fp8": jnp.float8_e4m3fn,
}
QUANT = ("int8", "fp8")
#: score width fraction (LokiConfig.d_f default) and selection cap used
#: for the abstract sweep — k_blocks only sizes a tiny SMEM row, so a
#: small representative value keeps tracing fast without weakening the
#:  contract
D_F = 0.25
K_BLOCKS_CAP = 8


def _sds(shape: Tuple[int, ...], dtype: Any) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _eval(fn: Callable[..., Any], *args: Any, **kwargs: Any) -> Any:
    """eval_shape with kwargs split the way the kernels expect them:
    array operands (ShapeDtypeStructs) must be *traced* — binding them
    in the partial would hand the kernel a bare struct — while ints and
    flags are compile-time statics and must stay out of the trace."""
    static = {k: v for k, v in kwargs.items()
              if not isinstance(v, jax.ShapeDtypeStruct)}
    traced = {k: v for k, v in kwargs.items()
              if isinstance(v, jax.ShapeDtypeStruct)}
    return jax.eval_shape(functools.partial(fn, **static), *args, **traced)


def check_all(budget: int = tuning.VMEM_BUDGET) -> List[Finding]:
    """Sweep TUNED × PageLayout dtypes × key widths. Every returned
    Finding points at kernels/tuning.py (the plan is the contract)."""
    entries = registry.load_all()
    out: List[Finding] = []
    out += _check_declarations(entries)
    path = "src/repro/kernels/tuning.py"
    for key, (variant, bs) in sorted(tuning.TUNED.items()):
        smax, dim, g, bs_hint = key
        for dtype_name, dtype in LAYOUT_DTYPES.items():
            itemsize = jnp.dtype(dtype).itemsize
            for kdim in dict.fromkeys((dim, max(dim // 2, 1))):
                out += _check_cell(
                    path, entries, smax=smax, dim=dim, g=g,
                    bs_hint=bs_hint, variant=variant, bs=bs, kdim=kdim,
                    dtype_name=dtype_name, dtype=dtype,
                    itemsize=itemsize, budget=budget)
    return out


def _check_cell(path: str, entries: Dict[str, registry.KernelEntry], *,
                smax: int, dim: int, g: int, bs_hint: int, variant: str,
                bs: int, kdim: int, dtype_name: str, dtype: Any,
                itemsize: int, budget: int) -> List[Finding]:
    out: List[Finding] = []
    cell = (f"plan ({smax}, {dim}, {g}, {bs_hint})={variant}/{bs} "
            f"dtype={dtype_name} kdim={kdim}")
    plan = tuning.KernelPlan(variant, bs)
    d = max(min(int(D_F * dim), kdim), 8)

    if smax % bs:
        out.append(Finding("contract-divisibility", path, 1,
                           f"{cell}: S={smax} not divisible by "
                           f"block_size={bs}"))
        return out
    sub = tuning.SUBLANE.get(itemsize, 8)
    if bs % sub:
        out.append(Finding(
            "contract-sublane", path, 1,
            f"{cell}: block_size={bs} not a multiple of the {dtype_name} "
            f"sublane granule {sub}"))
    for wname, w in (("d", d), ("kdim", kdim), ("dim", dim)):
        if w % tuning.LANE and tuning.LANE % w:
            out.append(Finding(
                "contract-lane", path, 1,
                f"{cell}: staged width {wname}={w} neither divides nor "
                f"is a multiple of the {tuning.LANE}-lane tile"))
    vmem = plan.vmem_bytes(smax=smax, d=d, kdim=kdim, dim=dim, g=g,
                           itemsize=itemsize)
    if vmem > budget:
        out.append(Finding(
            "contract-vmem", path, 1,
            f"{cell}: per-grid-step VMEM footprint {vmem} bytes exceeds "
            f"budget {budget}"))
    if out:
        return out          # geometry is broken: eval would just re-raise

    # geometry holds — abstract-eval the registered entry points with the
    # serving-shaped operands this plan would actually see. Pages default
    # to the config-hint size when the plan's blocks tile it, else to one
    # block per page (the runtime falls back identically).
    ps = bs_hint if bs_hint % bs == 0 else bs
    quant = dtype_name in QUANT
    nb = smax // bs
    kb = min(max(int(0.25 * nb), 1), K_BLOCKS_CAP)
    n_pages = smax // ps + 1
    rows = n_pages * ps
    q = _sds((1, 1, g, kdim), jnp.float32)
    k_pool = _sds((rows, 1, kdim), dtype)
    v_pool = _sds((rows, 1, dim), dtype)
    cur = _sds((1,), jnp.int32)
    table = _sds((1, smax // ps), jnp.int32)
    scales: Dict[str, Any] = {}
    if quant:
        scales = {"k_scale": _sds((n_pages,), jnp.float32),
                  "v_scale": _sds((n_pages,), jnp.float32)}

    def expect(name: str, fn: Callable[[], Any],
               shape: Tuple[int, ...]) -> None:
        try:
            got = fn()
        except Exception as e:  # noqa: BLE001 — every trace error is a finding
            out.append(Finding(
                "contract-eval", path, 1,
                f"{cell}: {name} failed abstract eval: {type(e).__name__}: "
                f"{e}"))
            return
        if tuple(got.shape) != shape:
            out.append(Finding(
                "contract-eval", path, 1,
                f"{cell}: {name} output shape {tuple(got.shape)} != "
                f"declared {shape}"))

    if "fused_loki_decode" in entries:
        fused = entries["fused_loki_decode"].fn
        expect("fused_loki_decode(paged)",
               lambda: _eval(fused, q, k_pool, v_pool, cur,
                             d=d, k_blocks=kb, block_size=bs,
                             page_table=table, page_size=ps, **scales),
               (1, 1, g, dim))
    if "select_blocks" in entries:
        sel_fn = entries["select_blocks"].fn
        ksc = {"k_scale": scales["k_scale"]} if quant else {}
        expect("select_blocks(paged)",
               lambda: _eval(sel_fn, q, k_pool, cur, d=d, k_blocks=kb,
                             block_size=bs, page_table=table,
                             page_size=ps, **ksc),
               (1, 1, kb))
    if "block_sparse_attention_grouped" in entries:
        gfn = entries["block_sparse_attention_grouped"].fn
        idx = _sds((1, 1, kb), jnp.int32)
        expect("block_sparse_attention_grouped(paged)",
               lambda: _eval(gfn, q, k_pool, v_pool, idx, cur,
                             block_size=bs, page_table=table,
                             page_size=ps, **scales),
               (1, 1, g, dim))
    if "paged_full_decode" in entries:
        ffn = entries["paged_full_decode"].fn
        expect("paged_full_decode(paged)",
               lambda: _eval(ffn, q, k_pool, v_pool, cur,
                             block_size=bs, page_table=table,
                             page_size=ps, **scales),
               (1, 1, g, dim))
    if "fused_exact_topk_decode" in entries:
        efn = entries["fused_exact_topk_decode"].fn
        expect("fused_exact_topk_decode(paged)",
               lambda: _eval(efn, q, k_pool, v_pool, cur, k_blocks=kb,
                             block_size=bs, page_table=table,
                             page_size=ps, **scales),
               (1, 1, g, dim))

    # contiguous-cache entry points carry no page/scale contract — one
    # representative eval per (plan, dtype) at full key width suffices
    if kdim != dim:
        return out
    bh = g
    q2 = _sds((bh, dim), jnp.float32)
    k2 = _sds((bh, smax, dim), dtype)
    v2 = _sds((bh, smax, dim), dtype)
    cur2 = _sds((bh,), jnp.int32)
    if "block_max_scores" in entries:
        expect("block_max_scores",
               lambda: _eval(entries["block_max_scores"].fn, q2, k2, cur2,
                             d=d, block_size=bs),
               (bh, nb))
    if "block_max_scores_fm" in entries:
        kT = _sds((bh, dim, smax), dtype)
        expect("block_max_scores_fm",
               lambda: _eval(entries["block_max_scores_fm"].fn, q2, kT,
                             cur2, d=d, block_size=bs),
               (bh, nb))
    if "block_sparse_attention" in entries:
        idx2 = _sds((bh, kb), jnp.int32)
        expect("block_sparse_attention",
               lambda: _eval(entries["block_sparse_attention"].fn,
                             q2, k2, v2, idx2, cur2, block_size=bs),
               (bh, dim))
    if "flash_attention" in entries:
        sq = min(smax, 4 * bs)
        q3 = _sds((bh, sq, dim), jnp.float32)
        kv3 = _sds((bh, sq, dim), dtype)
        expect("flash_attention",
               lambda: _eval(entries["flash_attention"].fn, q3, kv3, kv3,
                             block_q=bs, block_k=bs),
               (bh, sq, dim))
    return out


# ------------------------------------------------ declaration cross-check

def _check_declarations(
        entries: Dict[str, registry.KernelEntry]) -> List[Finding]:
    """The registry contract must match what the source actually builds:
    declared scalar-prefetch operands imply a PrefetchScalarGridSpec,
    declared scale sidecars imply SMEM BlockSpecs — and vice versa."""
    out: List[Finding] = []
    for name, entry in sorted(entries.items()):
        try:
            src = inspect.getsource(entry.fn)
            tree = ast.parse(src)
        except (OSError, TypeError, SyntaxError):
            continue
        path = f"src/{entry.contract.module.replace('.', '/')}.py"
        line = entry.fn.__code__.co_firstlineno
        names = {n.attr if isinstance(n, ast.Attribute) else n.id
                 for n in ast.walk(tree)
                 if isinstance(n, (ast.Attribute, ast.Name))}
        uses_prefetch = "PrefetchScalarGridSpec" in names
        uses_smem = "SMEM" in names
        c = entry.contract
        if c.uses_prefetch_grid and not uses_prefetch:
            out.append(Finding(
                "contract-prefetch", path, line,
                f"{name} declares scalar_prefetch={c.scalar_prefetch} "
                "but never builds a PrefetchScalarGridSpec"))
        if not c.uses_prefetch_grid and uses_prefetch:
            out.append(Finding(
                "contract-prefetch", path, line,
                f"{name} builds a PrefetchScalarGridSpec but declares no "
                "scalar_prefetch operands"))
        if c.smem_sidecars and not uses_smem:
            out.append(Finding(
                "contract-prefetch", path, line,
                f"{name} declares SMEM sidecars {c.smem_sidecars} but "
                "never places an operand in SMEM"))
        if c.paged_operand and c.paged_operand not in c.scalar_prefetch:
            out.append(Finding(
                "contract-prefetch", path, line,
                f"{name}: paged operand {c.paged_operand!r} must ride "
                "scalar prefetch (page tables are grid-visible)"))
    return out
