"""Static contract checking for the repro codebase (DESIGN.md §12).

Four passes, one CLI (``python -m repro.analysis``):

  lint              AST rules over src/repro: host syncs in the serving
                    hot path, forbidden ops in Pallas kernel bodies,
                    tracer-valued Python branches in jitted code,
                    wall-clock/random in serving/, frozen-dataclass
                    mutation, missing buffer donation
  kernel-contracts  abstract-eval of every registered kernel entry point
                    across the tuning-table plans × PageLayout dtypes
  resource-flow     alloc/acquire ↔ release pairing on all paths through
                    the scheduler, and lifecycle-edge legality at every
                    transition() call site
  trace-guard       runtime sentinels (retrace detection, page-table
                    sanitizer) used by tests/engines, not the CLI

Findings carry per-rule ids and file:line locations; a committed baseline
(analysis_baseline.json) holds accepted findings, and ``--strict`` fails
on anything unbaselined.
"""
from repro.analysis.common import Finding, load_baseline, fingerprint

__all__ = ["Finding", "load_baseline", "fingerprint"]
