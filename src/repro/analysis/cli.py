"""``python -m repro.analysis`` — run the static passes over the repo.

Exit codes: 0 clean (or all findings baselined / non-strict), 1 at least
one unbaselined finding under ``--strict``, 2 usage error.

Baseline workflow: findings are identified by line-number-independent
fingerprints (rule + file + function + offending source text). A
committed ``analysis_baseline.json`` at the repo root lists accepted
fingerprints; ``--update-baseline`` rewrites it from the current run.
The steady state of this repo is an *empty* baseline.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from typing import List, Optional, Sequence

from repro.analysis import kernel_contracts, lint, resource_flow
from repro.analysis.common import (Finding, finding_fingerprints,
                                   iter_sources, load_baseline, repo_root,
                                   save_baseline)

CONTRACT_RULES = ("contract-divisibility", "contract-sublane",
                  "contract-lane", "contract-vmem", "contract-eval",
                  "contract-prefetch")
ALL_RULES = tuple(lint.RULES) + tuple(resource_flow.RULES) + CONTRACT_RULES


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static contract checks: serving hot-path lint, "
                    "Pallas kernel contracts, resource flow.")
    p.add_argument("paths", nargs="*", type=pathlib.Path,
                   help="files or directories to analyse "
                        "(default: src/repro under the repo root)")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on any unbaselined finding")
    p.add_argument("--baseline", type=pathlib.Path, default=None,
                   help="baseline file (default: "
                        "<repo>/analysis_baseline.json)")
    p.add_argument("--update-baseline", action="store_true",
                   help="rewrite the baseline from this run's findings")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print every rule id and exit")
    p.add_argument("--no-contracts", action="store_true",
                   help="skip the kernel-contract sweep (needs jax; the "
                        "AST passes do not)")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for r in ALL_RULES:
            print(r)
        return 0

    rules: Optional[Sequence[str]] = None
    if args.rules:
        rules = tuple(r.strip() for r in args.rules.split(",") if r.strip())
        unknown = [r for r in rules if r not in ALL_RULES]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)}", file=sys.stderr)
            return 2

    root = repo_root()
    paths = list(args.paths) or [root / "src" / "repro"]
    sources = iter_sources(paths, root)

    findings: List[Finding] = []
    findings += lint.run(sources, rules=rules)
    findings += resource_flow.run(sources, rules=rules)
    want_contracts = (not args.no_contracts and
                      (rules is None or any(r in CONTRACT_RULES
                                            for r in rules)))
    if want_contracts:
        contract = kernel_contracts.check_all()
        if rules is not None:
            contract = [f for f in contract if f.rule in rules]
        findings += contract

    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    prints = finding_fingerprints(findings, root)

    baseline_path = args.baseline or (root / "analysis_baseline.json")
    if args.update_baseline:
        save_baseline(baseline_path, prints)
        print(f"baseline updated: {len(prints)} finding(s) -> "
              f"{baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    fresh = [(f, fp) for f, fp in zip(findings, prints)
             if fp not in baseline]
    for f, _ in fresh:
        print(f.format())
    n_base = len(findings) - len(fresh)
    if findings or baseline:
        print(f"{len(fresh)} finding(s) ({n_base} baselined, "
              f"{len(baseline)} baseline entries)")
    else:
        print("clean: no findings, empty baseline")
    if args.strict and fresh:
        return 1
    return 0


if __name__ == "__main__":      # pragma: no cover — exercised via __main__
    raise SystemExit(main())
