"""Fault-tolerant checkpointing.

Design goals (1000+ node deployments):
  * atomic    — write to tmp dir, fsync, rename; a crash mid-save never
                corrupts the latest checkpoint
  * async     — serialization happens on a background thread; the train loop
                only blocks if a previous save is still in flight
  * checksummed — every array file carries a crc; restore skips corrupt or
                partial checkpoints and falls back to the previous one
  * mesh-agnostic — arrays are saved as full logical arrays (np), so a
                restart may use a different device count / mesh shape
                (elastic scaling); resharding happens at load via
                device_put with the new sharding
  * keep-N    — bounded disk usage
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def _unflatten_into(tree_like, arrays: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing array {key!r}")
        a = arrays[key]
        leaves.append(a.astype(leaf.dtype) if hasattr(leaf, "dtype") else a)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save

    def save(self, step: int, tree, *, blocking: bool = False,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()                      # one in-flight save at a time
        arrays = _flatten(jax.device_get(tree))
        meta = {"step": step, "time": time.time(), "extra": extra or {},
                "arrays": {}}

        def work():
            try:
                tmp = os.path.join(self.dir, f".tmp_step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                for key, arr in arrays:
                    fn = key.replace("/", "__") + ".npy"
                    path = os.path.join(tmp, fn)
                    np.save(path, arr)
                    with open(path, "rb") as f:
                        crc = zlib.crc32(f.read())
                    meta["arrays"][key] = {"file": fn, "crc": crc,
                                           "shape": list(arr.shape),
                                           "dtype": str(arr.dtype)}
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                    f.flush()
                    os.fsync(f.fileno())
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self.wait()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def _load_dir(self, step: int) -> Optional[Dict[str, np.ndarray]]:
        d = os.path.join(self.dir, f"step_{step}")
        try:
            with open(os.path.join(d, "meta.json")) as f:
                meta = json.load(f)
            arrays = {}
            for key, info in meta["arrays"].items():
                path = os.path.join(d, info["file"])
                with open(path, "rb") as f:
                    raw = f.read()
                if zlib.crc32(raw) != info["crc"]:
                    raise IOError(f"crc mismatch for {key} at step {step}")
                import io
                arrays[key] = np.load(io.BytesIO(raw))
            return arrays
        except Exception:
            return None

    def restore_latest(self, tree_like, *, shardings=None
                       ) -> Tuple[Optional[int], Any]:
        """Restore the newest intact checkpoint; corrupt ones are skipped.

        ``shardings``: optional pytree of NamedSharding for elastic reload
        onto a (possibly different) mesh."""
        for step in reversed(self.steps()):
            arrays = self._load_dir(step)
            if arrays is None:
                continue
            tree = _unflatten_into(tree_like, arrays)
            if shardings is not None:
                tree = jax.tree.map(
                    lambda a, s: jax.device_put(a, s), tree, shardings)
            return step, tree
        return None, tree_like
