"""Primitive layers: norms, MLP variants, embeddings, RoPE, initializers.

Pure-functional style: ``init_*`` builds a param dict, ``*_apply`` consumes it.
All matmuls go through ``dot`` which casts to the compute dtype and constrains
logical sharding axes on the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import constrain


def dot(x, w, prec=None):
    return jnp.matmul(x, w, precision=prec)


def _init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------- norms

def init_norm(cfg, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if "bias" in p:  # LayerNorm
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:            # RMSNorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dt)


# ---------------------------------------------------------------- MLPs

def init_mlp(key, cfg, d_ff=None):
    """Gated (swiglu/geglu) or plain (sq_relu/gelu) MLP params."""
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    ks = jax.random.split(key, 3)
    gated = cfg.mlp in ("swiglu", "geglu")
    p = {
        "w_in": _init(ks[0], (d, 2 * f if gated else f)),
        "w_out": _init(ks[1], (f, d)),
    }
    return p


def mlp_apply(p, x, cfg):
    f = p["w_out"].shape[0]
    ax = ("batch", "seq", "mlp") if x.ndim == 3 else ("batch", "mlp")
    h = dot(x, p["w_in"].astype(x.dtype))
    h = constrain(h, ax)
    if cfg.mlp in ("swiglu", "geglu"):
        gate, up = h[..., :f], h[..., f:]
        act = jax.nn.silu(gate) if cfg.mlp == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    elif cfg.mlp == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    out = dot(h, p["w_out"].astype(x.dtype))
    return constrain(out, ("batch", "seq", "act_embed") if x.ndim == 3
                     else ("batch", "act_embed"))


# logical axes of MLP params (used by the sharding rule engine)
def mlp_axes():
    return {"w_in": ("embed", "mlp"), "w_out": ("mlp", "embed")}


# ---------------------------------------------------------------- embeddings

def init_embed(key, cfg):
    # 1/sqrt(d) keeps tied-unembed logits O(1) at init (xent starts at ln V)
    return {"table": _init(key, (cfg.vocab, cfg.d_model),
                           scale=cfg.d_model ** -0.5)}


def embed_apply(p, tokens, cfg):
    out = jnp.take(p["table"].astype(jnp.dtype(cfg.dtype)), tokens, axis=0)
    return constrain(out, ("batch", "seq", "act_embed"))


def unembed_apply(p, x, cfg):
    # matmul in the activation dtype, accumulate in fp32 (loss stability
    # without materializing an fp32 copy of the vocab table every step)
    logits = jnp.matmul(x, p["table"].T.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return constrain(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., S, H, D). positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                              # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- losses

def softmax_xent(logits, labels, z_loss=0.0, mask=None):
    """logits (B,S,V) fp32, labels (B,S) int32. Returns mean loss."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is not None:
        loss = loss * mask
        return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)
