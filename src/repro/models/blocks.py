"""Transformer / SSM blocks: init + train-forward + decode-step for each
block family. All blocks share a uniform interface so the LM can lax.scan
over stacked per-layer params:

  init_block(key, cfg)                        -> params (one layer)
  block_train(p, x, positions, cfg)           -> (y, aux_loss)
  block_decode(p, cache, x, pos_len, cfg)     -> (y, new_cache)
  init_cache(cfg, batch, smax, dtype)         -> per-layer cache pytree

``pos_len`` is the number of tokens already in the cache (B,) — the new token
lands at that index and RoPE uses it as the position.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import attention as A
from repro.core import baselines, dispatch, loki
from repro.models import layers as L
from repro.sharding.rules import constrain


# =====================================================================
# Attention block
# =====================================================================

def init_attention(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": L._init(ks[0], (d, cfg.q_dim)),
        "wk": L._init(ks[1], (d, cfg.kv_dim)),
        "wv": L._init(ks[2], (d, cfg.kv_dim)),
        "wo": L._init(ks[3], (cfg.q_dim, d)),
        # PCA basis per kv head (identity until calibrated). Held in params so
        # it checkpoints/shards like everything else; excluded from the
        # optimizer by name (see optim.adamw).
        "pca": jnp.broadcast_to(jnp.eye(hd, dtype=jnp.float32),
                                (cfg.n_kv_heads, hd, hd)).copy(),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
    return p


def _qkv(p, x, cfg: ModelConfig):
    """x (B,S,E) -> q (B,S,H,D), k/v (B,S,Hkv,D)."""
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = L.dot(x, p["wq"].astype(dt))
    k = L.dot(x, p["wk"].astype(dt))
    v = L.dot(x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    b, s = x.shape[:2]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def attn_train(p, x, positions, cfg: ModelConfig, *, capture=None):
    """Full causal attention (train / perplexity eval).

    ``capture``: optional dict that receives pre/post-rotary keys for PCA
    calibration runs."""
    q, k, v = _qkv(p, x, cfg)
    if capture is not None:
        capture["pre"] = k
    if cfg.rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    if capture is not None:
        capture["post"] = k
        capture["q"] = q
    out = A.causal_attention(q, k, v, causal=True,
                             sliding_window=cfg.sliding_window)
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.q_dim)
    return L.dot(out, p["wo"].astype(x.dtype))


def encoder_attn_train(p, x, positions, cfg: ModelConfig):
    q, k, v = _qkv(p, x, cfg)
    out = A.causal_attention(q, k, v, causal=False)
    b, s = x.shape[:2]
    return L.dot(out.reshape(b, s, cfg.q_dim), p["wo"].astype(x.dtype))


def init_attn_cache(cfg: ModelConfig, batch: int, smax: int, dtype):
    hd = cfg.resolved_head_dim
    pol = cfg.loki
    if cfg.attn_policy() == "pcaattn":
        d = max(int(pol.d_f * hd), 8)
        k_shape = (batch, smax, cfg.n_kv_heads, d)
    elif cfg.attn_policy() == "h2o":
        budget = loki.static_k(pol, smax)
        st = baselines.h2o_init(batch, budget, cfg.n_kv_heads, hd, dtype)
        return {"k": st.k, "v": st.v, "pos": st.pos, "acc": st.acc,
                "fill": st.fill}
    else:
        k_shape = (batch, smax, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(k_shape, dtype),
        "v": jnp.zeros((batch, smax, cfg.n_kv_heads, hd), dtype),
    }


_UINT_OF = {2: jnp.uint16, 4: jnp.uint32, 1: jnp.uint8}


def _write_cache(cache_arr, new, pos_len):
    """Insert new (B,Hkv,D) rows at per-slot positions pos_len (B,).

    The vmapped DUS lowers to a scatter. Backends without a native
    low-precision scatter (XLA:CPU legalizes bf16 scatter via f32) would
    otherwise rewrite the whole buffer with converts every step (§Perf L3),
    so we scatter the raw bit pattern as an unsigned int — a free bitcast on
    TPU, and in-place everywhere."""
    b = new.shape[0]
    dt = cache_arr.dtype
    uint = _UINT_OF.get(jnp.dtype(dt).itemsize) if jnp.issubdtype(
        dt, jnp.floating) else None
    c_view = jax.lax.bitcast_convert_type(cache_arr, uint) if uint \
        else cache_arr
    n_view = jax.lax.bitcast_convert_type(new.astype(dt), uint) if uint \
        else new.astype(dt)

    def one(c, n, i):
        return jax.lax.dynamic_update_slice_in_dim(c, n[None], i, axis=0)

    out = jax.vmap(one)(c_view, n_view,
                        jnp.broadcast_to(pos_len, (b,)).astype(jnp.int32))
    return jax.lax.bitcast_convert_type(out, dt) if uint else out


def attn_decode(p, cache, x, pos_len, cfg: ModelConfig, *,
                page_table=None, page_size: int = 0, frame_table=None,
                rank=None, sliding_window=None):
    """One-token decode with the configured attention policy.

    x (B,E); pos_len (B,) tokens already cached. Returns (y (B,E), cache).

    ``sliding_window`` (static): this layer's attention window, overriding
    the config-global ``cfg.sliding_window`` — models mixing SWA and
    full-attention layers (``cfg.window_layers``) pass each layer's own
    window through the unrolled decode path (0 = full attention).

    With ``page_table (B, max_pages)``/``page_size`` the cache arrays are
    the serving engine's shared page pools (R,Hkv,D): the new token's K/V
    scatter through the table to their physical rows, and reads either
    gather the logical per-slot view (jnp policies) or hand the pool plus
    table straight to the paged Pallas kernels (loki_block).

    ``frame_table (B, max_pages)`` (tiered pools, DESIGN.md §13): K/V rows
    live at device *frames* while the always-resident ``k_lat`` sidecar is
    indexed by logical page. The approximate score pass reads only the
    sidecar; exact attention gathers winner rows through the frame table
    (HOST pages resolve to the trash frame — finite garbage masked to an
    exact zero by the selection validity mask). Returns (y, cache,
    winners) where ``winners (B, max_pages)`` flags logical pages the
    selection attended.

    ``rank`` (traced scalar): this layer's latent-K rank under per-layer
    ``cfg.page_ranks`` — tail columns of the stored keys are zero-masked,
    which is self-consistent truncation (zeroed dims contribute nothing
    to q̂·k̂)."""
    hd = cfg.resolved_head_dim
    b = x.shape[0]
    q, k, v = _qkv(p, x[:, None, :], cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]          # (B,H,D)/(B,Hkv,D)
    positions = jnp.broadcast_to(pos_len, (b,))
    if cfg.rope:
        q = L.apply_rope(q[:, None], positions[:, None],
                         cfg.rope_theta)[:, 0]
        k = L.apply_rope(k[:, None], positions[:, None],
                         cfg.rope_theta)[:, 0]

    policy = cfg.attn_policy()
    proj = p["pca"]
    cur_len = positions + 1                       # cache incl. new token
    paged = page_table is not None
    sw = cfg.sliding_window if sliding_window is None else sliding_window

    if policy == "h2o":
        if paged:
            raise ValueError("h2o keeps its own budgeted cache; "
                             "serve it through the dense engine")
        st = baselines.H2OState(cache["k"], cache["v"], cache["pos"],
                                cache["acc"], cache["fill"])
        out, st = baselines.h2o_decode(q, k, v, st, positions)
        new_cache = {"k": st.k, "v": st.v, "pos": st.pos, "acc": st.acc,
                     "fill": st.fill}
        y = L.dot(out.reshape(b, cfg.q_dim), p["wo"].astype(x.dtype))
        return y, new_cache

    lay = cfg.page_layout
    if policy in ("loki", "loki_block"):
        # cache keys live in the PCA basis (paper line 3-4)
        _, k_store = loki.project_qk(q, k, proj)
    elif policy == "pcaattn":
        d = cache["k"].shape[-1]
        k_store = jnp.einsum("bhd,hde->bhe", k, proj[..., :d].astype(k.dtype))
    elif paged and lay.basis == "pca":
        # latent-basis pages for non-Loki policies: store k̂ = k·P, rotate
        # q at read time — exact at full rank (Lemma 4.1), back-projection
        # folds into the epilogue (softmax weights are basis-free)
        k_store = jnp.einsum("bhd,hde->bhe", k, proj.astype(k.dtype))
    else:
        k_store = k
    if paged:
        from repro.serving import paged_cache as PC
        # the pool's allocated width is authoritative: per-layer ranks
        # stack every layer at the max width (narrower layers zero-mask)
        kw = cache["k"].shape[-1]
        if kw < k_store.shape[-1] and policy != "pcaattn":
            k_store = k_store[..., :kw]           # latent rank-r truncation
        if rank is not None and policy != "pcaattn":
            k_store = k_store * (jnp.arange(kw) < rank).astype(k_store.dtype)
        if frame_table is not None:
            if policy not in ("loki", "loki_block"):
                raise ValueError("tiered pools serve Loki policies only "
                                 f"(got {policy!r})")
            if cfg.loki.n_chunks:
                raise ValueError("tiered pools do not support chunked "
                                 "(distributed) Loki selection")
            dl = cache["k_lat"].shape[-1]
            cache = {"k": PC.write_token_rows(cache["k"], k_store,
                                              frame_table, positions,
                                              page_size),
                     "v": PC.write_token_rows(cache["v"], v, frame_table,
                                              positions, page_size),
                     "k_lat": PC.write_token_rows(cache["k_lat"],
                                                  k_store[..., :dl],
                                                  page_table, positions,
                                                  page_size)}
            out, win = dispatch.loki_tiered_decode(
                q, cache["k"], cache["v"], cache["k_lat"], cur_len, proj,
                cfg.loki, sliding_window=sw,
                page_table=page_table, frame_table=frame_table,
                page_size=page_size, token_granular=(policy == "loki"))
            y = L.dot(out.reshape(b, cfg.q_dim), p["wo"].astype(x.dtype))
            return y, cache, win
        if lay.quantized:
            kp, ks = PC.write_token_rows_q(
                cache["k"], cache["k_scale"], k_store, page_table,
                positions, page_size, qmax=lay.qmax)
            vp, vs = PC.write_token_rows_q(
                cache["v"], cache["v_scale"], v, page_table,
                positions, page_size, qmax=lay.qmax)
            cache = {"k": kp, "v": vp, "k_scale": ks, "v_scale": vs}
        else:
            cache = {"k": PC.write_token_rows(cache["k"], k_store,
                                              page_table, positions,
                                              page_size),
                     "v": PC.write_token_rows(cache["v"], v, page_table,
                                              positions, page_size)}

        def view(name):
            return PC.gather_logical_dq(cache[name],
                                        cache.get(name + "_scale"),
                                        page_table, page_size)
    else:
        cache = {"k": _write_cache(cache["k"], k_store, pos_len),
                 "v": _write_cache(cache["v"], v, pos_len)}

        def view(name):
            return cache[name]

    # queries follow the storage basis; hd**-0.5 stays the logit scale even
    # when the stored K width is the latent rank r < hd
    q_read = q
    if paged and lay.basis == "pca" and policy in ("full", "exact_topk"):
        qg_r = q.reshape(b, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads,
                         hd)
        qh = jnp.einsum("bhgd,hde->bhge", qg_r, proj.astype(q.dtype))
        q_read = qh[..., :lay.k_width(hd)].reshape(b, cfg.n_heads, -1)

    if policy == "full":
        # backend-dispatched like loki_block: on the Pallas path the paged
        # streaming kernel reads live blocks through the table; the XLA
        # path is the bit-preserved gather + decode_full reference
        out = dispatch.full_paged_decode(q_read, cache["k"], cache["v"],
                                         cur_len, backend=cfg.loki.backend,
                                         block_size=cfg.loki.block_size,
                                         sliding_window=sw,
                                         logit_scale=hd ** -0.5,
                                         page_table=page_table,
                                         page_size=page_size,
                                         k_scale=cache.get("k_scale"),
                                         v_scale=cache.get("v_scale"))
    elif policy == "exact_topk":
        # exact scores + block top-k fused the same way loki_block's
        # approximate pass is; XLA keeps the token-granular reference
        out = dispatch.exact_topk_paged_decode(q_read, cache["k"],
                                               cache["v"], cur_len,
                                               cfg.loki,
                                               logit_scale=hd ** -0.5,
                                               page_table=page_table,
                                               page_size=page_size,
                                               k_scale=cache.get("k_scale"),
                                               v_scale=cache.get("v_scale"))
    elif policy == "loki":
        if cfg.loki.n_chunks:
            out = loki.loki_decode_chunked(
                q, view("k"), view("v"), cur_len, proj,
                cfg.loki, sliding_window=sw)
        else:
            out = loki.loki_decode(q, view("k"), view("v"),
                                   cur_len, proj, cfg.loki,
                                   sliding_window=sw)
    elif policy == "loki_block":
        # backend-dispatched: fused Pallas kernels on TPU (or when forced),
        # the jnp reference otherwise (core/dispatch.py). Paged caches pass
        # through untouched — the kernels index the pool via the table and
        # dequantize quantized layouts in their DMA epilogue.
        out = dispatch.loki_block_decode(q, cache["k"], cache["v"], cur_len,
                                         proj, cfg.loki,
                                         sliding_window=sw,
                                         page_table=page_table,
                                         page_size=page_size,
                                         k_scale=cache.get("k_scale"),
                                         v_scale=cache.get("v_scale"))
    elif policy == "pcaattn":
        out = baselines.pcaattn_decode(q, view("k"), view("v"),
                                       cur_len, proj, cfg.loki)
    else:
        raise ValueError(f"unknown attention policy {policy!r}")
    y = L.dot(out.reshape(b, cfg.q_dim), p["wo"].astype(x.dtype))
    return y, cache


def attn_prefill(p, cache, x, positions, cfg: ModelConfig):
    """Process a whole prompt, filling cache slots [0, S). Returns (y, cache).

    The cache stores keys in the policy's basis so subsequent decode steps
    are pure Algorithm-1."""
    q, k, v = _qkv(p, x, cfg)
    if cfg.rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    out = A.causal_attention(q, k, v, causal=True,
                             sliding_window=cfg.sliding_window)
    b, s = x.shape[:2]
    y = L.dot(out.reshape(b, s, cfg.q_dim), p["wo"].astype(x.dtype))

    policy = cfg.attn_policy()
    proj = p["pca"]
    if policy in ("loki", "loki_block"):
        k_store = jnp.einsum("bshd,hde->bshe", k, proj.astype(k.dtype))
    elif policy == "pcaattn":
        d = cache["k"].shape[-1]
        k_store = jnp.einsum("bshd,hde->bshe", k,
                             proj[..., :d].astype(k.dtype))
    else:
        k_store = k
    if policy == "h2o":
        # budget cache: keep the most recent `budget` prompt tokens
        budget = cache["k"].shape[1]
        take = min(budget, s)
        kk = k[:, s - take:]
        vv = v[:, s - take:]
        pad = budget - take
        cache = dict(cache)
        cache["k"] = jnp.pad(kk, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(
            cache["k"].dtype)
        cache["v"] = jnp.pad(vv, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(
            cache["v"].dtype)
        cache["pos"] = jnp.pad(
            jnp.broadcast_to(jnp.arange(s - take, s), (b, take)),
            ((0, 0), (0, pad)), constant_values=-1).astype(jnp.int32)
        cache["acc"] = jnp.zeros_like(cache["acc"])
        cache["fill"] = jnp.full((b,), take, jnp.int32)
        return y, cache
    smax = cache["k"].shape[1]
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k_store.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }
    return y, cache


def attn_prefill_chunk(p, cache, x, pos_start, n_valid, cfg: ModelConfig, *,
                       table_row, page_size: int, frame_row=None,
                       rank=None, sliding_window=None):
    """One chunk of a paged, chunked prefill for a single request.

    ``sliding_window`` overrides ``cfg.sliding_window`` for this layer
    (per-layer windows, ``cfg.window_layers``; 0 = full attention).

    x (1,C,E) holds the chunk's token embeddings at logical positions
    ``pos_start .. pos_start+C-1``; only the first ``n_valid`` are real
    (the scheduler zero-pads the final chunk to keep the jit signature
    fixed). The chunk's K/V scatter through ``table_row (max_pages,)``
    into the shared pool (pad rows go to the trash page), then the chunk
    attends causally over [0, pos_start+C) via the logical view.

    Exactness across chunks: the cached prefix holds keys in the policy's
    storage basis, so prefix scores are taken in that basis — for Loki
    policies that is q̂·k̂ which equals q·k exactly for orthogonal P
    (Lemma 4.1). The chunk's own columns use the fresh original-basis
    keys, so a single-chunk prefill reproduces the one-shot prefill's
    score matrix term for term."""
    from repro.serving import paged_cache as PC
    b, c = x.shape[:2]
    q, k, v = _qkv(p, x, cfg)
    positions = pos_start + jnp.arange(c)[None]            # (1, C)
    if cfg.rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    policy = cfg.attn_policy()
    proj = p["pca"]
    lay = cfg.page_layout
    hd = cfg.resolved_head_dim
    if policy not in ("full", "exact_topk", "loki", "loki_block"):
        raise ValueError(f"policy {policy!r} cannot reconstruct exact "
                         "prefix attention from its cache; use the dense "
                         "engine's one-shot prefill")
    pca_store = policy in ("loki", "loki_block") or lay.basis == "pca"
    k_store = (jnp.einsum("bshd,hde->bshe", k, proj.astype(k.dtype))
               if pca_store else k)
    kw = cache["k"].shape[-1]      # allocated pool width is authoritative
    if kw < hd:
        k_store = k_store[..., :kw]                # latent rank-r storage
    if rank is not None:
        k_store = k_store * (jnp.arange(kw) < rank).astype(k_store.dtype)
    if frame_row is not None:
        # tiered pool (DESIGN.md §13): full-D rows at device frames, the
        # latent sidecar by logical page. Prefill is exact attention, so
        # the scheduler has promoted every page of this slot already.
        dl = cache["k_lat"].shape[-1]
        cache = {"k": PC.write_chunk_rows(cache["k"], k_store[0], frame_row,
                                          pos_start, page_size,
                                          n_valid=n_valid),
                 "v": PC.write_chunk_rows(cache["v"], v[0], frame_row,
                                          pos_start, page_size,
                                          n_valid=n_valid),
                 "k_lat": PC.write_chunk_rows(cache["k_lat"],
                                              k_store[0][..., :dl],
                                              table_row, pos_start,
                                              page_size, n_valid=n_valid)}
    elif lay.quantized:
        kp, ks = PC.write_chunk_rows_q(
            cache["k"], cache["k_scale"], k_store[0], table_row, pos_start,
            page_size, n_valid=n_valid, qmax=lay.qmax)
        vp, vs = PC.write_chunk_rows_q(
            cache["v"], cache["v_scale"], v[0], table_row, pos_start,
            page_size, n_valid=n_valid, qmax=lay.qmax)
        cache = {"k": kp, "v": vp, "k_scale": ks, "v_scale": vs}
    else:
        cache = {"k": PC.write_chunk_rows(cache["k"], k_store[0], table_row,
                                          pos_start, page_size,
                                          n_valid=n_valid),
                 "v": PC.write_chunk_rows(cache["v"], v[0], table_row,
                                          pos_start, page_size,
                                          n_valid=n_valid)}

    read_row = frame_row if frame_row is not None else table_row
    klog = PC.gather_logical_dq(cache["k"], cache.get("k_scale"),
                                read_row[None], page_size)
    vlog = PC.gather_logical_dq(cache["v"], cache.get("v_scale"),
                                read_row[None], page_size)
    sl = klog.shape[1]
    n_kv = cfg.n_kv_heads
    scale = hd ** -0.5
    qg = A._group(q, n_kv)                                 # (1,C,Hkv,G,D)
    if pca_store:
        q_pref = jnp.einsum("bchgd,hde->bchge", qg, proj.astype(q.dtype))
    else:
        q_pref = qg
    if kw < hd:
        q_pref = q_pref[..., :kw]       # scores against rank-r cached keys
    # prefix scores against the cached (storage-basis) keys ...
    scores = jnp.einsum("bchgd,bshd->bhgcs", q_pref * scale, klog,
                        preferred_element_type=jnp.float32)
    # ... the chunk's own columns overwritten with fresh original-basis
    # scores (bit-parity with the one-shot prefill for these terms).
    # Scatter, not dynamic_update_slice: when the padded chunk overhangs
    # the logical length (pos_start + C > Sl, pad columns only) a DUS
    # would clamp the start and land the whole block at shifted columns;
    # drop-mode scatter discards exactly the overhanging pads instead.
    s_chunk = jnp.einsum("bchgd,bshd->bhgcs", qg * scale, k,
                         preferred_element_type=jnp.float32)
    chunk_cols = pos_start + jnp.arange(c)
    scores = scores.at[:, :, :, :, chunk_cols].set(s_chunk, mode="drop")

    sw = cfg.sliding_window if sliding_window is None else sliding_window
    kv_pos = jnp.arange(sl)
    mask = kv_pos[None, :] <= positions[0][:, None]        # causal (C, Sl)
    if sw:
        mask &= positions[0][:, None] - kv_pos[None, :] < sw
    scores = jnp.where(mask[None, None, None], scores, A.NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(vlog.dtype)
    o = jnp.einsum("bhgcs,bshd->bchgd", w, vlog)
    y = L.dot(o.reshape(b, c, cfg.q_dim), p["wo"].astype(x.dtype))
    return y, cache


# =====================================================================
# MoE block (GShard-style capacity dispatch; FLOPs track active experts)
# =====================================================================

def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 3)
    gated = cfg.mlp in ("swiglu", "geglu")
    return {
        "router": L._init(ks[0], (d, m.n_experts)),
        "w_in": L._init(ks[1], (m.n_experts, d, 2 * f if gated else f)),
        "w_out": L._init(ks[2], (m.n_experts, f, d)),
    }


MOE_GROUP = 256  # tokens per dispatch group (keeps dispatch tensors small)


def moe_apply(p, x, cfg: ModelConfig):
    """Returns (y, aux_loss). Capacity routing with sort-based gather/scatter
    dispatch (§Perf M1).

    The GShard one-hot formulation materializes (G,g,K,E,C) dispatch/combine
    tensors — ~50 GB/layer at train_4k scale for 40 experts. Here tokens are
    argsorted by expert id (stable sort keeps GShard's drop-in-token-order
    semantics exactly), each expert's capacity window gathers its tokens, and
    the combine is a scatter-add — O(E·C) index tensors instead of
    O(g·K·E·C) one-hots. Compute shards over the expert dim when divisible,
    else over the capacity dim (``expert_capacity`` rule)."""
    m = cfg.moe
    b, s, d = x.shape
    n_tok = b * s
    g = min(MOE_GROUP, n_tok)
    n_groups = n_tok // g
    xt = x.reshape(n_groups, g, d)
    xt = constrain(xt, ("moe_group", None, "act_embed"))
    K, E = m.top_k, m.n_experts

    logits = L.dot(xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # (G,g,E)
    gate_w, eidx = jax.lax.top_k(probs, K)                  # (G,g,K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(g * K / E * m.capacity_factor))
    cap = max(4, -(-cap // 4) * 4)                          # round up to 4

    # ---- sort-based dispatch ------------------------------------------
    flat_e = eidx.reshape(n_groups, g * K)                  # (G,gK)
    flat_w = gate_w.reshape(n_groups, g * K)
    order = jnp.argsort(flat_e, axis=-1, stable=True)       # tokens by expert
    sorted_e = jnp.take_along_axis(flat_e, order, -1)
    erange = jnp.arange(E)
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, erange, side="left"))(sorted_e)
    ends = jax.vmap(
        lambda se: jnp.searchsorted(se, erange, side="right"))(sorted_e)
    slot = starts[:, :, None] + jnp.arange(cap)[None, None]   # (G,E,C)
    valid = slot < ends[:, :, None]                           # capacity drop
    slot = jnp.minimum(slot, g * K - 1)
    sel = jnp.take_along_axis(order, slot.reshape(n_groups, -1), -1)
    tok = sel // K                                            # (G,E*C)
    tok = constrain(tok, ("moe_group", None))
    w_sel = jnp.take_along_axis(flat_w, sel, -1)
    w_sel = jnp.where(valid.reshape(n_groups, -1), w_sel, 0.0)

    dt = x.dtype
    x_sel = jnp.take_along_axis(xt, tok[..., None], axis=1)   # (G,E*C,D)
    x_sel = constrain(x_sel, ("moe_group", None, "act_embed"))
    expert_in = x_sel.reshape(n_groups, E, cap, d)
    expert_in = constrain(
        expert_in, ("moe_group", "expert", "expert_capacity", "act_embed"))
    f = m.d_ff_expert
    h = jnp.einsum("gecd,edf->gecf", expert_in, p["w_in"].astype(dt))
    h = constrain(h, ("moe_group", "expert", "expert_capacity", "mlp"))
    if cfg.mlp in ("swiglu", "geglu"):
        gate, up = h[..., :f], h[..., f:]
        act = jax.nn.silu(gate) if cfg.mlp == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    elif cfg.mlp == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_out"].astype(dt))
    expert_out = constrain(
        expert_out, ("moe_group", "expert", "expert_capacity", "act_embed"))

    # ---- combine: weighted scatter-add back to token order ------------
    contrib = (expert_out.reshape(n_groups, E * cap, d)
               * w_sel[..., None].astype(dt))
    contrib = constrain(contrib, ("moe_group", None, "act_embed"))
    y = jnp.zeros((n_groups, g, d), dt)
    y = y.at[jnp.arange(n_groups)[:, None], tok].add(contrib)
    y = constrain(y, ("moe_group", None, "act_embed"))
    y = y.reshape(b, s, d)

    # aux: load-balance (Switch) + router z-loss
    first = jax.nn.one_hot(eidx[:, :, 0], E, dtype=jnp.float32)
    frac_tokens = jnp.mean(first, axis=1)                   # first choice
    frac_probs = jnp.mean(probs, axis=1)
    lb = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, -1))
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = lb + m.router_z_loss * zl
    return y, aux


def moe_decode(p, x, cfg: ModelConfig):
    """Single-token MoE: gather the top-k expert weights per token.

    x (B,E). At decode, per-token expert weight gathers beat dispatch einsums
    (k·d·f bytes vs n_tok·E·C flops)."""
    m = cfg.moe
    b, d = x.shape
    logits = L.dot(x.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    gate_w, eidx = jax.lax.top_k(probs, m.top_k)            # (B,K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    w_in = jnp.take(p["w_in"], eidx, axis=0).astype(x.dtype)   # (B,K,d,f')
    w_out = jnp.take(p["w_out"], eidx, axis=0).astype(x.dtype)
    f = m.d_ff_expert
    h = jnp.einsum("bd,bkdf->bkf", x, w_in)
    if cfg.mlp in ("swiglu", "geglu"):
        gate, up = h[..., :f], h[..., f:]
        act = jax.nn.silu(gate) if cfg.mlp == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    elif cfg.mlp == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("bkf,bkfd->bkd", h, w_out)
    return jnp.einsum("bk,bkd->bd", gate_w.astype(x.dtype), y)


# =====================================================================
# Mamba (S6) block — hymba's parallel-SSM path
# =====================================================================

def init_mamba(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    a = jnp.broadcast_to(jnp.arange(1, s.state_dim + 1, dtype=jnp.float32),
                         (d_in, s.state_dim))
    return {
        "in_proj": L._init(ks[0], (d, 2 * d_in)),
        "conv_w": L._init(ks[1], (s.conv_width, d_in), scale=0.5),
        "x_proj": L._init(ks[2], (d_in, dt_rank + 2 * s.state_dim)),
        "dt_proj": L._init(ks[3], (dt_rank, d_in)),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": L._init(ks[4], (d_in, d)),
    }


def _mamba_scan(p, xz, conv_state, ssm_state, cfg: ModelConfig,
                n_valid=None):
    """Shared S6 recurrence. xz (B,S,2*d_in) from in_proj.

    conv_state (B,cw-1,d_in), ssm_state (B,d_in,N).
    Returns (y (B,S,d_in->d projected later), states).

    ``n_valid`` (traced scalar): positions at or past it are zero padding
    (a fixed-size prefill chunk's tail). Their ``dt`` is forced to 0 so the
    SSM state passes through unchanged (exp(0·A)=1, zero input), and the
    carried conv window ends at the last *valid* token — running chunks
    back-to-back reproduces the unchunked recurrence exactly."""
    s = cfg.ssm
    d_in = xz.shape[-1] // 2
    x, z = xz[..., :d_in], xz[..., d_in:]
    # causal depthwise conv with carried state
    cw = s.conv_width
    xpad = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    if cw <= 1:
        new_conv = conv_state
    elif n_valid is None:
        new_conv = xpad[:, -(cw - 1):]
    else:
        # last cw-1 inputs *ending at the n_valid-th real token* (rows
        # [n_valid, n_valid + cw - 1) of xpad; reaches back into the old
        # conv state when the chunk has fewer than cw-1 valid tokens)
        new_conv = jax.lax.dynamic_slice_in_dim(xpad, n_valid, cw - 1,
                                                axis=1)
    conv = sum(xpad[:, i:i + x.shape[1]] * p["conv_w"][i].astype(x.dtype)
               for i in range(cw))
    x = jax.nn.silu(conv)

    dt_rank = p["dt_proj"].shape[0]
    proj = L.dot(x, p["x_proj"].astype(x.dtype))
    dt = jax.nn.softplus(
        L.dot(proj[..., :dt_rank], p["dt_proj"].astype(x.dtype))
        + p["dt_bias"].astype(x.dtype))                        # (B,S,d_in)
    if n_valid is not None:
        dt = dt * (jnp.arange(x.shape[1]) < n_valid)[None, :, None]
    bmat = proj[..., dt_rank:dt_rank + s.state_dim]            # (B,S,N)
    cmat = proj[..., dt_rank + s.state_dim:]                   # (B,S,N)
    a = -jnp.exp(p["a_log"]).astype(jnp.float32)               # (d_in,N)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                              # (B,d_in)...
        da = jnp.exp(dt_t[..., None] * a)                      # (B,d_in,N)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(bmat.astype(jnp.float32), 1, 0),
          jnp.moveaxis(cmat.astype(jnp.float32), 1, 0))
    new_ssm, ys = jax.lax.scan(step, ssm_state.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    y = y + x * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y, new_conv, new_ssm


def mamba_train(p, x, cfg: ModelConfig):
    s = cfg.ssm
    b = x.shape[0]
    d_in = s.expand * cfg.d_model
    xz = L.dot(x, p["in_proj"].astype(x.dtype))
    conv0 = jnp.zeros((b, s.conv_width - 1, d_in), x.dtype)
    ssm0 = jnp.zeros((b, d_in, s.state_dim), jnp.float32)
    y, _, _ = _mamba_scan(p, xz, conv0, ssm0, cfg)
    return L.dot(y, p["out_proj"].astype(x.dtype))


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, s.state_dim), jnp.float32),
    }


def mamba_decode(p, cache, x, cfg: ModelConfig):
    xz = L.dot(x[:, None, :], p["in_proj"].astype(x.dtype))
    y, conv, ssm = _mamba_scan(p, xz, cache["conv"], cache["ssm"], cfg)
    y = L.dot(y[:, 0], p["out_proj"].astype(x.dtype))
    return y, {"conv": conv.astype(cache["conv"].dtype), "ssm": ssm}


def mamba_prefill_chunk(p, state, x, n_valid, cfg: ModelConfig):
    """One chunk of a chunked prefill through the S6 recurrence.

    x (1,C,E) chunk hidden states, only the first ``n_valid`` real; state
    is the slot's carried {conv, ssm}. Pad tokens leave the state untouched
    (see ``_mamba_scan``), so consecutive chunks reproduce the one-shot
    ``_mamba_prefill`` state exactly. Returns (y (1,C,E), new_state)."""
    xz = L.dot(x, p["in_proj"].astype(x.dtype))
    y, conv, ssm = _mamba_scan(p, xz, state["conv"], state["ssm"], cfg,
                               n_valid=n_valid)
    y = L.dot(y, p["out_proj"].astype(x.dtype))
    return y, {"conv": conv.astype(state["conv"].dtype), "ssm": ssm}


# =====================================================================
# xLSTM blocks — mLSTM (chunkwise-parallel) and sLSTM (recurrent)
# =====================================================================

def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    nh = cfg.ssm.n_heads
    dh = d // nh
    ks = jax.random.split(key, 6)
    return {
        "wq": L._init(ks[0], (d, d)),
        "wk": L._init(ks[1], (d, d)),
        "wv": L._init(ks[2], (d, d)),
        "w_if": L._init(ks[3], (d, 2 * nh), scale=0.01),
        "b_if": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]),
        "wo_gate": L._init(ks[4], (d, d)),
        "w_out": L._init(ks[5], (d, d)),
    }


MLSTM_CHUNK = 256


def mlstm_train(p, x, cfg: ModelConfig, *, return_state: bool = False,
                initial_state=None, n_valid=None):
    """Chunkwise-parallel mLSTM (exponential-gated linear attention with
    matrix memory). O(S·c·d + S·d²/c) — sub-quadratic, the long_500k path.

    ``return_state``: also return the final (C, n, m) recurrent state — the
    scan's own carry — so prefill gets its cache for free instead of
    re-scanning the whole prompt token-by-token (§Perf X2).
    ``initial_state``: resume the recurrence from a carried {C, n, m} (the
    paged engine's chunked prefill). ``n_valid``: positions at or past it
    are padding — their input gate is forced to -inf and forget gate to 0
    (identity), so they contribute nothing to the carry."""
    b, s, d = x.shape
    nh = cfg.ssm.n_heads
    dh = d // nh
    dt = x.dtype
    q = L.dot(x, p["wq"].astype(dt)).reshape(b, s, nh, dh) * dh ** -0.5
    k = L.dot(x, p["wk"].astype(dt)).reshape(b, s, nh, dh) * dh ** -0.5
    v = L.dot(x, p["wv"].astype(dt)).reshape(b, s, nh, dh)
    # gate pre-activations: bf16 matmul, f32 accumulation (§Perf X3 — an
    # f32 upcast here forces f32 partial-sum all-reduces under FSDP)
    if_g = jnp.matmul(x, p["w_if"].astype(dt),
                      preferred_element_type=jnp.float32) + p["b_if"]
    ig, fg = if_g[..., :nh], if_g[..., nh:]                 # (B,S,H)
    logf = jax.nn.log_sigmoid(fg)
    if n_valid is not None:
        vm = (jnp.arange(s) < n_valid)[None, :, None]
        ig = jnp.where(vm, ig, -1e30)                       # i -> 0
        logf = jnp.where(vm, logf, 0.0)                     # f -> 1

    c = min(MLSTM_CHUNK, s)
    if s % c:
        c = s
    n_chunks = s // c

    def reshape_c(t):
        return jnp.moveaxis(t.reshape(b, n_chunks, c, *t.shape[2:]), 1, 0)

    qc, kc, vc = reshape_c(q), reshape_c(k), reshape_c(v)
    ic, fc = reshape_c(ig), reshape_c(logf)                 # (n,B,c,H)

    def chunk_step(carry, inp):
        C, n, m = carry          # (B,H,dh,dh), (B,H,dh), (B,H)
        qq, kk, vv, ii, ff = inp
        csum = jnp.cumsum(ff, axis=1)                       # (B,c,H)
        total = csum[:, -1]                                 # (B,H)
        # log decay from chunk start to position t (inclusive)
        d_in = csum                                          # sum_{j<=t} logf
        # intra-chunk log weights: a[t,s] = csum_t - csum_s + i_s  (s<=t)
        log_a = (d_in[:, :, None, :] - d_in[:, None, :, :]
                 + ii[:, None, :, :])                       # (B,t,s,H)
        tmask = jnp.tril(jnp.ones((c, c), bool))
        log_a = jnp.where(tmask[None, :, :, None], log_a, -jnp.inf)
        # inter-chunk: carried state decayed to position t
        log_b = d_in + m[:, None, :]                        # (B,t,H)
        m_new = jnp.maximum(jnp.max(log_a, axis=2), log_b)  # (B,t,H)
        a = jnp.exp(log_a - m_new[:, :, None, :])
        bw = jnp.exp(log_b - m_new)                         # (B,t,H)
        # numerator / denominator (fp32 accumulation)
        scores = jnp.einsum("bthd,bshd->bhts", qq, kk,
                            preferred_element_type=jnp.float32)
        scores = scores * jnp.moveaxis(a, 3, 1)             # (B,H,t,s)
        num_intra = jnp.einsum("bhts,bshd->bthd", scores.astype(dt), vv)
        num_inter = jnp.einsum("bthd,bhde->bthe", qq,
                               C.astype(dt)) * bw[..., None].astype(dt)
        den = (jnp.einsum("bthd,bhd->bth", qq.astype(jnp.float32), n) * bw
               + jnp.sum(scores, axis=3).transpose(0, 2, 1))
        h = (num_intra + num_inter).astype(jnp.float32) / jnp.maximum(
            jnp.abs(den), jnp.exp(-m_new))[..., None]
        h = h.astype(dt)
        # carry update: C' = exp(total + m - m') C + sum_s exp(csum_T - csum_s + i_s - m') k v^T
        m_next = jnp.maximum(total + m, jnp.max(
            total[:, None] - d_in + ii, axis=1))            # (B,H)
        decay_c = jnp.exp(total + m - m_next)               # (B,H)
        w_s = jnp.exp(total[:, None] - d_in + ii - m_next[:, None])
        C = (C * decay_c[..., None, None]
             + jnp.einsum("bsh,bshd,bshe->bhde",
                          w_s, kk.astype(jnp.float32),
                          vv.astype(jnp.float32)))
        n = (n * decay_c[..., None]
             + jnp.einsum("bsh,bshd->bhd", w_s, kk.astype(jnp.float32)))
        return (C, n, m_next), h

    if initial_state is not None:
        C0 = initial_state["C"].astype(jnp.float32)
        n0 = initial_state["n"].astype(jnp.float32)
        m0 = initial_state["m"].astype(jnp.float32)
    else:
        C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
        m0 = jnp.full((b, nh), -1e30, jnp.float32)
    (C_f, n_f, m_f), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                       (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)
    o = jax.nn.sigmoid(L.dot(x, p["wo_gate"].astype(dt)))
    y = L.dot(h * o, p["w_out"].astype(dt))
    if return_state:
        return y, {"C": C_f, "n": n_f, "m": m_f}
    return y


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    nh = cfg.ssm.n_heads
    dh = cfg.d_model // nh
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def mlstm_decode(p, cache, x, cfg: ModelConfig):
    b, d = x.shape
    nh = cfg.ssm.n_heads
    dh = d // nh
    dt = x.dtype
    q = L.dot(x, p["wq"].astype(dt)).reshape(b, nh, dh) * dh ** -0.5
    k = L.dot(x, p["wk"].astype(dt)).reshape(b, nh, dh) * dh ** -0.5
    v = L.dot(x, p["wv"].astype(dt)).reshape(b, nh, dh)
    if_g = (L.dot(x.astype(jnp.float32), p["w_if"].astype(jnp.float32))
            + p["b_if"])
    ii, ff = if_g[..., :nh], jax.nn.log_sigmoid(if_g[..., nh:])
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(ff + m, ii)
    fw = jnp.exp(ff + m - m_new)[..., None]
    iw = jnp.exp(ii - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = C * fw[..., None] + iw[..., None] * kf[..., None] * vf[:, :, None, :]
    n = n * fw + iw * kf
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh",
                                         q.astype(jnp.float32), n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(dt).reshape(b, d)
    o = jax.nn.sigmoid(L.dot(x, p["wo_gate"].astype(dt)))
    y = L.dot(h * o, p["w_out"].astype(dt))
    return y, {"C": C, "n": n, "m": m_new}


def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    nh = cfg.ssm.n_heads
    dh = d // nh
    ks = jax.random.split(key, 3)
    return {
        "w_gates": L._init(ks[0], (d, 4 * d)),         # z,i,f,o pre-acts
        "r_gates": L._init(ks[1], (nh, dh, 4 * dh), scale=0.1),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "w_out": L._init(ks[2], (d, d)),
    }


def _slstm_cell(p, wx_t, state, nh, dh):
    """One sLSTM step. wx_t (B,4d) precomputed input part."""
    c, n, h, m = state
    b = wx_t.shape[0]
    rh = jnp.einsum("bhd,hde->bhe", h.reshape(b, nh, dh),
                    p["r_gates"]).reshape(b, 4 * nh * dh)
    pre = (wx_t + rh + p["b_gates"]).astype(jnp.float32)
    d = nh * dh
    z, i_p, f_p, o_p = pre[:, :d], pre[:, d:2*d], pre[:, 2*d:3*d], pre[:, 3*d:]
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o_p)
    logf = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(logf + m, i_p)
    i_w = jnp.exp(i_p - m_new)
    f_w = jnp.exp(logf + m - m_new)
    c = f_w * c + i_w * z
    n = f_w * n + i_w
    h = o * (c / jnp.maximum(n, 1.0))
    return (c, n, h, m_new)


def slstm_train(p, x, cfg: ModelConfig, *, return_state: bool = False,
                initial_state=None, n_valid=None):
    """``initial_state``/``n_valid``: resume from a carried {c,n,h,m} and
    skip state updates for pad positions (the paged engine's chunked
    prefill) — the recurrence is stepwise, so masking is exact."""
    b, s, d = x.shape
    nh = cfg.ssm.n_heads
    dh = d // nh
    wx = jnp.matmul(x, p["w_gates"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    # §Perf X1: the sLSTM recurrence is sequential with dense per-head
    # coupling — tensor-parallel state would need a collective every token
    # (32768 tiny all-to-alls per layer at prefill_32k). Replicate the gate
    # activations across the model axis ONCE, outside the scan; the cell is
    # then collective-free and the model axis idles through this (tiny) op.
    wx = constrain(wx, ("batch", "seq", None))
    if initial_state is not None:
        state0 = (initial_state["c"].astype(jnp.float32),
                  initial_state["n"].astype(jnp.float32),
                  initial_state["h"].astype(jnp.float32),
                  initial_state["m"].astype(jnp.float32))
    else:
        zeros = jnp.zeros((b, d), jnp.float32)
        state0 = (zeros, zeros, zeros, jnp.full((b, d), -1e30))
    state0 = jax.tree.map(lambda a: constrain(a, ("batch", None)), state0)
    valid = (jnp.arange(s) < n_valid) if n_valid is not None \
        else jnp.ones((s,), bool)

    def step(st, inp):
        wx_t, ok = inp
        new = _slstm_cell(p, wx_t, st, nh, dh)
        st = jax.tree.map(lambda nw, od: jnp.where(ok, nw, od), new, st)
        return st, st[2]

    st_f, hs = jax.lax.scan(step, state0, (jnp.moveaxis(wx, 1, 0), valid))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    y = L.dot(h, p["w_out"].astype(x.dtype))
    if return_state:
        c, n, hst, m = st_f
        return y, {"c": c, "n": n, "h": hst, "m": m}
    return y


def init_slstm_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z.copy(), "h": z.copy(),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_decode(p, cache, x, cfg: ModelConfig):
    nh = cfg.ssm.n_heads
    dh = cfg.d_model // nh
    wx = jnp.matmul(x, p["w_gates"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
    st = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_cell(p, wx, st, nh, dh)
    y = L.dot(h.astype(x.dtype), p["w_out"].astype(x.dtype))
    return y, {"c": c, "n": n, "h": h, "m": m}
