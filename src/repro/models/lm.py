"""Language-model assembly for every assigned architecture family.

One parameter tree, three entry points:

  init(key, cfg)                                   -> params
  forward(params, tokens, cfg, ...)                -> logits       (train/eval)
  prefill(params, cfg, inputs)                     -> (logits, cache)
  decode_step(params, cfg, cache, token, pos_len)  -> (logits, cache)

Layers are stacked along a leading L axis and driven with ``lax.scan`` so the
lowered HLO stays compact regardless of depth (critical for the 512-device
dry-run compiles). Architectures whose layers are heterogeneous (xLSTM's
mLSTM/sLSTM mix) use a Python loop over per-layer param trees instead
(cfg-driven; these models are shallow).

Block composition per family:
  dense   : [attn, mlp]
  moe     : [attn, moe]
  hybrid  : [attn ∥ mamba, mlp]          (hymba: parallel heads, mean-fused)
  ssm     : [mlstm] or [slstm]           (xlstm; no attention at all)
  encdec  : encoder [attn, mlp] + decoder [attn, cross-attn, mlp]  (whisper)
  vlm     : dense backbone; vision patch embeddings prepended (llava)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models import layers as L
from repro.serving import cache_spec as CS
# canonical layer-kind logic lives in the CacheSpec registry so the spec
# table and the model assembly can never disagree; re-exported here for the
# rest of the codebase (engine.py etc. call lm.uses_scan)
from repro.serving.cache_spec import layer_kind, uses_scan
from repro.sharding.rules import constrain


# --------------------------------------------------------------- init

def _is_slstm(cfg: ModelConfig, i: int) -> bool:
    return CS.is_slstm(cfg, i)


def init_layer(key, cfg: ModelConfig, kind: str):
    """kind: dense|moe|hybrid|mlstm|slstm|enc|dec"""
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    if kind in ("dense", "moe", "hybrid", "enc", "dec"):
        p["ln1"] = L.init_norm(cfg)
        p["attn"] = B.init_attention(ks[0], cfg)
        p["ln2"] = L.init_norm(cfg)
    if kind in ("dense", "hybrid", "enc", "dec"):
        p["mlp"] = L.init_mlp(ks[1], cfg)
    if kind == "moe":
        p["moe"] = B.init_moe(ks[1], cfg)
    if kind == "hybrid":
        p["ssm"] = B.init_mamba(ks[2], cfg)
        p["ln_ssm"] = L.init_norm(cfg)
    if kind == "mlstm":
        p["ln1"] = L.init_norm(cfg)
        p["ssm"] = B.init_mlstm(ks[0], cfg)
        p["ln2"] = L.init_norm(cfg)
        p["mlp"] = L.init_mlp(ks[1], cfg, d_ff=2 * cfg.d_model)
    if kind == "slstm":
        p["ln1"] = L.init_norm(cfg)
        p["ssm"] = B.init_slstm(ks[0], cfg)
        p["ln2"] = L.init_norm(cfg)
        p["mlp"] = L.init_mlp(ks[1], cfg, d_ff=2 * cfg.d_model)
    if kind == "dec" and cfg.is_encoder_decoder:
        p["ln_x"] = L.init_norm(cfg)
        p["xattn"] = B.init_attention(ks[3], cfg)
    return p


def init(key, cfg: ModelConfig):
    k_emb, k_layers, k_enc, k_out = jax.random.split(key, 4)
    params: Dict[str, Any] = {"embed": L.init_embed(k_emb, cfg)}
    if uses_scan(cfg):
        kind = layer_kind(cfg, 0)
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: init_layer(k, cfg, kind))(keys)
    else:
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = [init_layer(keys[i], cfg, layer_kind(cfg, i))
                            for i in range(cfg.n_layers)]
    if cfg.is_encoder_decoder:
        ekeys = jax.random.split(k_enc, cfg.enc_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: init_layer(k, cfg, "enc"))(ekeys)
        params["enc_norm"] = L.init_norm(cfg)
    if cfg.vision_tokens:
        # stub frontend: a single linear adapter over precomputed patch
        # embeddings (anyres tiling & the ViT tower are out of scope — the
        # dry-run feeds ShapeDtypeStructs for the patch embeddings).
        params["vision_adapter"] = L._init(k_out, (cfg.d_model, cfg.d_model))
    params["final_norm"] = L.init_norm(cfg)
    return params


# ----------------------------------------------------- layer train fns

def _block_train(p, x, positions, cfg: ModelConfig, kind: str,
                 enc_out=None, capture=None):
    aux = jnp.float32(0.0)
    if kind in ("dense", "moe", "hybrid", "dec"):
        h = L.norm_apply(p["ln1"], x)
        a = B.attn_train(p["attn"], h, positions, cfg, capture=capture)
        if kind == "hybrid":
            s = B.mamba_train(p["ssm"], h, cfg)
            a = 0.5 * (L.norm_apply(p["ln_ssm"], a) +
                       L.norm_apply(p["ln_ssm"], s))
        x = x + a
        if kind == "dec" and cfg.is_encoder_decoder:
            h = L.norm_apply(p["ln_x"], x)
            q, _, _ = B._qkv(p["xattn"], h, cfg)
            from repro.core.attention import cross_attention
            ek, ev = enc_out
            o = cross_attention(q, ek, ev)
            b, s_ = h.shape[:2]
            x = x + L.dot(o.reshape(b, s_, cfg.q_dim),
                          p["xattn"]["wo"].astype(h.dtype))
        h = L.norm_apply(p["ln2"], x)
        if kind == "moe":
            y, aux = B.moe_apply(p["moe"], h, cfg)
        else:
            y = L.mlp_apply(p["mlp"], h, cfg)
        x = x + y
    elif kind in ("mlstm", "slstm"):
        h = L.norm_apply(p["ln1"], x)
        y = (B.mlstm_train(p["ssm"], h, cfg) if kind == "mlstm"
             else B.slstm_train(p["ssm"], h, cfg))
        x = x + y
        h = L.norm_apply(p["ln2"], x)
        x = x + L.mlp_apply(p["mlp"], h, cfg)
    return x, aux


def _encode(params, frames, cfg: ModelConfig):
    """Whisper-style encoder over precomputed frame embeddings.

    Returns per-layer-agnostic encoder output projected to (k, v) per decoder
    layer lazily (we return the hidden states; cross-attn projects)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    pos = jnp.arange(frames.shape[1])[None]
    x = x + _sinusoidal(frames.shape[1], cfg.d_model).astype(x.dtype)

    def body(x, p):
        h = L.norm_apply(p["ln1"], x)
        a = B.encoder_attn_train(p["attn"], h, pos, cfg)
        x = x + a
        h = L.norm_apply(p["ln2"], x)
        return x + L.mlp_apply(p["mlp"], h, cfg), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.norm_apply(params["enc_norm"], x)


def _sinusoidal(s: int, d: int):
    import numpy as np
    pos = np.arange(s)[:, None]
    i = np.arange(d // 2)[None]
    ang = pos / np.power(10000.0, 2 * i / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32)[None]


def _enc_kv(p_layer, enc_x, cfg: ModelConfig):
    """Project encoder hidden states to this decoder layer's cross (k, v)."""
    _, k, v = B._qkv(p_layer["xattn"], enc_x, cfg)
    return k, v


# --------------------------------------------------------------- forward

def forward(params, tokens, cfg: ModelConfig, *, frames=None, patches=None,
            remat: str = "none", capture_keys: bool = False):
    """Teacher-forced forward -> logits (B,S,V).

    frames: (B,enc_seq,d_model) whisper stub input.
    patches: (B,vision_tokens,d_model) llava stub input (prepended).
    capture_keys: also return (pre, post) rotary keys per layer for PCA
    calibration — (L,B,S,Hkv,D) each.
    """
    x = L.embed_apply(params["embed"], tokens, cfg)
    b, s = tokens.shape
    positions = jnp.arange(s)[None]
    if cfg.vision_tokens and patches is not None:
        vis = L.dot(patches.astype(x.dtype),
                    params["vision_adapter"].astype(x.dtype))
        x = jnp.concatenate([vis, x[:, : s - cfg.vision_tokens]], axis=1)
    if not cfg.rope and not cfg.is_encoder_decoder and cfg.family != "ssm":
        x = x + _sinusoidal(s, cfg.d_model).astype(x.dtype)
    if cfg.is_encoder_decoder:
        x = x + _sinusoidal(s, cfg.d_model).astype(x.dtype)

    enc_x = _encode(params, frames, cfg) if cfg.is_encoder_decoder else None

    captures = [] if capture_keys else None

    if uses_scan(cfg) and not capture_keys:
        kind = layer_kind(cfg, 0)

        def body(carry, p):
            x, aux = carry
            enc_out = _enc_kv(p, enc_x, cfg) if cfg.is_encoder_decoder else None
            x, a = _block_train(p, x, positions, cfg, kind, enc_out=enc_out)
            return (x, aux + a), None

        if remat != "none":
            policy = (jax.checkpoint_policies.nothing_saveable
                      if remat == "full"
                      else jax.checkpoint_policies.checkpoint_dots)
            body = jax.checkpoint(body, policy=policy)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   params["layers"])
    else:
        aux = jnp.float32(0.0)
        layers = params["layers"]
        n = cfg.n_layers
        for i in range(n):
            if uses_scan(cfg):
                p = jax.tree.map(lambda a: a[i], layers)
                kind = layer_kind(cfg, 0)
            else:
                p = layers[i]
                kind = layer_kind(cfg, i)
            cap = {} if capture_keys and "attn" in p else None
            enc_out = _enc_kv(p, enc_x, cfg) if cfg.is_encoder_decoder else None
            x, a = _block_train(p, x, positions, cfg, kind,
                                enc_out=enc_out, capture=cap)
            aux = aux + a
            if cap is not None:
                captures.append(cap)

    x = L.norm_apply(params["final_norm"], x)
    logits = L.unembed_apply(params["embed"], x, cfg)
    if capture_keys:
        pre = jnp.stack([c["pre"] for c in captures]) if captures else None
        post = jnp.stack([c["post"] for c in captures]) if captures else None
        qs = jnp.stack([c["q"] for c in captures]) if captures else None
        return logits, aux, (pre, post, qs)
    return logits, aux


# --------------------------------------------------------------- caches

def init_cache(cfg: ModelConfig, batch: int, smax: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Stacked (L, ...) decode cache for the whole model."""
    def one(kind):
        c = {}
        if kind in ("dense", "moe", "hybrid", "dec"):
            c["attn"] = B.init_attn_cache(cfg, batch, smax, dtype)
        if kind == "hybrid":
            c["ssm"] = B.init_mamba_cache(cfg, batch, dtype)
        if kind == "mlstm":
            c["ssm"] = B.init_mlstm_cache(cfg, batch)
        if kind == "slstm":
            c["ssm"] = B.init_slstm_cache(cfg, batch)
        if kind == "dec" and cfg.is_encoder_decoder:
            hd = cfg.resolved_head_dim
            c["cross_k"] = jnp.zeros(
                (batch, cfg.enc_seq, cfg.n_kv_heads, hd), dtype)
            c["cross_v"] = jnp.zeros_like(c["cross_k"])
        return c

    if uses_scan(cfg):
        kind = layer_kind(cfg, 0)
        layer = one(kind)
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (cfg.n_layers,) + a.shape).copy(), layer)}
    return {"layers": [one(layer_kind(cfg, i)) for i in range(cfg.n_layers)]}


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.float32, n_slots: int = 1,
                     device_pages: Optional[int] = None) -> Dict[str, Any]:
    """Spec-driven paged decode cache for *every* family.

    Each layer's components come from the CacheSpec registry
    (serving/cache_spec.py):

      PagedAttn / WindowPagedAttn -> shared page pool (n_pages * page_size,
          Hkv, D) per layer, no batch dim; requests map logical positions
          to pool rows through per-slot page tables.
      StateSlot -> per-slot recurrent state (n_slots, ...) carried across
          prefill chunks / decode steps; O(1) in request length.
      CrossAttnStatic -> per-slot encoder K/V (n_slots, enc_seq, Hkv, D)
          written once at admission.

    Pool memory scales with the page budget, not n_slots × smax.

    Physical layout is the component's ``PageLayout``: storage dtype, K
    feature width (latent rank under basis="pca") and — for quantized
    dtypes — per-page f32 ``k_scale``/``v_scale`` sidecars (one slot per
    physical page) living next to the pools. CrossAttnStatic carries one
    scale per *slot* (written once at admission). The ``dtype`` argument
    keeps its historical meaning for StateSlot components and for the
    default layout, so existing callers are bit-identical.

    ``device_pages`` (DESIGN.md §13) turns the pool tiered: the full-D
    K/V pools shrink to ``device_pages`` *frames* while an always-resident
    latent-K sidecar ``k_lat`` keeps the leading
    ``cache_spec.latent_score_width`` columns of every *logical* page's
    (PCA-rotated) keys, so Loki's approximate score pass never touches the
    host tier. Quantized layouts are rejected: their RMW store path
    re-derives per-page scales, which is not replay-idempotent under the
    tiered engine's optimistic-run/repair decode."""
    from repro.serving import paged_cache as PC
    CS.assert_pageable(cfg)
    specs = CS.layer_specs(cfg)
    r = n_pages * page_size
    rkv = (device_pages if device_pages is not None else n_pages) * page_size
    if device_pages is not None:
        if not (2 <= device_pages <= n_pages):
            raise ValueError(f"device_pages {device_pages} must be in "
                             f"[2, n_pages={n_pages}]")
        if cfg.page_layout.quantized:
            raise ValueError("tiered pools require a non-quantized "
                             "PageLayout (per-page scale RMW is not "
                             "replay-idempotent)")

    def pool_dtype(lay):
        # the default layout defers to the caller's dtype argument
        if lay == CS.PageLayout():
            return dtype
        return PC.STORAGE_DTYPE[lay.dtype]

    def one(spec: CS.LayerSpec) -> Dict[str, Any]:
        c: Dict[str, Any] = {}
        for name, comp in spec.components:
            if isinstance(comp, (CS.PagedAttn, CS.WindowPagedAttn)):
                lay = comp.layout
                pdt = pool_dtype(lay)
                # per-layer ranks: scan families stack every layer's pool
                # in one array, so allocate at the max width — narrower
                # layers zero-mask their tail dims at write time
                kw = (CS.max_k_width(cfg) if cfg.page_ranks is not None
                      else comp.k_width)
                c["attn"] = {
                    "k": jnp.zeros((rkv, comp.n_kv_heads, kw), pdt),
                    "v": jnp.zeros((rkv, comp.n_kv_heads, comp.head_dim),
                                   pdt)}
                if device_pages is not None:
                    c["attn"]["k_lat"] = jnp.zeros(
                        (r, comp.n_kv_heads, CS.latent_score_width(cfg)),
                        pdt)
                if lay.quantized:
                    c["attn"]["k_scale"] = jnp.zeros((n_pages,),
                                                     jnp.float32)
                    c["attn"]["v_scale"] = jnp.zeros((n_pages,),
                                                     jnp.float32)
            elif isinstance(comp, CS.StateSlot):
                c["ssm"] = CS.state_slot_init(cfg, comp, n_slots, dtype)
            elif isinstance(comp, CS.CrossAttnStatic):
                lay = comp.layout
                c["cross_k"] = jnp.zeros(
                    (n_slots, comp.enc_seq, comp.n_kv_heads,
                     comp.head_dim), pool_dtype(lay))
                c["cross_v"] = jnp.zeros_like(c["cross_k"])
                if lay.quantized:
                    c["cross_k_scale"] = jnp.zeros((n_slots,),
                                                   jnp.float32)
                    c["cross_v_scale"] = jnp.zeros((n_slots,),
                                                   jnp.float32)
        return c

    if uses_scan(cfg):
        layer = one(specs[0])
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (cfg.n_layers,) + a.shape).copy(), layer)}
    return {"layers": [one(s) for s in specs]}


# --------------------------------------------------------------- decode

def _layer_decode(p, c, x, pos_len, cfg: ModelConfig, kind: str, *,
                  page_table=None, page_size: int = 0, live=None,
                  frame_table=None, rank=None, sliding_window=None):
    def keep_live(new, old):
        """StateSlot protection for the batched paged tick: slots that are
        idle or mid-prefill must not have their carried recurrent state
        advanced by the unconditional batched decode (their K/V writes
        already land in the trash page; state has no trash row)."""
        if live is None:
            return new
        return jax.tree.map(
            lambda nw, od: jnp.where(
                live.reshape((-1,) + (1,) * (nw.ndim - 1)), nw, od),
            new, old)

    win = None
    if kind in ("dense", "moe", "hybrid", "dec"):
        h = L.norm_apply(p["ln1"], x)
        if frame_table is not None:
            a, new_attn, win = B.attn_decode(p["attn"], c["attn"], h,
                                             pos_len, cfg,
                                             page_table=page_table,
                                             page_size=page_size,
                                             frame_table=frame_table,
                                             rank=rank)
        else:
            a, new_attn = B.attn_decode(p["attn"], c["attn"], h, pos_len,
                                        cfg, page_table=page_table,
                                        page_size=page_size, rank=rank,
                                        sliding_window=sliding_window)
        c = dict(c)
        c["attn"] = new_attn
        if kind == "hybrid":
            s, new_ssm = B.mamba_decode(p["ssm"], c["ssm"], h, cfg)
            c["ssm"] = keep_live(new_ssm, c["ssm"])
            a = 0.5 * (L.norm_apply(p["ln_ssm"], a) +
                       L.norm_apply(p["ln_ssm"], s))
        x = x + a
        if kind == "dec" and cfg.is_encoder_decoder:
            h = L.norm_apply(p["ln_x"], x)
            from repro.core.attention import decode_full
            q, _, _ = B._qkv(p["xattn"], h[:, None], cfg)
            ck, cv = c["cross_k"], c["cross_v"]
            if "cross_k_scale" in c:      # quantized CrossAttnStatic pages
                ck = ck.astype(jnp.float32) \
                    * c["cross_k_scale"][:, None, None, None]
                cv = cv.astype(jnp.float32) \
                    * c["cross_v_scale"][:, None, None, None]
            o = decode_full(q[:, 0], ck, cv, jnp.int32(ck.shape[1]))
            x = x + L.dot(o.reshape(x.shape[0], cfg.q_dim),
                          p["xattn"]["wo"].astype(x.dtype))
        h = L.norm_apply(p["ln2"], x)
        y = (B.moe_decode(p["moe"], h, cfg) if kind == "moe"
             else L.mlp_apply(p["mlp"], h, cfg))
        x = x + y
    else:
        h = L.norm_apply(p["ln1"], x)
        fn = B.mlstm_decode if kind == "mlstm" else B.slstm_decode
        y, new_ssm = fn(p["ssm"], c["ssm"], h, cfg)
        c = dict(c)
        c["ssm"] = keep_live(new_ssm, c["ssm"])
        x = x + y
        h = L.norm_apply(p["ln2"], x)
        x = x + L.mlp_apply(p["mlp"], h, cfg)
    return x, c, win


_UINT_OF = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}

# cache keys whose leading (post-L) axis is the *slot* axis — everything
# else in a paged cache is pooled (no batch dim) and shared by all slots
_SLOT_KEYS = ("ssm", "cross_k", "cross_v", "cross_k_scale", "cross_v_scale")


def _slot_gather(layers, sidx, scan: bool):
    """Compact the per-slot cache components to the packed batch: leaf
    [n_slots] rows -> [n_live] rows at ``sidx``. Pooled attn leaves pass
    through untouched (they carry no slot axis)."""
    ax = 1 if scan else 0

    def g(tree):
        return jax.tree.map(lambda a: jnp.take(a, sidx, axis=ax), tree)

    if scan:
        return {k: (g(v) if k in _SLOT_KEYS else v)
                for k, v in layers.items()}
    return [{k: (g(v) if k in _SLOT_KEYS else v) for k, v in lc.items()}
            for lc in layers]


def _slot_scatter(full_layers, packed_layers, sidx, scan: bool):
    """Merge a packed decode's cache back into the full-width cache.

    Recurrent state (``ssm``) scatters to its slots — sound because the
    packed batch holds *distinct* slot ids. Cross K/V is read-only during
    decode, so the original leaves are kept (no copy). Pooled attn leaves
    come from the packed run verbatim: page-table indirection already
    landed their writes at the right physical rows."""
    def sc(full, pk, ax):
        idx = (slice(None), sidx) if ax else sidx
        return jax.tree.map(
            lambda f, p: f.at[idx].set(p.astype(f.dtype)), full, pk)

    def merge(full_lc, packed_lc, ax):
        out = dict(packed_lc)
        for k in _SLOT_KEYS:
            if k not in full_lc:
                continue
            out[k] = (sc(full_lc[k], packed_lc[k], ax) if k == "ssm"
                      else full_lc[k])
        return out

    if scan:
        return merge(full_layers, packed_layers, 1)
    return [merge(f, p, 0) for f, p in zip(full_layers, packed_layers)]


def _cache_bits(tree):
    """Float leaves -> same-width uint views (free bitcast on TPU). The scan
    then slices/stacks the per-layer cache with *integer* dynamic-slice /
    dynamic-update-slice, which every backend does in place — XLA:CPU
    legalizes low-precision float DUS via f32, rewriting the whole stacked
    cache with converts each layer (§Perf L3)."""
    def f(a):
        if jnp.issubdtype(a.dtype, jnp.floating):
            return jax.lax.bitcast_convert_type(
                a, _UINT_OF[jnp.dtype(a.dtype).itemsize])
        return a
    return jax.tree.map(f, tree)


def _cache_unbits(tree, dtypes):
    return jax.tree.map(
        lambda a, dt: jax.lax.bitcast_convert_type(a, dt)
        if a.dtype != dt else a, tree, dtypes)


def decode_step(params, cfg: ModelConfig, cache, token, pos_len, *,
                page_table=None, page_size: int = 0, live=None,
                frame_table=None, slot_idx=None):
    """One generation step. token (B,) int32; pos_len (B,) tokens cached.

    Returns (logits (B,V), new_cache). With ``page_table (B, max_pages)``/
    ``page_size`` the cache is the pooled layout of ``init_paged_cache``
    and every layer's attention reads/writes resolve through the table.
    ``live (B,)`` bool: slots marked dead keep their StateSlot components
    (recurrent state / cross K/V are per-slot, with no trash row to divert
    writes to).

    ``slot_idx (B,)`` int32 — gather-packed decode: the batch rows are a
    *compaction* of the cache's slot axis (distinct slot ids; token /
    pos_len / live / page_table rows arrive pre-packed by the scheduler).
    Per-slot components are gathered to the packed batch before the layer
    stack and the advanced recurrent state is scattered back after, so
    decode FLOPs scale with live slots instead of engine capacity while
    the cache keeps its full-width layout.

    With ``cfg.window_layers`` (per-layer SWA/full mixes) the layer stack
    unrolls so each layer gets its *static* window, and a rank-3
    ``page_table (B, n_groups, max_pages)`` carries one table row per
    page-table group (cache_spec.layer_group_ids picks each layer's row).

    ``frame_table (B, max_pages)`` (tiered pools, DESIGN.md §13) maps each
    logical table entry to its device frame (0 = trash frame for HOST
    pages). The return becomes (logits, winners, new_cache) where
    ``winners (B, max_pages)`` bool is the union over layers of logical
    pages the Loki selection attended — the scheduler promotes HOST
    winners and replays."""
    x = L.embed_apply(params["embed"], token[:, None], cfg)[:, 0]
    if not cfg.rope and cfg.family != "ssm":
        # sinusoidal decoders: add position encoding for the current slot
        d = cfg.d_model
        x = x + _sinusoidal_at(pos_len, d).astype(x.dtype)

    tiered = frame_table is not None
    ranks = None
    if cfg.page_ranks is not None and page_table is not None:
        ranks = jnp.asarray(cfg.page_ranks, jnp.int32)

    scan = uses_scan(cfg)
    hetero = cfg.window_layers is not None and scan
    if tiered and hetero:
        raise ValueError("tiered pools do not compose with per-layer "
                         "window groups (window_layers)")
    packed = slot_idx is not None
    layers_in = cache["layers"]
    if packed:
        sidx = jnp.asarray(slot_idx, jnp.int32)
        layers_in = _slot_gather(layers_in, sidx, scan)

    if scan and not hetero:
        kind = layer_kind(cfg, 0)
        dtypes = jax.tree.map(lambda a: a.dtype, layers_in)
        xs = (params["layers"], _cache_bits(layers_in))
        if ranks is not None:
            xs = xs + (ranks,)

        def body(carry, pc):
            p, cbits = pc[0], pc[1]
            rk = pc[2] if len(pc) > 2 else None
            x, win = carry if tiered else (carry, None)
            c = _cache_unbits(cbits, dtypes)
            x, c, w = _layer_decode(p, c, x, pos_len, cfg, kind,
                                    page_table=page_table,
                                    page_size=page_size, live=live,
                                    frame_table=frame_table, rank=rk)
            if tiered:
                return (x, win | w), _cache_bits(c)
            return x, _cache_bits(c)

        if tiered:
            win0 = jnp.zeros(page_table.shape, bool)
            (x, win), new_bits = jax.lax.scan(body, (x, win0), xs)
        else:
            win = None
            x, new_bits = jax.lax.scan(body, x, xs)
        new_cache = {"layers": _cache_unbits(new_bits, dtypes)}
    elif hetero:
        # per-layer static windows: unroll over the stacked leaves so each
        # layer's mask/kernel window and page-table group row are compile-
        # time constants (these models are shallow; the scan families'
        # compact-HLO concern doesn't bite)
        win = None
        gids = CS.layer_group_ids(cfg)
        kind = layer_kind(cfg, 0)
        new_layers = layers_in
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            c = jax.tree.map(lambda a: a[i], new_layers)
            pt_i = page_table
            if page_table is not None and page_table.ndim == 3:
                pt_i = page_table[:, gids[i]]
            x, c, _ = _layer_decode(
                p, c, x, pos_len, cfg, kind,
                page_table=pt_i, page_size=page_size, live=live,
                rank=None if ranks is None else ranks[i],
                sliding_window=cfg.layer_window(i))
            new_layers = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_index_in_dim(
                    full, one.astype(full.dtype), i, 0), new_layers, c)
        new_cache = {"layers": new_layers}
    else:
        # non-scan families (xlstm) have no paged attention: no tiering
        win = None
        new_list = []
        x_cur = x
        for i in range(cfg.n_layers):
            x_cur, c, _ = _layer_decode(params["layers"][i],
                                        layers_in[i],
                                        x_cur, pos_len, cfg,
                                        layer_kind(cfg, i),
                                        page_table=page_table,
                                        page_size=page_size, live=live)
            new_list.append(c)
        x = x_cur
        new_cache = {"layers": new_list}

    if packed:
        new_cache = {"layers": _slot_scatter(cache["layers"],
                                             new_cache["layers"],
                                             sidx, scan)}
    x = L.norm_apply(params["final_norm"], x)
    logits = L.unembed_apply(params["embed"], x[:, None], cfg)[:, 0]
    if tiered:
        return logits, win, new_cache
    return logits, new_cache


def _sinusoidal_at(pos, d):
    import numpy as np
    i = jnp.arange(d // 2)[None]
    ang = pos[:, None].astype(jnp.float32) / jnp.power(
        10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def prefill(params, cfg: ModelConfig, tokens, smax: int, *, frames=None,
            patches=None, cache_dtype=jnp.bfloat16):
    """Process a prompt, returning (logits_last (B,V), cache, pos_len)."""
    b, s = tokens.shape
    cache = init_cache(cfg, b, smax, cache_dtype)
    x = L.embed_apply(params["embed"], tokens, cfg)
    positions = jnp.arange(s)[None]
    if cfg.vision_tokens and patches is not None:
        vis = L.dot(patches.astype(x.dtype),
                    params["vision_adapter"].astype(x.dtype))
        x = jnp.concatenate([vis, x[:, : s - cfg.vision_tokens]], axis=1)
    if (not cfg.rope or cfg.is_encoder_decoder) and cfg.family != "ssm":
        x = x + _sinusoidal(s, cfg.d_model).astype(x.dtype)
    enc_x = _encode(params, frames, cfg) if cfg.is_encoder_decoder else None

    if uses_scan(cfg):
        kind = layer_kind(cfg, 0)

        def body(carry, pc):
            x = carry
            p, c = pc
            h = L.norm_apply(p["ln1"], x)
            if kind in ("dense", "moe", "hybrid", "dec"):
                a, new_attn = B.attn_prefill(p["attn"], c["attn"], h,
                                             positions, cfg)
                c = dict(c)
                c["attn"] = new_attn
                if kind == "hybrid":
                    sy, xz_states = _mamba_prefill(p["ssm"], h, cfg)
                    c["ssm"] = xz_states
                    a = 0.5 * (L.norm_apply(p["ln_ssm"], a) +
                               L.norm_apply(p["ln_ssm"], sy))
                x = x + a
                if kind == "dec" and cfg.is_encoder_decoder:
                    ek, ev = _enc_kv(p, enc_x, cfg)
                    c["cross_k"] = ek.astype(c["cross_k"].dtype)
                    c["cross_v"] = ev.astype(c["cross_v"].dtype)
                    hx = L.norm_apply(p["ln_x"], x)
                    q, _, _ = B._qkv(p["xattn"], hx, cfg)
                    from repro.core.attention import cross_attention
                    o = cross_attention(q, ek, ev)
                    x = x + L.dot(o.reshape(b, s, cfg.q_dim),
                                  p["xattn"]["wo"].astype(x.dtype))
                h = L.norm_apply(p["ln2"], x)
                if kind == "moe":
                    y, _ = B.moe_apply(p["moe"], h, cfg)
                else:
                    y = L.mlp_apply(p["mlp"], h, cfg)
                x = x + y
            return x, c

        x, new_layers = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
        cache = {"layers": new_layers}
    else:
        # ssm family: prefill == run the recurrence, keep final states.
        # The train-path scans already carry exactly the decode state, so we
        # take their final carry instead of re-scanning the prompt through
        # the decode cell token-by-token (§Perf X2: removes a 32768-step
        # while loop and its per-step collectives per layer).
        for i in range(cfg.n_layers):
            kind = layer_kind(cfg, i)
            p = params["layers"][i]
            h = L.norm_apply(p["ln1"], x)
            fn = B.mlstm_train if kind == "mlstm" else B.slstm_train
            y, st = fn(p["ssm"], h, cfg, return_state=True)
            cache["layers"][i]["ssm"] = st
            x = x + y
            h2 = L.norm_apply(p["ln2"], x)
            x = x + L.mlp_apply(p["mlp"], h2, cfg)

    x = L.norm_apply(params["final_norm"], x[:, -1:])
    logits = L.unembed_apply(params["embed"], x, cfg)[:, 0]
    pos_len = jnp.full((b,), s, jnp.int32)
    return logits, cache, pos_len


def prefill_chunk(params, cfg: ModelConfig, cache, tokens, pos_start,
                  n_valid, page_table, page_size: int, *, slot=None,
                  frame_row=None):
    """One step of a paged, chunked prefill for a single request — driven
    by the CacheSpec table, so every family serves through it.

    tokens (1, C) — a fixed-size chunk whose first ``n_valid`` entries are
    real prompt tokens at logical positions ``pos_start .. pos_start+C-1``
    (the rest is zero padding, written to the trash page). Per component:

      PagedAttn/WindowPagedAttn — the chunk's K/V scatter through
          ``page_table`` ((1, max_pages) or (max_pages,)) into the shared
          pool; attention runs causally over the cached prefix plus the
          chunk (blocks.attn_prefill_chunk, exact via Lemma 4.1).
      StateSlot — the slot's recurrent state (mamba / mLSTM / sLSTM) is
          carried across chunks: pad tokens leave it untouched, so chunked
          prefill reproduces the one-shot recurrence exactly.
      CrossAttnStatic — read-only (written at admission); the chunk's
          cross-attention queries attend the slot's full encoder K/V.

    Returns (logits (1, V) for token ``n_valid - 1`` of the chunk,
    new_cache). ``pos_start``/``n_valid``/``slot`` are traced scalars —
    one trace serves every chunk of every request in any slot.

    Prefix caching (state-free families): chunks fully covered by cached
    pages are *skipped entirely* — the scheduler starts the query stream
    at the first uncached token, so the first call may have ``pos_start``
    anywhere in the prompt over a table whose earlier entries are shared
    physical pages. This composes with chunking because cached pages
    already hold storage-basis keys: the prefix scores below are taken in
    that basis regardless of who wrote the rows (Lemma 4.1 — scoring is
    unaffected), so a cache-hit run is exact, not approximate.

    ``frame_row (max_pages,)`` (tiered pools): device frame of each table
    entry. Prefill is exact attention over the whole prefix, so the
    scheduler promotes *all* of the slot's pages before each chunk; here
    the frame row simply redirects the K/V writes and gathers while the
    latent sidecar is written through the logical ``table_row``."""
    CS.assert_pageable(cfg)
    if cfg.window_layers is not None:
        # per-layer table groups: the table is (n_groups, max_pages) (or
        # batch-1 of it); each layer slices its group's row below
        table_row = page_table[0] if page_table.ndim == 3 else page_table
    else:
        table_row = page_table[0] if page_table.ndim == 2 else page_table
    if frame_row is not None and frame_row.ndim == 2:
        frame_row = frame_row[0]
    ranks = (jnp.asarray(cfg.page_ranks, jnp.int32)
             if cfg.page_ranks is not None else None)
    slot = jnp.int32(0) if slot is None else jnp.asarray(slot, jnp.int32)
    b, c = tokens.shape
    x = L.embed_apply(params["embed"], tokens, cfg)
    positions = pos_start + jnp.arange(c)
    if (not cfg.rope or cfg.is_encoder_decoder) and cfg.family != "ssm":
        x = x + _sinusoidal_at(positions, cfg.d_model)[None].astype(x.dtype)

    def slot_take(a):
        return jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0)

    def slot_put(full, one):
        return jax.lax.dynamic_update_slice_in_dim(
            full, one.astype(full.dtype), slot, axis=0)

    if uses_scan(cfg):
        kind = layer_kind(cfg, 0)

        def body_at(x, p, cc, rk, trow, sw):
            cc = dict(cc)
            h = L.norm_apply(p["ln1"], x)
            a, new_attn = B.attn_prefill_chunk(p["attn"], cc["attn"], h,
                                               pos_start, n_valid, cfg,
                                               table_row=trow,
                                               page_size=page_size,
                                               frame_row=frame_row,
                                               rank=rk,
                                               sliding_window=sw)
            cc["attn"] = new_attn
            if kind == "hybrid":
                st = jax.tree.map(slot_take, cc["ssm"])
                sy, new_st = B.mamba_prefill_chunk(p["ssm"], st, h,
                                                   n_valid, cfg)
                cc["ssm"] = jax.tree.map(slot_put, cc["ssm"], new_st)
                a = 0.5 * (L.norm_apply(p["ln_ssm"], a) +
                           L.norm_apply(p["ln_ssm"], sy))
            x = x + a
            if kind == "dec" and cfg.is_encoder_decoder:
                ek = slot_take(cc["cross_k"])
                ev = slot_take(cc["cross_v"])
                if "cross_k_scale" in cc:
                    ek = ek.astype(jnp.float32) \
                        * slot_take(cc["cross_k_scale"])[:, None, None, None]
                    ev = ev.astype(jnp.float32) \
                        * slot_take(cc["cross_v_scale"])[:, None, None, None]
                ek, ev = ek.astype(x.dtype), ev.astype(x.dtype)
                hx = L.norm_apply(p["ln_x"], x)
                q, _, _ = B._qkv(p["xattn"], hx, cfg)
                from repro.core.attention import cross_attention
                o = cross_attention(q, ek, ev)
                x = x + L.dot(o.reshape(b, c, cfg.q_dim),
                              p["xattn"]["wo"].astype(x.dtype))
            h = L.norm_apply(p["ln2"], x)
            if kind == "moe":
                y, _ = B.moe_apply(p["moe"], h, cfg)
            else:
                y = L.mlp_apply(p["mlp"], h, cfg)
            return x + y, cc

        if cfg.window_layers is not None:
            # unrolled: each layer's window is static and its K/V scatter
            # goes through its page-table group's row
            gids = CS.layer_group_ids(cfg)
            new_layers = cache["layers"]
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                cc = jax.tree.map(lambda a: a[i], new_layers)
                trow = (table_row[gids[i]] if table_row.ndim == 2
                        else table_row)
                x, cc = body_at(x, p, cc,
                                None if ranks is None else ranks[i],
                                trow, cfg.layer_window(i))
                new_layers = jax.tree.map(
                    lambda full, one: jax.lax.dynamic_update_index_in_dim(
                        full, one.astype(full.dtype), i, 0),
                    new_layers, cc)
            new_cache = {"layers": new_layers}
        else:
            xs = (params["layers"], cache["layers"])
            if ranks is not None:
                xs = xs + (ranks,)

            def body(x, pc):
                rk = pc[2] if len(pc) > 2 else None
                return body_at(x, pc[0], pc[1], rk, table_row, None)

            x, new_layers = jax.lax.scan(body, x, xs)
            new_cache = {"layers": new_layers}
    else:
        # ssm family (xlstm): no pages at all — the chunk runs the
        # recurrences from the slot's carried state, masking pad tokens
        new_list = []
        for i in range(cfg.n_layers):
            kind = layer_kind(cfg, i)
            p = params["layers"][i]
            cc = dict(cache["layers"][i])
            st = jax.tree.map(slot_take, cc["ssm"])
            h = L.norm_apply(p["ln1"], x)
            fn = B.mlstm_train if kind == "mlstm" else B.slstm_train
            y, new_st = fn(p["ssm"], h, cfg, return_state=True,
                           initial_state=st, n_valid=n_valid)
            cc["ssm"] = jax.tree.map(slot_put, cc["ssm"], new_st)
            new_list.append(cc)
            x = x + y
            h2 = L.norm_apply(p["ln2"], x)
            x = x + L.mlp_apply(p["mlp"], h2, cfg)
        new_cache = {"layers": new_list}

    x_last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
    x_last = L.norm_apply(params["final_norm"], x_last)
    logits = L.unembed_apply(params["embed"], x_last, cfg)[:, 0]
    return logits, new_cache


def copy_cache_page(cfg: ModelConfig, cache, src_page, dst_page,
                    page_size: int, src_frame=None, dst_frame=None):
    """Copy-on-write over a paged cache: duplicate physical page ``src``'s
    rows into ``dst`` in every paged-attention layer's K and V pool.

    The scheduler calls this when a request sharing a cached tail page
    must diverge (its next token lands mid-page in rows another request /
    the prefix index still reads): the rows read so far move to a private
    page, the table entry is repointed, and only then does the request
    write. ``src_page``/``dst_page`` are traced scalars — one trace serves
    every COW.

    Tiered pools: the full-D K/V rows live at ``src_frame``/``dst_frame``
    (both pages must be RESIDENT) while the latent sidecar copies by
    logical page id."""
    from repro.serving import paged_cache as PC
    src = jnp.asarray(src_page, jnp.int32)
    dst = jnp.asarray(dst_page, jnp.int32)

    def cp(attn):
        if src_frame is not None:
            sf = jnp.asarray(src_frame, jnp.int32)
            df = jnp.asarray(dst_frame, jnp.int32)
            return {"k": PC.copy_page_rows(attn["k"], sf, df, page_size),
                    "v": PC.copy_page_rows(attn["v"], sf, df, page_size),
                    "k_lat": PC.copy_page_rows(attn["k_lat"], src, dst,
                                               page_size)}
        out = {"k": PC.copy_page_rows(attn["k"], src, dst, page_size),
               "v": PC.copy_page_rows(attn["v"], src, dst, page_size)}
        if "k_scale" in attn:   # quantized layout: the codes only stay a
            # faithful dequant of the donor if the scale rides along
            out["k_scale"] = PC.copy_page_scale(attn["k_scale"], src, dst)
            out["v_scale"] = PC.copy_page_scale(attn["v_scale"], src, dst)
        return out

    if uses_scan(cfg):
        layers = dict(cache["layers"])
        if "attn" in layers:
            # (L, R, Hkv, D): vmap the row copy over the stacked layer axis
            layers["attn"] = jax.vmap(cp)(layers["attn"])
        return {"layers": layers}
    out = []
    for lc in cache["layers"]:
        if "attn" in lc:
            lc = {**lc, "attn": cp(lc["attn"])}
        out.append(lc)
    return {"layers": out}


def promote_page_rows(cfg: ModelConfig, cache, k_rows, v_rows, frame,
                      page_size: int):
    """Land a promoted page's host-tier full-D rows in its staging frame
    (tiered pools, DESIGN.md §13). ``k_rows (L, page_size, Hkv, kw)`` /
    ``v_rows (L, page_size, Hkv, D)`` are the bytes captured at demotion;
    ``frame`` is the frame ``PagePool.promote_begin`` handed out. The
    latent sidecar is untouched — it never left the device."""
    layers = dict(cache["layers"])
    attn = dict(layers["attn"])
    row = jnp.asarray(frame, jnp.int32) * page_size

    def dus(pool, rows):
        return jax.lax.dynamic_update_slice_in_dim(
            pool, rows.astype(pool.dtype), row, axis=1)

    attn["k"] = dus(attn["k"], k_rows)
    attn["v"] = dus(attn["v"], v_rows)
    layers["attn"] = attn
    return {"layers": layers}


def encode_cross_kv(params, cfg: ModelConfig, frames):
    """Encoder K/V for every decoder layer (the CrossAttnStatic component).

    Runs the encoder once over ``frames (B, enc_seq, d_model)`` and
    projects the hidden states with each decoder layer's cross-attention
    weights. Returns (k, v), each (L, B, enc_seq, Hkv, D) — written into a
    request's slot once at admission by the paged engine."""
    enc_x = _encode(params, frames, cfg)

    def body(carry, p):
        k, v = _enc_kv(p, enc_x, cfg)
        return carry, (k, v)

    _, (ks, vs) = jax.lax.scan(body, 0, params["layers"])
    return ks, vs


def _mamba_prefill(p, x, cfg):
    s = cfg.ssm
    b = x.shape[0]
    d_in = s.expand * cfg.d_model
    xz = L.dot(x, p["in_proj"].astype(x.dtype))
    conv0 = jnp.zeros((b, s.conv_width - 1, d_in), x.dtype)
    ssm0 = jnp.zeros((b, d_in, s.state_dim), jnp.float32)
    y, conv, ssm = B._mamba_scan(p, xz, conv0, ssm0, cfg)
    y = L.dot(y, p["out_proj"].astype(x.dtype))
    return y, {"conv": conv, "ssm": ssm}

