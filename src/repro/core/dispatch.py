"""Backend selection for the Loki decode hot path (DESIGN.md §5).

One chokepoint decides, per decode step, which implementation of block-
granular Loki runs:

  backend="xla"    — the pure-jnp reference (``loki.loki_decode_block``),
                     paper-faithful per-head selection; lowers everywhere.
  backend="pallas" — the fused GQA-batched kernels (group-shared selection,
                     DESIGN.md §4), with ``kernels/tuning.py`` picking the
                     single-pass vs two-kernel variant and block size. Off
                     TPU the kernels run in interpret mode (how CI validates
                     them); on TPU they compile through Mosaic.
  backend="auto"   — "pallas" on TPU, "xla" elsewhere.

Shapes no kernel plan covers fall back to jnp *with the kernel's group-
shared selection semantics*, so a given backend choice is numerically
consistent across shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LokiConfig
from repro.core import attention as attn
from repro.core import baselines, loki
from repro.kernels import ops, tuning

BACKENDS = ("auto", "pallas", "xla")

# Backends disabled at runtime after a failure (graceful degradation,
# DESIGN.md §11): when a fused-Pallas decode aborts mid-serving, the
# engine reports it here and every subsequent ``resolve_backend`` routes
# to the XLA path instead — the process keeps serving on the slow-but-
# sound implementation rather than dying or flapping. Process-wide on
# purpose: a kernel that aborted once on this host will abort again.
_DISABLED: dict = {}          # backend -> reason


def disable_backend(backend: str, reason: str = "") -> None:
    """Mark a backend failed; resolve_backend avoids it from now on."""
    if backend not in BACKENDS or backend == "auto":
        raise ValueError(f"cannot disable backend {backend!r}")
    _DISABLED[backend] = reason or "runtime failure"


def enable_backend(backend: str) -> None:
    """Clear a failure mark (tests, or operator-driven recovery)."""
    _DISABLED.pop(backend, None)


def backend_disabled(backend: str) -> Optional[str]:
    """The failure reason if ``backend`` is disabled, else None."""
    return _DISABLED.get(backend)


def resolve_backend(backend: str, platform: Optional[str] = None) -> str:
    """'auto' | 'pallas' | 'xla' -> the concrete backend for this host,
    skipping backends disabled by an earlier runtime failure (the XLA
    reference path is never disabled — it is the floor of the
    degradation ladder)."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown loki backend {backend!r}; have {BACKENDS}")
    if backend == "auto":
        platform = platform or jax.default_backend()
        backend = "pallas" if platform == "tpu" else "xla"
    if backend == "pallas" and "pallas" in _DISABLED:
        return "xla"
    return backend


def _token_fallback(q_rope, k_hat_cache, v_cache, cur_len, proj, cfg,
                    *, sliding_window, logit_scale, page_table, page_size,
                    k_scale=None, v_scale=None):
    """Token-granular jnp path; gathers the logical view first when paged
    (dequantizing through the per-page scale sidecars when present)."""
    if page_table is not None:
        from repro.serving.paged_cache import gather_logical_dq
        k_hat_cache = gather_logical_dq(k_hat_cache, k_scale,
                                        page_table, page_size)
        v_cache = gather_logical_dq(v_cache, v_scale, page_table, page_size)
    return loki.loki_decode(q_rope, k_hat_cache, v_cache, cur_len, proj,
                            cfg, sliding_window=sliding_window,
                            logit_scale=logit_scale)


def loki_block_decode(q_rope, k_hat_cache, v_cache, cur_len, proj,
                      cfg: LokiConfig, *, sliding_window: int = 0,
                      logit_scale=None, page_table=None, page_size: int = 0,
                      k_scale=None, v_scale=None,
                      interpret: Optional[bool] = None):
    """Block-granular Loki decode through the configured backend.

    q_rope (B,H,D); k_hat_cache (B,Smax,Hkv,W) with W <= D the stored
    latent key width (rank-r PageLayout truncation; W = D full basis);
    v_cache (B,Smax,Hkv,D); cur_len (B,) or scalar; proj (Hkv,D,D).
    Returns (B,H,D).

    ``sliding_window`` and ``cfg.local_window`` are honored identically on
    every backend (the token path's semantics). With ``page_table``/
    ``page_size`` the caches are the serving engine's shared page pools
    (R,Hkv,·): the Pallas kernels index their block DMAs through the table,
    the jnp paths gather the logical view through the same table. Quantized
    layouts pass the pools' per-page f32 ``k_scale``/``v_scale`` sidecars;
    every path dequantizes behind its DMA/gather, never in HBM."""
    backend = resolve_backend(cfg.backend)
    paged = page_table is not None
    b, h = q_rope.shape[0], q_rope.shape[1]
    if paged:
        n_kv, kd = k_hat_cache.shape[-2], k_hat_cache.shape[-1]
        dim = v_cache.shape[-1]
        smax = page_table.shape[1] * page_size
    else:
        _, smax, n_kv, kd = k_hat_cache.shape
        dim = v_cache.shape[-1]
    g = h // n_kv
    if logit_scale is None and kd < dim:
        # rank-r keys: the softmax temperature is set by the true head_dim,
        # not the truncated key width — pin it before any backend's default
        logit_scale = dim ** -0.5
    d = min(max(int(cfg.d_f * dim), 8), kd)
    plan = tuning.plan_decode(smax, dim, g, d, cfg.block_size,
                              itemsize=jnp.dtype(k_hat_cache.dtype).itemsize)
    if paged and plan is not None and page_size % plan.block_size:
        # kernel DMA blocks must tile pages exactly; otherwise a block could
        # straddle two (non-adjacent) physical pages
        plan = None
    pargs = dict(page_table=page_table, page_size=page_size)
    qargs = dict(k_scale=k_scale, v_scale=v_scale)
    fb_args = dict(sliding_window=sliding_window, logit_scale=logit_scale,
                   page_table=page_table, page_size=page_size, **qargs)

    if backend == "xla":
        if smax % cfg.block_size:
            # short caches (smax < block_size etc.): adopt the planner's
            # dividing block size rather than tripping the reference assert
            if plan is None:
                return _token_fallback(q_rope, k_hat_cache, v_cache,
                                       cur_len, proj, cfg, **fb_args)
            cfg = dataclasses.replace(cfg, block_size=plan.block_size)
        return loki.loki_decode_block(q_rope, k_hat_cache, v_cache, cur_len,
                                      proj, cfg, logit_scale=logit_scale,
                                      sliding_window=sliding_window,
                                      **pargs, **qargs)
    if plan is None:
        # no viable tiling: jnp fallback, keeping the kernel's group-shared
        # selection when the block decomposition exists at all
        if smax % cfg.block_size == 0 and (
                not paged or page_size % cfg.block_size == 0):
            return loki.loki_decode_block(q_rope, k_hat_cache, v_cache,
                                          cur_len, proj, cfg,
                                          logit_scale=logit_scale,
                                          sliding_window=sliding_window,
                                          group_select=True,
                                          **pargs, **qargs)
        return _token_fallback(q_rope, k_hat_cache, v_cache, cur_len, proj,
                               cfg, **fb_args)

    nb = smax // plan.block_size
    k_blocks = max(int(cfg.k_f * nb), 1)
    if sliding_window:
        # a sliding window overlaps at most ceil(w/bs)+1 blocks; selection
        # slots beyond that can only fill with -1 sentinels, so clamping
        # trims dead attention-pass iterations (the kernel's score stream
        # already skips blocks older than the window entirely)
        k_blocks = min(k_blocks,
                       -(-sliding_window // plan.block_size) + 1)
    qg = q_rope.reshape(b, n_kv, g, dim)
    q_hat = jnp.einsum("bhgd,hde->bhge", qg, proj.astype(q_rope.dtype))
    q_hat = q_hat[..., :kd]
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fn = (ops.loki_decode_fused if plan.variant == "fused"
          else ops.loki_decode_two_kernel)
    out = fn(q_hat, k_hat_cache, v_cache, cur, d=d, k_blocks=k_blocks,
             block_size=plan.block_size, scale=logit_scale,
             local_window=cfg.local_window, sliding_window=sliding_window,
             interpret=interpret, **pargs, **qargs)
    return out.reshape(b, h, dim)


def _gathered(k_cache, v_cache, page_table, page_size, k_scale, v_scale):
    """Logical (B,Smax,Hkv,·) views of possibly-pooled caches."""
    if page_table is None:
        return k_cache, v_cache
    from repro.serving.paged_cache import gather_logical_dq
    return (gather_logical_dq(k_cache, k_scale, page_table, page_size),
            gather_logical_dq(v_cache, v_scale, page_table, page_size))


def full_paged_decode(q, k_cache, v_cache, cur_len, *, backend: str = "auto",
                      block_size: int = 128, sliding_window: int = 0,
                      logit_scale=None, page_table=None, page_size: int = 0,
                      k_scale=None, v_scale=None,
                      interpret: Optional[bool] = None):
    """Full-attention decode through the configured backend.

    q (B,H,W) queries already in the storage basis (W <= D the stored key
    width); k_cache (B,Smax,Hkv,W) or pooled (R,Hkv,W) with ``page_table``;
    v_cache (·,Hkv,D). Returns (B,H,D).

    backend="xla" is the bit-preserved reference (gather the logical view,
    ``attention.decode_full``); "pallas" streams live blocks through the
    page table (gather_attention.paged_full_decode) — same math, online
    softmax, so parity is within float tolerance. Shapes with no viable
    tiling fall back to the jnp path."""
    backend = resolve_backend(backend)
    paged = page_table is not None
    b, h = q.shape[0], q.shape[1]
    if paged:
        n_kv, kd = k_cache.shape[-2], k_cache.shape[-1]
        smax = page_table.shape[1] * page_size
    else:
        _, smax, n_kv, kd = k_cache.shape
    dim = v_cache.shape[-1]
    g = h // n_kv
    if logit_scale is None and kd < dim:
        logit_scale = dim ** -0.5

    plan = None
    if backend == "pallas":
        plan = tuning.plan_full_decode(
            smax, dim, g, kd, block_size,
            itemsize=jnp.dtype(k_cache.dtype).itemsize)
        if plan is not None and paged and page_size % plan.block_size:
            plan = None
    if plan is None:
        kc, vc = _gathered(k_cache, v_cache, page_table, page_size,
                           k_scale, v_scale)
        return attn.decode_full(q, kc, vc, cur_len,
                                sliding_window=sliding_window,
                                logit_scale=logit_scale)
    qg = q.reshape(b, n_kv, g, kd)
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out = ops.full_decode(qg, k_cache, v_cache, cur,
                          block_size=plan.block_size, scale=logit_scale,
                          sliding_window=sliding_window,
                          page_table=page_table, page_size=page_size,
                          k_scale=k_scale, v_scale=v_scale,
                          interpret=interpret)
    return out.reshape(b, h, dim)


def exact_topk_paged_decode(q, k_cache, v_cache, cur_len, cfg: LokiConfig,
                            *, logit_scale=None, page_table=None,
                            page_size: int = 0, k_scale=None, v_scale=None,
                            interpret: Optional[bool] = None):
    """Exact-top-k decode through the configured backend.

    backend="xla" is the bit-preserved token-granular reference
    (``baselines.exact_topk_decode`` over the gathered logical view);
    "pallas" fuses the exact score pass with block top-k the same way the
    Loki kernel fuses its approximate pass (score width = full stored key
    width, group-shared selection — ``baselines.exact_topk_decode_block``
    is the jnp oracle and the fallback for kernel-shaped configurations
    no plan covers)."""
    backend = resolve_backend(cfg.backend)
    paged = page_table is not None
    b, h = q.shape[0], q.shape[1]
    if paged:
        n_kv, kd = k_cache.shape[-2], k_cache.shape[-1]
        smax = page_table.shape[1] * page_size
    else:
        _, smax, n_kv, kd = k_cache.shape
    dim = v_cache.shape[-1]
    g = h // n_kv
    if logit_scale is None and kd < dim:
        logit_scale = dim ** -0.5
    pargs = dict(page_table=page_table, page_size=page_size,
                 k_scale=k_scale, v_scale=v_scale)

    if backend == "xla":
        kc, vc = _gathered(k_cache, v_cache, page_table, page_size,
                           k_scale, v_scale)
        return baselines.exact_topk_decode(q, kc, vc, cur_len, cfg,
                                           logit_scale=logit_scale)
    # the exact score pass reads the full stored width: plan with d = kd
    plan = tuning.plan_decode(smax, dim, g, kd, cfg.block_size,
                              itemsize=jnp.dtype(k_cache.dtype).itemsize)
    if plan is not None and paged and page_size % plan.block_size:
        plan = None
    if plan is None:
        if smax % cfg.block_size == 0 and (
                not paged or page_size % cfg.block_size == 0):
            # kernel-shaped fallback: keep the block/group-shared semantics
            return baselines.exact_topk_decode_block(
                q, k_cache, v_cache, cur_len, cfg, logit_scale=logit_scale,
                group_select=True, **pargs)
        kc, vc = _gathered(k_cache, v_cache, page_table, page_size,
                           k_scale, v_scale)
        return baselines.exact_topk_decode(q, kc, vc, cur_len, cfg,
                                           logit_scale=logit_scale)

    nb = smax // plan.block_size
    k_blocks = max(int(cfg.k_f * nb), 1)
    qg = q.reshape(b, n_kv, g, kd)
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if plan.variant == "fused":
        out = ops.exact_topk_decode_fused(
            qg, k_cache, v_cache, cur, k_blocks=k_blocks,
            block_size=plan.block_size, scale=logit_scale,
            interpret=interpret, **pargs)
    else:
        # the two-kernel pair at d = kd scores exactly — select_blocks'
        # "approximate" stream reads the whole key, so this is the same
        # selection as the fused variant
        out = ops.loki_decode_two_kernel(
            qg, k_cache, v_cache, cur, d=kd, k_blocks=k_blocks,
            block_size=plan.block_size, scale=logit_scale,
            local_window=0, sliding_window=0, interpret=interpret, **pargs)
    return out.reshape(b, h, dim)


def loki_tiered_decode(q_rope, k_pool, v_pool, lat_pool, cur_len, proj,
                       cfg: LokiConfig, *, page_table, frame_table,
                       page_size: int, sliding_window: int = 0,
                       logit_scale=None, token_granular: bool = False,
                       interpret: Optional[bool] = None):
    """Tiered Loki decode (DESIGN.md §13) through the configured backend.

    The score/top-k pass reads only the always-resident latent-K sidecar
    ``lat_pool (R_log, Hkv, d)`` through the *logical* ``page_table``;
    exact attention reads winner rows from the frame-sized ``k_pool``/
    ``v_pool (R_dev, Hkv, ·)`` through ``frame_table``. Returns
    (out (B,H,D), winners (B, max_pages) bool).

    Routing mirrors ``loki_block_decode`` decision-for-decision (backend
    resolution, planner adoption of a dividing block size, group-shared
    selection on kernel-shaped fallbacks, token fallback otherwise) so a
    tiered engine selects exactly the pages its single-tier twin attends.
    On the Pallas path the two-kernel composition is used as-is: the
    select kernel's block DMAs index the sidecar via the logical table and
    the attention kernel's via the frame table — no kernel-body changes.
    The single-pass fused variant cannot split its score/attend reads
    across two pools, so tiered always runs the two-kernel pair: bit-
    identical to a single-tier two-kernel run, within float tolerance
    (accumulation order) of a fused one."""
    paged_common = dict(page_table=page_table, frame_table=frame_table,
                        page_size=page_size, sliding_window=sliding_window,
                        logit_scale=logit_scale)
    b, h = q_rope.shape[0], q_rope.shape[1]
    n_kv, kd = k_pool.shape[-2], k_pool.shape[-1]
    dim = v_pool.shape[-1]
    smax = page_table.shape[1] * page_size
    g = h // n_kv
    if logit_scale is None and kd < dim:
        logit_scale = dim ** -0.5
        paged_common["logit_scale"] = logit_scale
    if token_granular:
        # the "loki" policy's paper-faithful token top-k (loki_decode)
        return loki.loki_decode_tiered(q_rope, k_pool, v_pool, lat_pool,
                                       cur_len, proj, cfg,
                                       token_granular=True, **paged_common)
    backend = resolve_backend(cfg.backend)
    d = min(max(int(cfg.d_f * dim), 8), kd)
    plan = tuning.plan_decode(smax, dim, g, d, cfg.block_size,
                              itemsize=jnp.dtype(k_pool.dtype).itemsize)
    if plan is not None and page_size % plan.block_size:
        plan = None
    if backend == "xla":
        if smax % cfg.block_size:
            if plan is None:
                return loki.loki_decode_tiered(
                    q_rope, k_pool, v_pool, lat_pool, cur_len, proj, cfg,
                    token_granular=True, **paged_common)
            cfg = dataclasses.replace(cfg, block_size=plan.block_size)
        return loki.loki_decode_tiered(q_rope, k_pool, v_pool, lat_pool,
                                       cur_len, proj, cfg, **paged_common)
    if plan is None:
        if smax % cfg.block_size == 0 and page_size % cfg.block_size == 0:
            return loki.loki_decode_tiered(q_rope, k_pool, v_pool, lat_pool,
                                           cur_len, proj, cfg,
                                           group_select=True, **paged_common)
        return loki.loki_decode_tiered(q_rope, k_pool, v_pool, lat_pool,
                                       cur_len, proj, cfg,
                                       token_granular=True, **paged_common)

    bs = plan.block_size
    nb = smax // bs
    k_blocks = max(int(cfg.k_f * nb), 1)
    if sliding_window:
        k_blocks = min(k_blocks, -(-sliding_window // bs) + 1)
    qg = q_rope.reshape(b, n_kv, g, dim)
    q_hat = jnp.einsum("bhgd,hde->bhge", qg, proj.astype(q_rope.dtype))
    q_hat = q_hat[..., :kd]
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # Two-kernel composition, one table per tier: the select kernel's
    # score DMAs walk the latent sidecar through the logical page table;
    # the attention kernel re-resolves the winning (logical) blocks
    # through the frame table, reading full-width rows from HBM frames.
    blk_idx = ops.select_blocks(q_hat[..., :d], lat_pool, cur, d=d,
                                k_blocks=k_blocks, block_size=bs,
                                scale=logit_scale,
                                local_window=cfg.local_window,
                                sliding_window=sliding_window,
                                page_table=page_table, page_size=page_size,
                                k_scale=None, interpret=interpret)
    out = ops.block_sparse_attention_grouped(
        q_hat, k_pool, v_pool, blk_idx, cur, block_size=bs,
        scale=logit_scale, sliding_window=sliding_window,
        page_table=frame_table, page_size=page_size,
        k_scale=None, v_scale=None, interpret=interpret)
    valid = blk_idx.reshape(b, -1) >= 0
    pages = jnp.where(valid, blk_idx.reshape(b, -1) * bs // page_size, 0)
    winners = jnp.zeros((b, page_table.shape[1]), bool)
    winners = winners.at[jnp.arange(b)[:, None], pages].max(valid)
    return out.reshape(b, h, dim), winners
