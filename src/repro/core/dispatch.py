"""Backend selection for the Loki decode hot path (DESIGN.md §5).

One chokepoint decides, per decode step, which implementation of block-
granular Loki runs:

  backend="xla"    — the pure-jnp reference (``loki.loki_decode_block``),
                     paper-faithful per-head selection; lowers everywhere.
  backend="pallas" — the fused GQA-batched kernels (group-shared selection,
                     DESIGN.md §4), with ``kernels/tuning.py`` picking the
                     single-pass vs two-kernel variant and block size. Off
                     TPU the kernels run in interpret mode (how CI validates
                     them); on TPU they compile through Mosaic.
  backend="auto"   — "pallas" on TPU, "xla" elsewhere.

Shapes no kernel plan covers fall back to jnp *with the kernel's group-
shared selection semantics*, so a given backend choice is numerically
consistent across shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LokiConfig
from repro.core import loki
from repro.kernels import ops, tuning

BACKENDS = ("auto", "pallas", "xla")


def resolve_backend(backend: str, platform: Optional[str] = None) -> str:
    """'auto' | 'pallas' | 'xla' -> the concrete backend for this host."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown loki backend {backend!r}; have {BACKENDS}")
    if backend == "auto":
        platform = platform or jax.default_backend()
        return "pallas" if platform == "tpu" else "xla"
    return backend


def loki_block_decode(q_rope, k_hat_cache, v_cache, cur_len, proj,
                      cfg: LokiConfig, *, logit_scale=None,
                      interpret: Optional[bool] = None):
    """Block-granular Loki decode through the configured backend.

    q_rope (B,H,D); k_hat_cache/v_cache (B,Smax,Hkv,D); cur_len (B,) or
    scalar; proj (Hkv,D,D). Returns (B,H,D)."""
    backend = resolve_backend(cfg.backend)
    b, smax, n_kv, dim = k_hat_cache.shape
    h = q_rope.shape[1]
    g = h // n_kv
    d = min(max(int(cfg.d_f * dim), 8), dim)
    plan = tuning.plan_decode(smax, dim, g, d, cfg.block_size,
                              itemsize=jnp.dtype(k_hat_cache.dtype).itemsize)

    if backend == "xla":
        if smax % cfg.block_size:
            # short caches (smax < block_size etc.): adopt the planner's
            # dividing block size rather than tripping the reference assert
            if plan is None:
                return loki.loki_decode(q_rope, k_hat_cache, v_cache,
                                        cur_len, proj, cfg,
                                        logit_scale=logit_scale)
            cfg = dataclasses.replace(cfg, block_size=plan.block_size)
        return loki.loki_decode_block(q_rope, k_hat_cache, v_cache, cur_len,
                                      proj, cfg, logit_scale=logit_scale)
    if plan is None:
        # no viable tiling: jnp fallback, keeping the kernel's group-shared
        # selection when the block decomposition exists at all
        if smax % cfg.block_size == 0:
            return loki.loki_decode_block(q_rope, k_hat_cache, v_cache,
                                          cur_len, proj, cfg,
                                          logit_scale=logit_scale,
                                          group_select=True)
        return loki.loki_decode(q_rope, k_hat_cache, v_cache, cur_len, proj,
                                cfg, logit_scale=logit_scale)

    nb = smax // plan.block_size
    k_blocks = max(int(cfg.k_f * nb), 1)
    qg = q_rope.reshape(b, n_kv, g, dim)
    q_hat = jnp.einsum("bhgd,hde->bhge", qg, proj.astype(q_rope.dtype))
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fn = (ops.loki_decode_fused if plan.variant == "fused"
          else ops.loki_decode_two_kernel)
    out = fn(q_hat, k_hat_cache, v_cache, cur, d=d, k_blocks=k_blocks,
             block_size=plan.block_size, scale=logit_scale,
             interpret=interpret)
    return out.reshape(b, h, dim)
