"""Attention: full (train/prefill, memory-bounded chunked softmax) + decode.

Decode-time attention is expressed as pluggable *policies* (full, exact-topk,
Loki, PCAAttn, H2O) — see loki.py / baselines.py. This module holds the shared
math: GQA-aware score computation, chunked causal attention for long
sequences (flash-style online softmax in pure jnp, so it lowers everywhere),
and masking helpers.

Shapes (conventions used throughout the framework):
  q          (B, S, H,   Dh)
  k, v       (B, S, Hkv, Dh)
  kv cache   (B, Smax, Hkv, Dh)
  decode q   (B, H, Dh)        — a single new token per slot
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import constrain

NEG_INF = -1e30


def _group(q, n_kv):
    """(B,S,H,D) -> (B,S,Hkv,G,D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def causal_attention(q, k, v, *, causal=True, sliding_window=0,
                     chunk=512, logit_scale=None):
    """Chunked (online-softmax) attention. Memory O(S * chunk) not O(S^2).

    q (B,S,H,D); k,v (B,S,Hkv,D). Returns (B,S,H,D).
    """
    b, s, h, d = q.shape
    sk = k.shape[1]
    n_kv = k.shape[2]
    scale = logit_scale if logit_scale is not None else d ** -0.5
    qg = _group(q, n_kv) * scale                       # (B,S,Hkv,G,D)
    chunk = min(chunk, s)
    n_chunks = s // chunk if s % chunk == 0 else 1
    if s % chunk != 0:
        chunk = s

    kT = jnp.swapaxes(k, 1, 2)                         # (B,Hkv,Sk,D)
    vT = jnp.swapaxes(v, 1, 2)

    kv_pos = jnp.arange(sk)

    def one_chunk(ci, qc):
        # qc: (B,chunk,Hkv,G,D)
        q_pos = ci * chunk + jnp.arange(chunk)
        qc = constrain(qc, ("batch", "act_seq", "kv_heads", "heads", None))
        scores = jnp.einsum("bchgd,bhsd->bhgcs", qc, kT,
                            preferred_element_type=jnp.float32)
        # TP fallback chain: kv_heads if divisible, else q-group, else the
        # q-chunk (sequence parallel) — spec_for dedups left to right
        scores = constrain(scores,
                           ("batch", "kv_heads", "heads", "act_seq", None))
        mask = jnp.ones((chunk, sk), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if sliding_window:
            mask &= q_pos[:, None] - kv_pos[None, :] < sliding_window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhgcs,bhsd->bchgd", w, vT)
        return constrain(o, ("batch", "act_seq", "kv_heads", "heads", None))

    if n_chunks == 1:
        out = one_chunk(0, qg)
    else:
        qs = qg.reshape(b, n_chunks, chunk, n_kv, h // n_kv, d)
        qs = jnp.swapaxes(qs, 0, 1)                    # (n,B,chunk,Hkv,G,D)
        out = jax.lax.map(lambda args: one_chunk(*args),
                          (jnp.arange(n_chunks), qs))
        out = jnp.swapaxes(out, 0, 1).reshape(b, s, n_kv, h // n_kv, d)
    out = out.reshape(b, s, h, d)
    return constrain(out, ("batch", "seq", "heads", "head_dim"))


def cross_attention(q, k, v, chunk=512):
    return causal_attention(q, k, v, causal=False, chunk=chunk)


# ------------------------------------------------------------ decode scores

def decode_scores(q, k_cache, *, d_slice: Optional[int] = None,
                  logit_scale=None):
    """Scores of one new token against the cache.

    q (B,H,D), k_cache (B,Smax,Hkv,D) -> (B,Hkv,G,Smax) fp32 (unmasked).
    ``d_slice`` restricts the contraction to the first d feature dims
    (Loki's approximate scoring — contiguous slice, the paper's key trick).
    """
    b, h, d = q.shape
    n_kv = k_cache.shape[2]
    scale = logit_scale if logit_scale is not None else d ** -0.5
    qg = q.reshape(b, n_kv, h // n_kv, d)
    if d_slice is not None and d_slice < d:
        qg = qg[..., :d_slice]
        k_cache = k_cache[..., :d_slice]
    return jnp.einsum("bhgd,bshd->bhgs", qg * scale, k_cache,
                      preferred_element_type=jnp.float32)


def length_mask(smax: int, cur_len, extra=None):
    """(Smax,) or (B,1,1,Smax) validity mask for cache positions < cur_len."""
    pos = jnp.arange(smax)
    if jnp.ndim(cur_len) == 0:
        m = pos < cur_len
        return m[None, None, None, :]
    m = pos[None, :] < cur_len[:, None]            # (B,Smax)
    return m[:, None, None, :]


def window_mask(smax: int, cur_len, window: int):
    pos = jnp.arange(smax)
    if jnp.ndim(cur_len) == 0:
        m = pos >= cur_len - window
        return m[None, None, None, :]
    m = pos[None, :] >= (cur_len[:, None] - window)
    return m[:, None, None, :]


def decode_full(q, k_cache, v_cache, cur_len, *, sliding_window=0,
                logit_scale=None):
    """Vanilla decode attention over the whole (valid) cache."""
    scores = decode_scores(q, k_cache, logit_scale=logit_scale)
    m = length_mask(k_cache.shape[1], cur_len)
    if sliding_window:
        m = m & window_mask(k_cache.shape[1], cur_len, sliding_window)
    scores = jnp.where(m, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v_cache)
    b, _, _, d = out.shape
    return out.reshape(b, q.shape[1], d)


def gather_heads(cache, idx):
    """Gather cache rows per (kv-head, group).

    cache (B,S,Hkv,D), idx (B,Hkv,G,K) -> (B,Hkv,G,K,D)."""
    b, s, n_kv, d = cache.shape
    g, k = idx.shape[2], idx.shape[3]
    c = jnp.swapaxes(cache, 1, 2)                      # (B,Hkv,S,D)
    flat = idx.reshape(b, n_kv, g * k)                 # no G-fold broadcast
    out = jnp.take_along_axis(c, flat[..., None], axis=2)
    out = out.reshape(b, n_kv, g, k, d)
    return constrain(out, ("batch", "kv_heads", None, None, None))


def attend_selected(q, k_sel, v_sel, valid, *, logit_scale=None):
    """Exact attention over a selected key subset.

    q (B,H,W); k_sel (B,Hkv,G,K,W); v_sel (B,Hkv,G,K,D); valid
    (B,Hkv,G,K) bool. ``W <= D``: rank-r layouts store truncated latent
    keys, so the output width follows V, not the query."""
    b, h, d = q.shape
    n_kv = k_sel.shape[1]
    scale = logit_scale if logit_scale is not None else d ** -0.5
    qg = q.reshape(b, n_kv, h // n_kv, d) * scale
    scores = jnp.einsum("bhgd,bhgkd->bhgk", qg, k_sel,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(valid, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v_sel.dtype)
    out = jnp.einsum("bhgk,bhgkd->bhgd", w, v_sel)
    return out.reshape(b, h, v_sel.shape[-1])
