"""Loki: PCA-based top-k sparse decode attention (paper Algorithm 1).

The decode KV cache stores keys **in the PCA basis** (K̂ = K_rope @ P, full D
— no memory overhead, Lemma 4.1 makes attention in that basis exact). Each
step:

  1. q̂ = q_rope @ P                                        (O(D²))
  2. approx scores from the first d = d_f·D components      (O(dS))
  3. top-k (k = k_f·S) token indices from approx scores     (O(S log S))
  4. exact attention over the selected keys/values only     (O(2Dk))

Two selection granularities:
  * token (paper-faithful, default for the XLA path / dry-run lowering)
  * block of ``block_size`` tokens (TPU Pallas path — see kernels/, selection
    over per-block score maxima; DESIGN.md §3 justifies the adaptation).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LokiConfig
from repro.core.attention import (NEG_INF, attend_selected, decode_scores,
                                  gather_heads, length_mask, window_mask)


def project_qk(q, k, proj):
    """Rotate post-RoPE q/k into the PCA basis.

    q (B,H,D), k (B,Hkv,D) or (B,S,Hkv,D); proj (Hkv,D,D).
    Query heads use their kv-group's projection."""
    n_kv = proj.shape[0]
    b = q.shape[0]
    h = q.shape[1]
    qg = q.reshape(b, n_kv, h // n_kv, q.shape[-1])
    q_hat = jnp.einsum("bhgd,hde->bhge", qg, proj.astype(q.dtype))
    q_hat = q_hat.reshape(b, h, q.shape[-1])
    if k.ndim == 3:                                  # (B,Hkv,D) single token
        k_hat = jnp.einsum("bhd,hde->bhe", k, proj.astype(k.dtype))
    else:                                            # (B,S,Hkv,D)
        k_hat = jnp.einsum("bshd,hde->bshe", k, proj.astype(k.dtype))
    return q_hat, k_hat


def static_k(cfg: LokiConfig, smax: int) -> int:
    k = max(int(cfg.k_f * smax), cfg.min_k)
    return min(k, smax)


def select_topk(approx_scores, cfg: LokiConfig, cur_len, smax: int):
    """Token-granular selection. approx_scores (B,Hkv,G,S) fp32 (masked).

    Returns (idx (B,Hkv,G,K), valid (B,Hkv,G,K)). K is static (k_f * Smax);
    entries beyond k_f*cur_len are marked invalid so quality tracks the
    *dynamic* budget the paper uses while shapes stay jit-stable."""
    k = static_k(cfg, smax)
    _, idx = jax.lax.top_k(approx_scores, k)
    # dynamic budget: only the first k_f*cur_len (>= min_k) picks are live
    live = jnp.maximum((cfg.k_f * cur_len).astype(jnp.int32), cfg.min_k)
    ranks = jnp.arange(k)
    if jnp.ndim(cur_len) == 0:
        valid = ranks < live
        valid = jnp.broadcast_to(valid, idx.shape)
    else:
        valid = ranks[None, :] < live[:, None]       # (B,K)
        valid = jnp.broadcast_to(valid[:, None, None, :], idx.shape)
    # positions past cur_len were masked to NEG_INF; drop them too
    taken = jnp.take_along_axis(approx_scores, idx, axis=-1)
    valid = valid & (taken > NEG_INF / 2)
    return idx, valid


def loki_decode_chunked(q_rope, k_hat_cache, v_cache, cur_len, proj,
                        cfg: LokiConfig, *, sliding_window: int = 0,
                        logit_scale: Optional[float] = None):
    """Distributed Loki: per-chunk local top-k (k/n_chunks each), exact
    attention over the union of selections.

    With the cache's sequence dim sharded n_chunks-way, every top-k and
    gather is device-local; only (B,H)-sized softmax statistics cross the
    interconnect. Equals global-top-k Loki when the score mass is spread
    (measured in benchmarks/bench_jaccard.py) and is *exact* at k_f=1."""
    from repro.sharding.rules import constrain
    b, h, dim = q_rope.shape
    smax = k_hat_cache.shape[1]
    kd = k_hat_cache.shape[-1]        # stored key width (latent rank <= D)
    nc = cfg.n_chunks
    assert nc > 0 and smax % nc == 0
    sc = smax // nc
    d = min(max(int(cfg.d_f * dim), 8), kd)
    n_kv = proj.shape[0]
    g = h // n_kv

    qg = q_rope.reshape(b, n_kv, g, dim)
    q_hat = jnp.einsum("bhgd,hde->bhge", qg,
                       proj.astype(q_rope.dtype))[..., :kd]
    scale = logit_scale if logit_scale is not None else dim ** -0.5

    # chunk view of the cache: (B, nc, Sc, Hkv, D); nc rides the kv_seq shards
    kc = k_hat_cache.reshape(b, nc, sc, n_kv, kd)
    kc = constrain(kc, ("batch", "kv_seq", None, "kv_heads", None))
    vc = v_cache.reshape(b, nc, sc, n_kv, v_cache.shape[-1])
    vc = constrain(vc, ("batch", "kv_seq", None, "kv_heads", None))

    # approximate scores from the leading d PCA dims, chunk-local
    approx = jnp.einsum("bhgd,bcshd->bhgcs", (q_hat * scale)[..., :d],
                        kc[..., :d],
                        preferred_element_type=jnp.float32)  # (B,Hkv,G,nc,Sc)
    # keep scores batch- and chunk-sharded: without this GSPMD replicates the
    # (B,Hkv,G,nc,Sc) tensor across the data axis to run one global sort
    # (§Perf L1: 10.3 GB all-gather + 14.5 GB sort per step)
    approx = constrain(approx, ("batch", "kv_heads", None, "kv_seq", None))
    pos = jnp.arange(smax).reshape(nc, sc)
    if jnp.ndim(cur_len) == 0:
        live = pos[None] < cur_len
    else:
        live = pos[None] < cur_len[:, None, None]
    live = live[:, None, None]                         # (B,1,1,nc,Sc)
    if sliding_window:
        lo = (cur_len - sliding_window)
        win = (pos[None] >= (lo if jnp.ndim(cur_len) == 0
                             else lo[:, None, None]))[:, None, None]
        live = live & win
    if cfg.local_window:
        rec = (pos[None] >= ((cur_len - cfg.local_window)
                             if jnp.ndim(cur_len) == 0
                             else (cur_len - cfg.local_window)[:, None, None])
               )[:, None, None]
        approx = jnp.where(rec, jnp.float32(1e4) + approx, approx)
    approx = jnp.where(live, approx, NEG_INF)

    kpc = max(static_k(cfg, smax) // nc, 1)            # picks per chunk
    # §Perf L2: argsort-based selection instead of lax.top_k. XLA lowers
    # top_k to an opaque TopK custom-call with no SPMD partitioning rule, so
    # GSPMD all-gathers the full (B,...,S) score tensor to every device and
    # sorts globally. A plain sort HLO partitions over the non-sort dims,
    # keeping selection chunk-local.
    order = jnp.argsort(approx, axis=-1, descending=True)
    idx = order[..., :kpc]                             # (B,Hkv,G,nc,kpc)
    idx = constrain(idx, ("batch", "kv_heads", None, "kv_seq", None))
    top_s = jnp.take_along_axis(approx, idx, axis=-1)
    valid = top_s > NEG_INF / 2

    # chunk-local gathers (operand + index sharded identically on nc)
    kcx = jnp.swapaxes(kc, 2, 3)                       # (B,nc,Hkv,Sc,D)
    vcx = jnp.swapaxes(vc, 2, 3)
    kcx = constrain(kcx, ("batch", "kv_seq", "kv_heads", None, None))
    vcx = constrain(vcx, ("batch", "kv_seq", "kv_heads", None, None))
    idx_g = jnp.moveaxis(idx, 3, 1).reshape(b, nc, n_kv, g * kpc)
    idx_g = constrain(idx_g, ("batch", "kv_seq", "kv_heads", None))
    k_sel = jnp.take_along_axis(kcx, idx_g[..., None], axis=3)
    v_sel = jnp.take_along_axis(vcx, idx_g[..., None], axis=3)
    k_sel = constrain(k_sel, ("batch", "kv_seq", "kv_heads", None, None))
    v_sel = constrain(v_sel, ("batch", "kv_seq", "kv_heads", None, None))
    k_sel = k_sel.reshape(b, nc, n_kv, g, kpc, kd)
    v_sel = v_sel.reshape(b, nc, n_kv, g, kpc, v_cache.shape[-1])

    # exact scores over the union; softmax across (nc, kpc) jointly
    scores = jnp.einsum("bhgd,bchgkd->bhgck", q_hat * scale, k_sel,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(valid, scores, NEG_INF)         # (B,Hkv,G,nc,kpc)
    m = jnp.max(scores, axis=(3, 4), keepdims=True)
    w = jnp.exp(scores - m)
    den = jnp.sum(w, axis=(3, 4), keepdims=True)
    w = (w / jnp.maximum(den, 1e-30)).astype(v_sel.dtype)
    out = jnp.einsum("bhgck,bchgkd->bhgd", w, v_sel)
    return out.reshape(b, h, dim)


def loki_decode(q_rope, k_hat_cache, v_cache, cur_len, proj,
                cfg: LokiConfig, *, sliding_window: int = 0,
                logit_scale: Optional[float] = None):
    """Decode attention with Loki (Algorithm 1, lines 3-9).

    q_rope       (B,H,D)    post-RoPE query (original basis)
    k_hat_cache  (B,Smax,Hkv,W) keys already in PCA basis; W <= D is the
                 stored width (the PageLayout's latent rank under rank-r
                 pages, D otherwise — exact at W == D by Lemma 4.1)
    v_cache      (B,Smax,Hkv,D)
    proj         (Hkv,D,D)  PCA projection for this layer
    Returns (B,H,D).
    """
    b, h, dim = q_rope.shape
    smax = k_hat_cache.shape[1]
    kd = k_hat_cache.shape[-1]
    d = min(max(int(cfg.d_f * dim), 8), kd)
    # sqrt(D) scaling regardless of the stored key width (Algorithm 2)
    scale = logit_scale if logit_scale is not None else dim ** -0.5

    # line 3: rotate the query into the PCA basis (truncated to the
    # stored width — the trailing components have no cached counterpart)
    n_kv = proj.shape[0]
    qg = q_rope.reshape(b, n_kv, h // n_kv, dim)
    q_hat = jnp.einsum("bhgd,hde->bhge", qg, proj.astype(q_rope.dtype))
    q_hat = q_hat.reshape(b, h, dim)[..., :kd]

    # line 5: approximate scores from the leading d PCA components
    approx = decode_scores(q_hat, k_hat_cache, d_slice=d,
                           logit_scale=scale)
    m = length_mask(smax, cur_len)
    if sliding_window:
        m = m & window_mask(smax, cur_len, sliding_window)
    if cfg.local_window:
        # optionally force-include a recency window by inflating its scores
        recent = window_mask(smax, cur_len, cfg.local_window)
        approx = jnp.where(recent, jnp.float32(1e4) + approx, approx)
    approx = jnp.where(m, approx, NEG_INF)

    # lines 6-7: select + gather
    idx, valid = select_topk(approx, cfg, cur_len, smax)
    k_sel = gather_heads(k_hat_cache, idx)
    v_sel = gather_heads(v_cache, idx)

    # lines 8-9: exact attention in the PCA basis over the selection
    return attend_selected(q_hat, k_sel, v_sel, valid,
                           logit_scale=scale)


def loki_decode_block(q_rope, k_hat_cache, v_cache, cur_len, proj,
                      cfg: LokiConfig, *, sliding_window: int = 0,
                      logit_scale=None, group_select: bool = False,
                      page_table=None, page_size: int = 0,
                      k_scale=None, v_scale=None):
    """Block-granular Loki (the TPU-native formulation; jnp reference).

    Selection happens over per-block maxima of the approximate scores, and
    exact attention runs over the union of selected blocks. This is the
    oracle for kernels/gather_attention.py.

    ``sliding_window`` and ``cfg.local_window`` carry the token-granular
    semantics of ``loki_decode``: the sliding window masks positions out of
    both selection and the exact pass; the local window inflates recent
    approximate scores so the recency blocks always win selection.

    ``group_select``: share one block selection across the GQA group (top-k
    of the per-block maxima reduced over the group's query heads). This is
    the semantics of the fused GQA-batched kernel — each selected K̂/V block
    streams from HBM once per *group* instead of once per head (DESIGN.md
    §4) — and the oracle for kernels/fused_decode.py. Identical to per-head
    selection when G == 1.

    With ``page_table (B, max_pages)``/``page_size``, the caches are the
    serving engine's shared pools (R, Hkv, D); this reference gathers the
    logical per-slot view through the same table the fused kernel indexes —
    the jnp oracle for paged decode (DESIGN.md §7)."""
    if page_table is not None:
        from repro.serving.paged_cache import gather_logical_dq
        k_hat_cache = gather_logical_dq(k_hat_cache, k_scale,
                                        page_table, page_size)
        v_cache = gather_logical_dq(v_cache, v_scale,
                                    page_table, page_size)
    b, h, dim = q_rope.shape
    smax = k_hat_cache.shape[1]
    kd = k_hat_cache.shape[-1]        # stored key width (latent rank <= D)
    bs = cfg.block_size
    assert smax % bs == 0, "cache length must be a multiple of block_size"
    d = min(max(int(cfg.d_f * dim), 8), kd)
    n_blocks = smax // bs
    scale = logit_scale if logit_scale is not None else dim ** -0.5

    n_kv = proj.shape[0]
    qg = q_rope.reshape(b, n_kv, h // n_kv, dim)
    q_hat = jnp.einsum("bhgd,hde->bhge", qg, proj.astype(q_rope.dtype))
    q_hat = q_hat.reshape(b, h, dim)[..., :kd]

    approx = decode_scores(q_hat, k_hat_cache, d_slice=d,
                           logit_scale=scale)
    m = length_mask(smax, cur_len)
    if sliding_window:
        m = m & window_mask(smax, cur_len, sliding_window)
    if cfg.local_window:
        # force-include the recency window by inflating its scores, exactly
        # like the token-granular path (block maxima inherit the boost)
        recent = window_mask(smax, cur_len, cfg.local_window)
        approx = jnp.where(recent, jnp.float32(1e4) + approx, approx)
    approx = jnp.where(m, approx, NEG_INF)
    blk = approx.reshape(*approx.shape[:-1], n_blocks, bs).max(-1)

    k_blocks = max(int(cfg.k_f * n_blocks), 1)
    if group_select:
        blk_g = blk.max(axis=2, keepdims=True)          # (B,Hkv,1,nb)
        _, bidx = jax.lax.top_k(blk_g, k_blocks)        # (B,Hkv,1,kb)
        bidx = jnp.broadcast_to(bidx, (*blk.shape[:-1], k_blocks))
        taken = jnp.take_along_axis(blk_g, bidx[:, :, :1], axis=-1)
        bvalid = jnp.broadcast_to(taken > NEG_INF / 2, bidx.shape)
    else:
        _, bidx = jax.lax.top_k(blk, k_blocks)          # (B,Hkv,G,kb)
        taken = jnp.take_along_axis(blk, bidx, axis=-1)
        bvalid = taken > NEG_INF / 2

    # expand block indices -> token indices (kb*bs,)
    tok = bidx[..., None] * bs + jnp.arange(bs)
    idx = tok.reshape(*tok.shape[:-2], k_blocks * bs)
    valid = jnp.broadcast_to(bvalid[..., None], tok.shape)
    valid = valid.reshape(idx.shape)
    valid = valid & (jnp.take_along_axis(approx, idx, axis=-1) > NEG_INF / 2)

    k_sel = gather_heads(k_hat_cache, idx)
    v_sel = gather_heads(v_cache, idx)
    return attend_selected(q_hat, k_sel, v_sel, valid,
                           logit_scale=scale)


def loki_decode_tiered(q_rope, k_pool, v_pool, lat_pool, cur_len, proj,
                       cfg: LokiConfig, *, page_table, frame_table,
                       page_size: int, sliding_window: int = 0,
                       logit_scale=None, token_granular: bool = False,
                       group_select: bool = False):
    """Loki decode over a tiered page pool (DESIGN.md §13; jnp reference).

    The approximate score pass (Algorithm 1 lines 3-5) reads only the
    always-resident latent-K sidecar ``lat_pool (R_log, Hkv, d)`` through
    the *logical* ``page_table`` — its rows are bitwise copies of the
    leading-d columns of the stored keys, so selection is exactly the
    single-tier selection regardless of which full-D pages are resident.
    Exact attention then gathers the winning rows from the frame-sized
    ``k_pool``/``v_pool (R_dev, Hkv, ·)`` through ``frame_table`` (HOST
    pages resolve to the trash frame 0: finite garbage whose scores the
    validity mask sends to NEG_INF — an exact zero after softmax).

    Returns (out (B,H,D), winners (B, max_pages) bool): the union of
    logical pages holding selected-and-valid rows. The engine promotes
    HOST winners and replays — row writes are idempotent full-row
    overwrites, so the replay is exact.

    ``token_granular`` mirrors ``loki_decode``'s selection;
    ``group_select`` mirrors ``loki_decode_block``'s fused-kernel
    semantics. Masks, recency inflation and the dynamic budget are copied
    from those references term for term."""
    from repro.serving.paged_cache import gather_logical_dq
    b, h, dim = q_rope.shape
    max_pages = page_table.shape[1]
    smax = max_pages * page_size
    kd = k_pool.shape[-1]             # stored key width (latent rank <= D)
    d = min(max(int(cfg.d_f * dim), 8), kd)
    assert d == lat_pool.shape[-1], \
        f"latent sidecar width {lat_pool.shape[-1]} != score width {d}"
    scale = logit_scale if logit_scale is not None else dim ** -0.5

    n_kv = proj.shape[0]
    g = h // n_kv
    qg = q_rope.reshape(b, n_kv, g, dim)
    q_hat = jnp.einsum("bhgd,hde->bhge", qg, proj.astype(q_rope.dtype))
    q_hat = q_hat.reshape(b, h, dim)[..., :kd]

    # phase 1: score + select from the resident latent tier only
    k_lat = gather_logical_dq(lat_pool, None, page_table, page_size)
    approx = decode_scores(q_hat, k_lat, d_slice=d, logit_scale=scale)
    m = length_mask(smax, cur_len)
    if sliding_window:
        m = m & window_mask(smax, cur_len, sliding_window)
    if cfg.local_window:
        recent = window_mask(smax, cur_len, cfg.local_window)
        approx = jnp.where(recent, jnp.float32(1e4) + approx, approx)
    approx = jnp.where(m, approx, NEG_INF)

    if token_granular:
        idx, valid = select_topk(approx, cfg, cur_len, smax)
    else:
        bs = cfg.block_size
        assert smax % bs == 0, \
            "cache length must be a multiple of block_size"
        n_blocks = smax // bs
        blk = approx.reshape(*approx.shape[:-1], n_blocks, bs).max(-1)
        k_blocks = max(int(cfg.k_f * n_blocks), 1)
        if group_select:
            blk_g = blk.max(axis=2, keepdims=True)      # (B,Hkv,1,nb)
            _, bidx = jax.lax.top_k(blk_g, k_blocks)    # (B,Hkv,1,kb)
            bidx = jnp.broadcast_to(bidx, (*blk.shape[:-1], k_blocks))
            taken = jnp.take_along_axis(blk_g, bidx[:, :, :1], axis=-1)
            bvalid = jnp.broadcast_to(taken > NEG_INF / 2, bidx.shape)
        else:
            _, bidx = jax.lax.top_k(blk, k_blocks)      # (B,Hkv,G,kb)
            taken = jnp.take_along_axis(blk, bidx, axis=-1)
            bvalid = taken > NEG_INF / 2
        tok = bidx[..., None] * bs + jnp.arange(bs)
        idx = tok.reshape(*tok.shape[:-2], k_blocks * bs)
        valid = jnp.broadcast_to(bvalid[..., None], tok.shape)
        valid = valid.reshape(idx.shape)
        valid = valid & (jnp.take_along_axis(approx, idx, axis=-1)
                         > NEG_INF / 2)

    # winner pages: union over heads/groups of valid selections
    flat_p = (idx // page_size).reshape(b, -1)
    flat_v = valid.reshape(b, -1)
    winners = jnp.zeros((b, max_pages), bool)
    winners = winners.at[jnp.arange(b)[:, None],
                         jnp.where(flat_v, flat_p, 0)].max(flat_v)

    # phase 2: exact attention, winner rows resolved through frame_table
    lpage = idx // page_size
    fid = jnp.take_along_axis(frame_table, lpage.reshape(b, -1),
                              axis=1).reshape(lpage.shape)
    rows = fid * page_size + idx % page_size            # device pool rows
    hsel = jnp.arange(n_kv)[None, :, None, None]
    k_sel = k_pool[rows, hsel]                          # (B,Hkv,G,K,kd)
    v_sel = v_pool[rows, hsel]
    out = attend_selected(q_hat, k_sel, v_sel, valid, logit_scale=scale)
    return out, winners
