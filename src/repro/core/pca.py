"""Offline PCA calibration of attention keys (paper Section 3 + 4.1).

Streaming per-(layer, head) second-moment accumulation over a calibration
run, eigendecomposition into orthogonal projections P (descending explained
variance), and the Rank@v analysis of Figures 1/2.

The calibrator is model-agnostic: the LM forward pass is run with
``capture_keys=True`` which returns pre-rotary and post-rotary keys per layer;
we accumulate E[k k^T] and E[k] in fp64-ish (fp32 running sums) and finalize
covariance eigenvectors offline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class KeyStats:
    """Streaming covariance stats for keys of shape (L, Hkv, D)."""
    sum_outer: np.ndarray   # (L, Hkv, D, D)
    sum_vec: np.ndarray     # (L, Hkv, D)
    count: int

    @classmethod
    def create(cls, n_layers: int, n_kv: int, d: int) -> "KeyStats":
        return cls(np.zeros((n_layers, n_kv, d, d), np.float64),
                   np.zeros((n_layers, n_kv, d), np.float64), 0)

    def update(self, keys) -> None:
        """keys: (L, B, S, Hkv, D) array (one captured forward pass)."""
        k = np.asarray(keys, np.float64)
        l, b, s, h, d = k.shape
        k = np.moveaxis(k, 3, 1).reshape(l, h, b * s, d)
        self.sum_outer += np.einsum("lhnd,lhne->lhde", k, k)
        self.sum_vec += k.sum(axis=2)
        self.count += b * s

    def covariance(self) -> np.ndarray:
        mu = self.sum_vec / max(self.count, 1)
        return (self.sum_outer / max(self.count, 1)
                - np.einsum("lhd,lhe->lhde", mu, mu))


def eig_projections(cov: np.ndarray):
    """Eigendecompose (L,Hkv,D,D) covariances.

    Returns (P, eigvals): P (L,Hkv,D,D) with components as *columns* ordered by
    descending eigenvalue (so ``k @ P`` puts high-variance dims first), and the
    normalized eigenvalue spectra (L,Hkv,D), descending.
    """
    w, v = np.linalg.eigh(cov)          # ascending
    w = w[..., ::-1]
    v = v[..., ::-1]
    w = np.maximum(w, 0.0)
    w_norm = w / np.maximum(w.sum(axis=-1, keepdims=True), 1e-12)
    return v.astype(np.float32), w_norm.astype(np.float32)


def rank_at(eigvals: np.ndarray, v: float = 0.90) -> np.ndarray:
    """Rank_{l,h}@v of Eq. (2): smallest d with cumulative variance >= v."""
    c = np.cumsum(eigvals, axis=-1)
    return (c < v).sum(axis=-1) + 1


@dataclasses.dataclass
class PCACalibration:
    """Result of a calibration pass: projections for both candidate transforms
    (paper Section 4.1 — Lemma 4.1 holds for any orthogonal P, so both the
    pre-rotary and post-rotary covariance eigenbases are applied to post-RoPE
    q/k at inference; which works better is model-dependent)."""
    proj_pre: np.ndarray        # (L, Hkv, D, D)
    proj_post: np.ndarray
    eig_pre: np.ndarray         # (L, Hkv, D) normalized, descending
    eig_post: np.ndarray

    def projections(self, transform: str) -> np.ndarray:
        return self.proj_pre if transform == "pre" else self.proj_post

    def rank_at(self, v: float = 0.90, transform: str = "post") -> np.ndarray:
        eig = self.eig_pre if transform == "pre" else self.eig_post
        return rank_at(eig, v)

    def save(self, path: str) -> None:
        np.savez(path, proj_pre=self.proj_pre, proj_post=self.proj_post,
                 eig_pre=self.eig_pre, eig_post=self.eig_post)

    @classmethod
    def load(cls, path: str) -> "PCACalibration":
        z = np.load(path)
        return cls(z["proj_pre"], z["proj_post"], z["eig_pre"], z["eig_post"])

    @classmethod
    def identity(cls, n_layers: int, n_kv: int, d: int) -> "PCACalibration":
        eye = np.broadcast_to(np.eye(d, dtype=np.float32),
                              (n_layers, n_kv, d, d)).copy()
        flat = np.full((n_layers, n_kv, d), 1.0 / d, np.float32)
        return cls(eye, eye.copy(), flat, flat.copy())


def calibrate(forward_capture, batches, n_layers: int, n_kv: int,
              d: int) -> PCACalibration:
    """Run ``forward_capture(batch) -> (pre_keys, post_keys)`` over calibration
    batches, each (L,B,S,Hkv,D), and produce both candidate transforms."""
    st_pre = KeyStats.create(n_layers, n_kv, d)
    st_post = KeyStats.create(n_layers, n_kv, d)
    for batch in batches:
        pre, post = forward_capture(batch)
        st_pre.update(pre)
        st_post.update(post)
    p_pre, e_pre = eig_projections(st_pre.covariance())
    p_post, e_post = eig_projections(st_post.covariance())
    return PCACalibration(p_pre, p_post, e_pre, e_post)


def calibrate_model(params, cfg, token_batches, frames=None) -> PCACalibration:
    """Calibrate PCA transforms for an LM by capturing its keys over token
    batches (each (B,S) int32). The model-agnostic entry point examples and
    benchmarks use. ``frames``: encoder inputs for encoder-decoder models
    (whisper), shared across batches."""
    from repro.models import lm

    @jax.jit
    def capture(tokens):
        _, _, (pre, post, _q) = lm.forward(params, tokens, cfg,
                                           frames=frames, capture_keys=True)
        return pre, post

    def fwd(tokens):
        pre, post = capture(tokens)
        return np.asarray(pre), np.asarray(post)

    return calibrate(fwd, token_batches, cfg.n_layers, cfg.n_kv_heads,
                     cfg.resolved_head_dim)


def install_projections(params, calib: "PCACalibration",
                        transform: str = "pre"):
    """Return params with each attention block's ``pca`` leaf replaced by the
    calibrated projection (stacked (L,Hkv,D,D) for scan models, per-layer
    slices otherwise). Everything else is shared by reference."""
    proj = jnp.asarray(calib.projections(transform))
    layers = params["layers"]
    new = dict(params)
    if isinstance(layers, list):
        out = []
        for i, p in enumerate(layers):
            if "attn" in p:
                p = dict(p)
                attn = dict(p["attn"])
                # same cast as the scan branch below: without it a
                # non-f32 param tree gets an f32 pca leaf that breaks
                # dtype-strict consumers (checkpoint layouts, donation)
                attn["pca"] = proj[i].astype(attn["pca"].dtype)
                p["attn"] = attn
            out.append(p)
        new["layers"] = out
    else:
        lt = dict(layers)
        attn = dict(lt["attn"])
        attn["pca"] = proj.astype(attn["pca"].dtype)
        lt["attn"] = attn
        new["layers"] = lt
    return new
