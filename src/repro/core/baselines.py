"""Sparse-attention baselines the paper compares against (Section 5, Table 1).

* exact top-k — full-dimensionality scores, then top-k (quality upper bound
  for Loki; no speedup).
* H2O — heavy-hitter token eviction with a fixed-budget cache (half heavy
  hitters by accumulated attention mass, half recent), permanent deletion.
* PCAAttn — appendix E ablation: attention computed *directly* from the
  truncated d-dim PCA keys (known to fail; reproduced as a negative control).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LokiConfig
from repro.core.attention import (NEG_INF, attend_selected, decode_full,
                                  decode_scores, gather_heads, length_mask)
from repro.core.loki import select_topk


def exact_topk_decode(q_rope, k_cache, v_cache, cur_len, cfg: LokiConfig,
                      *, logit_scale=None):
    """Top-k over *exact* scores, exact attention over the selection."""
    smax = k_cache.shape[1]
    scores = decode_scores(q_rope, k_cache, logit_scale=logit_scale)
    scores = jnp.where(length_mask(smax, cur_len), scores, NEG_INF)
    idx, valid = select_topk(scores, cfg, cur_len, smax)
    k_sel = gather_heads(k_cache, idx)
    v_sel = gather_heads(v_cache, idx)
    return attend_selected(q_rope, k_sel, v_sel, valid,
                           logit_scale=logit_scale)


def pcaattn_decode(q_rope, k_hat_cache_d, v_cache, cur_len, proj,
                   cfg: LokiConfig, *, logit_scale=None):
    """Appendix E: softmax over truncated-basis scores directly.

    k_hat_cache_d (B,Smax,Hkv,d) stores ONLY the first d PCA dims (this
    variant does shrink the K half of the cache by d/D)."""
    b, h, dim = q_rope.shape
    d = k_hat_cache_d.shape[-1]
    n_kv = proj.shape[0]
    qg = q_rope.reshape(b, n_kv, h // n_kv, dim)
    q_hat = jnp.einsum("bhgd,hde->bhge", qg,
                       proj[..., :d].astype(q_rope.dtype))
    q_hat = q_hat.reshape(b, h, d)
    # NOTE scores scaled by sqrt(D) (paper Algorithm 2 line 6), not sqrt(d)
    scale = logit_scale if logit_scale is not None else dim ** -0.5
    scores = decode_scores(q_hat, k_hat_cache_d, logit_scale=scale)
    scores = jnp.where(length_mask(k_hat_cache_d.shape[1], cur_len),
                       scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v_cache)
    return out.reshape(b, h, v_cache.shape[-1])


# ----------------------------------------------------------------- H2O

class H2OState(NamedTuple):
    """Fixed-budget eviction cache. Slots [0, budget)."""
    k: jax.Array          # (B, budget, Hkv, D)
    v: jax.Array          # (B, budget, Hkv, D)
    pos: jax.Array        # (B, budget) original positions, -1 = empty
    acc: jax.Array        # (B, Hkv, budget) accumulated attention mass
    fill: jax.Array       # (B,) number of live slots


def h2o_init(batch, budget, n_kv, d, dtype=jnp.bfloat16) -> H2OState:
    return H2OState(
        k=jnp.zeros((batch, budget, n_kv, d), dtype),
        v=jnp.zeros((batch, budget, n_kv, d), dtype),
        pos=jnp.full((batch, budget), -1, jnp.int32),
        acc=jnp.zeros((batch, n_kv, budget), jnp.float32),
        fill=jnp.zeros((batch,), jnp.int32),
    )


def h2o_decode(q_rope, k_new, v_new, state: H2OState, step, *,
               recent_frac=0.5, logit_scale=None):
    """One H2O decode step: attend over the budget cache, accumulate scores,
    insert the new token (evicting the weakest non-recent heavy hitter when
    full). Returns (out (B,H,D), new_state).

    step: (B,) or scalar current position of the new token.
    """
    b, h, d = q_rope.shape
    budget = state.k.shape[1]
    n_kv = state.k.shape[2]
    step = jnp.broadcast_to(jnp.asarray(step, jnp.int32), (b,))

    # 1. insert new token first (so it can be attended this step)
    full = state.fill >= budget
    recent_slots = int(budget * recent_frac)
    # eviction candidates: non-recent region by original position rank.
    # slots are kept unsorted; "recent" = pos within (step - recent_slots).
    is_recent = state.pos >= (step[:, None] - recent_slots)
    score_for_evict = state.acc.mean(axis=1)                   # (B,budget)
    score_for_evict = jnp.where(is_recent | (state.pos < 0),
                                jnp.inf, score_for_evict)
    evict_slot = jnp.argmin(score_for_evict, axis=-1)          # (B,)
    slot = jnp.where(full, evict_slot, state.fill)

    def put(arr, upd):
        return arr.at[jnp.arange(b), slot].set(upd.astype(arr.dtype))

    k_cache = put(state.k, k_new)
    v_cache = put(state.v, v_new)
    pos = state.pos.at[jnp.arange(b), slot].set(step)
    acc = jnp.swapaxes(state.acc, 1, 2).at[jnp.arange(b), slot].set(0.0)
    acc = jnp.swapaxes(acc, 1, 2)
    fill = jnp.minimum(state.fill + 1, budget)

    # 2. attend over live slots
    scale = logit_scale if logit_scale is not None else d ** -0.5
    qg = q_rope.reshape(b, n_kv, h // n_kv, d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg * scale, k_cache,
                        preferred_element_type=jnp.float32)
    live = pos >= 0                                            # (B,budget)
    scores = jnp.where(live[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w.astype(v_cache.dtype), v_cache)

    # 3. accumulate attention mass (mean over query groups, the H2O oracle)
    acc = acc + w.mean(axis=2)
    return (out.reshape(b, h, d),
            H2OState(k_cache, v_cache, pos, acc, fill))
