"""Sparse-attention baselines the paper compares against (Section 5, Table 1).

* exact top-k — full-dimensionality scores, then top-k (quality upper bound
  for Loki; no speedup).
* H2O — heavy-hitter token eviction with a fixed-budget cache (half heavy
  hitters by accumulated attention mass, half recent), permanent deletion.
* PCAAttn — appendix E ablation: attention computed *directly* from the
  truncated d-dim PCA keys (known to fail; reproduced as a negative control).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LokiConfig
from repro.core.attention import (NEG_INF, attend_selected, decode_full,
                                  decode_scores, gather_heads, length_mask,
                                  window_mask)
from repro.core.loki import select_topk


def exact_topk_decode(q_rope, k_cache, v_cache, cur_len, cfg: LokiConfig,
                      *, logit_scale=None):
    """Top-k over *exact* scores, exact attention over the selection."""
    smax = k_cache.shape[1]
    scores = decode_scores(q_rope, k_cache, logit_scale=logit_scale)
    scores = jnp.where(length_mask(smax, cur_len), scores, NEG_INF)
    idx, valid = select_topk(scores, cfg, cur_len, smax)
    k_sel = gather_heads(k_cache, idx)
    v_sel = gather_heads(v_cache, idx)
    return attend_selected(q_rope, k_sel, v_sel, valid,
                           logit_scale=logit_scale)


def exact_topk_decode_block(q, k_cache, v_cache, cur_len, cfg: LokiConfig,
                            *, logit_scale=None, sliding_window: int = 0,
                            group_select: bool = True,
                            page_table=None, page_size: int = 0,
                            k_scale=None, v_scale=None):
    """Block-granular exact top-k (TPU-native formulation; the jnp oracle
    for ``kernels/fused_decode.fused_exact_topk_decode``).

    Selection runs over per-block maxima of the *exact* full-width scores
    — the same adaptation ``loki.loki_decode_block`` makes for the
    approximate path, minus the d-slice and minus recency inflation (the
    baseline has neither). ``group_select`` shares one block selection
    across the GQA group, the fused kernel's semantics. With
    ``page_table``/``page_size`` the caches are the serving engine's
    shared pools (R, Hkv, ·) and this reference gathers the logical view
    through the same table the kernel indexes."""
    if page_table is not None:
        from repro.serving.paged_cache import gather_logical_dq
        k_cache = gather_logical_dq(k_cache, k_scale, page_table, page_size)
        v_cache = gather_logical_dq(v_cache, v_scale, page_table, page_size)
    smax = k_cache.shape[1]
    bs = cfg.block_size
    assert smax % bs == 0, "cache length must be a multiple of block_size"
    n_blocks = smax // bs

    scores = decode_scores(q, k_cache, logit_scale=logit_scale)
    m = length_mask(smax, cur_len)
    if sliding_window:
        m = m & window_mask(smax, cur_len, sliding_window)
    scores = jnp.where(m, scores, NEG_INF)
    blk = scores.reshape(*scores.shape[:-1], n_blocks, bs).max(-1)

    k_blocks = max(int(cfg.k_f * n_blocks), 1)
    if group_select:
        blk_g = blk.max(axis=2, keepdims=True)          # (B,Hkv,1,nb)
        _, bidx = jax.lax.top_k(blk_g, k_blocks)        # (B,Hkv,1,kb)
        bidx = jnp.broadcast_to(bidx, (*blk.shape[:-1], k_blocks))
        taken = jnp.take_along_axis(blk_g, bidx[:, :, :1], axis=-1)
        bvalid = jnp.broadcast_to(taken > NEG_INF / 2, bidx.shape)
    else:
        _, bidx = jax.lax.top_k(blk, k_blocks)          # (B,Hkv,G,kb)
        taken = jnp.take_along_axis(blk, bidx, axis=-1)
        bvalid = taken > NEG_INF / 2

    tok = bidx[..., None] * bs + jnp.arange(bs)
    idx = tok.reshape(*tok.shape[:-2], k_blocks * bs)
    valid = jnp.broadcast_to(bvalid[..., None], tok.shape)
    valid = valid.reshape(idx.shape)
    valid = valid & (jnp.take_along_axis(scores, idx, axis=-1) > NEG_INF / 2)

    k_sel = gather_heads(k_cache, idx)
    v_sel = gather_heads(v_cache, idx)
    return attend_selected(q, k_sel, v_sel, valid, logit_scale=logit_scale)


def pcaattn_decode(q_rope, k_hat_cache_d, v_cache, cur_len, proj,
                   cfg: LokiConfig, *, logit_scale=None):
    """Appendix E: softmax over truncated-basis scores directly.

    k_hat_cache_d (B,Smax,Hkv,d) stores ONLY the first d PCA dims (this
    variant does shrink the K half of the cache by d/D)."""
    b, h, dim = q_rope.shape
    d = k_hat_cache_d.shape[-1]
    n_kv = proj.shape[0]
    qg = q_rope.reshape(b, n_kv, h // n_kv, dim)
    q_hat = jnp.einsum("bhgd,hde->bhge", qg,
                       proj[..., :d].astype(q_rope.dtype))
    q_hat = q_hat.reshape(b, h, d)
    # NOTE scores scaled by sqrt(D) (paper Algorithm 2 line 6), not sqrt(d)
    scale = logit_scale if logit_scale is not None else dim ** -0.5
    scores = decode_scores(q_hat, k_hat_cache_d, logit_scale=scale)
    scores = jnp.where(length_mask(k_hat_cache_d.shape[1], cur_len),
                       scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v_cache)
    return out.reshape(b, h, v_cache.shape[-1])


# ----------------------------------------------------------------- H2O

class H2OState(NamedTuple):
    """Fixed-budget eviction cache. Slots [0, budget)."""
    k: jax.Array          # (B, budget, Hkv, D)
    v: jax.Array          # (B, budget, Hkv, D)
    pos: jax.Array        # (B, budget) original positions, -1 = empty
    acc: jax.Array        # (B, Hkv, budget) accumulated attention mass
    fill: jax.Array       # (B,) number of live slots


def h2o_init(batch, budget, n_kv, d, dtype=jnp.bfloat16) -> H2OState:
    return H2OState(
        k=jnp.zeros((batch, budget, n_kv, d), dtype),
        v=jnp.zeros((batch, budget, n_kv, d), dtype),
        pos=jnp.full((batch, budget), -1, jnp.int32),
        acc=jnp.zeros((batch, n_kv, budget), jnp.float32),
        fill=jnp.zeros((batch,), jnp.int32),
    )


def h2o_decode(q_rope, k_new, v_new, state: H2OState, step, *,
               recent_frac=0.5, logit_scale=None):
    """One H2O decode step: attend over the budget cache, accumulate scores,
    insert the new token (evicting the weakest non-recent heavy hitter when
    full). Returns (out (B,H,D), new_state).

    step: (B,) or scalar current position of the new token.
    """
    b, h, d = q_rope.shape
    budget = state.k.shape[1]
    n_kv = state.k.shape[2]
    step = jnp.broadcast_to(jnp.asarray(step, jnp.int32), (b,))

    # 1. insert new token first (so it can be attended this step)
    full = state.fill >= budget
    recent_slots = int(budget * recent_frac)
    # eviction candidates: non-recent region by original position rank.
    # slots are kept unsorted; "recent" = pos within (step - recent_slots).
    is_recent = state.pos >= (step[:, None] - recent_slots)
    score_for_evict = state.acc.mean(axis=1)                   # (B,budget)
    score_for_evict = jnp.where(is_recent | (state.pos < 0),
                                jnp.inf, score_for_evict)
    evict_slot = jnp.argmin(score_for_evict, axis=-1)          # (B,)
    slot = jnp.where(full, evict_slot, state.fill)

    def put(arr, upd):
        return arr.at[jnp.arange(b), slot].set(upd.astype(arr.dtype))

    k_cache = put(state.k, k_new)
    v_cache = put(state.v, v_new)
    pos = state.pos.at[jnp.arange(b), slot].set(step)
    acc = jnp.swapaxes(state.acc, 1, 2).at[jnp.arange(b), slot].set(0.0)
    acc = jnp.swapaxes(acc, 1, 2)
    fill = jnp.minimum(state.fill + 1, budget)

    # 2. attend over live slots
    scale = logit_scale if logit_scale is not None else d ** -0.5
    qg = q_rope.reshape(b, n_kv, h // n_kv, d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg * scale, k_cache,
                        preferred_element_type=jnp.float32)
    live = pos >= 0                                            # (B,budget)
    scores = jnp.where(live[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w.astype(v_cache.dtype), v_cache)

    # 3. accumulate attention mass (mean over query groups, the H2O oracle)
    acc = acc + w.mean(axis=2)
    return (out.reshape(b, h, d),
            H2OState(k_cache, v_cache, pos, acc, fill))
