"""Markdown report generator over experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.utils.report [--dir experiments/dryrun]

Emits the §Dry-run and §Roofline tables consumed by EXPERIMENTS.md.
TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI, 16 GB HBM.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

HBM_GB = 16.0

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        r["_file"] = os.path.basename(f)
        recs.append(r)
    return recs


def _key(r):
    return (r["arch"], SHAPE_ORDER.index(r["shape"]), r["mesh"], r["policy"])


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{1e3*x:.2f}ms"
    return f"{1e6*x:.1f}us"


def dryrun_table(recs: List[Dict]) -> str:
    out = ["| arch | shape | mesh | policy | compile | peak mem/dev | "
           "fits 16G | collectives (count) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=_key):
        mem = (r.get("peak_mem_per_device") or 0) / 1e9
        cc = r.get("collective_counts", {})
        cstr = " ".join(f"{k.replace('collective-','c-')}:{int(v)}"
                        for k, v in sorted(cc.items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['policy']} | "
            f"{r.get('compile_seconds', 0):.1f}s | {mem:.1f} GB | "
            f"{'Y' if mem <= HBM_GB else 'N'} | {cstr} |")
    return "\n".join(out)


def roofline_table(recs: List[Dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | policy | t_comp | t_mem | t_coll | bound | "
           "useful=MODEL/HLO | MFU@bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=_key):
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['policy']} | "
            f"{fmt_s(r['t_compute'])} | {fmt_s(r['t_memory'])} | "
            f"{fmt_s(r['t_collective'])} | **{r['bottleneck'][:4]}** | "
            f"{r['useful_flops_fraction']:.2f} | {r['mfu_bound']*100:.1f}% |")
    return "\n".join(out)


def bottleneck_notes(recs: List[Dict], mesh: str = "16x16") -> str:
    """One sentence per cell on what would move the dominant term."""
    notes = []
    for r in sorted(recs, key=_key):
        if r["mesh"] != mesh:
            continue
        b = r["bottleneck"]
        if r["shape"] == "train_4k" and b == "memory":
            n = ("memory-bound: remat re-reads dominate — relax remat policy "
                 "or raise arithmetic intensity with larger per-device batch")
        elif r["shape"].startswith("decode") or r["shape"] == "long_500k":
            if b == "memory":
                n = ("memory-bound (expected: decode IS KV-bandwidth-bound) "
                     "— Loki's d_f/k_f byte cut is the lever; next: "
                     "feature-major cache layout / quantized cache")
            elif b == "collective":
                n = ("collective-bound: shard KV over fewer axes or move "
                     "top-k to chunk-local selection")
            else:
                n = "compute-bound decode: batch large enough to feed MXU"
        elif b == "compute":
            n = ("compute-bound: good — push MFU via fusion/layout; "
                 "check useful-fraction for remat waste")
        elif b == "collective":
            n = ("collective-bound: overlap collectives with compute, "
                 "gradient compression on cross-pod axis")
        else:
            n = "memory-bound: increase per-device arithmetic intensity"
        notes.append(f"- **{r['arch']} {r['shape']} ({r['policy']})**: {n}")
    return "\n".join(notes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments",
        "dryrun"))
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "notes"])
    args = ap.parse_args()
    recs = load(args.dir)
    if args.section in ("all", "dryrun"):
        print("### Dry-run (all cells, both meshes)\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline (single-pod 16x16)\n")
        print(roofline_table(recs))
        print()
    if args.section in ("all", "notes"):
        print("### Bottleneck notes\n")
        print(bottleneck_notes(recs))


if __name__ == "__main__":
    main()
