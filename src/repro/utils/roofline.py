"""Roofline term derivation from compiled dry-run artifacts.

Hardware model: TPU v5e —
  peak compute   197 TFLOP/s bf16 per chip
  HBM bandwidth  819 GB/s per chip
  ICI link       ~50 GB/s per link

Terms (per step, seconds):
  compute    = FLOPs / (chips × peak)
  memory     = bytes / (chips × bw)
  collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` of an SPMD-partitioned executable reports *per-device*
numbers, so we use them directly against single-chip peaks (equivalent to
global/chips).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    policy: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float                  # 6·N·D (global, analytic)
    chips: int
    peak_mem_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (global). >1 impossible; <<1 = waste."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization if the step ran exactly at the dominant
        roofline term (the score we hillclimb)."""
        if self.t_bound <= 0:
            return 0.0
        return (self.model_flops / self.chips / self.t_bound) / PEAK_FLOPS

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "policy": self.policy,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops": self.model_flops, "chips": self.chips,
            "peak_mem_per_device": self.peak_mem_per_device,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
        }


def count_params(cfg) -> float:
    """Total (dense-equivalent) and active parameter counts."""
    d, f, v, l = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    hd = cfg.resolved_head_dim
    attn = d * (cfg.n_heads * hd) * 2 + d * (cfg.n_kv_heads * hd) * 2
    gated = cfg.mlp in ("swiglu", "geglu")
    if cfg.moe:
        fe = cfg.moe.d_ff_expert
        per_expert = d * fe * (3 if gated else 2)
        mlp_total = cfg.moe.n_experts * per_expert + d * cfg.moe.n_experts
        mlp_active = cfg.moe.top_k * per_expert + d * cfg.moe.n_experts
    else:
        mlp_total = mlp_active = d * f * (3 if gated else 2)
    if cfg.family == "ssm":
        di = 2 * d
        mlp_total = mlp_active = d * 2 * d * (3 if gated else 2)
        attn = 4 * d * d + 2 * d * di       # lstm projections (approx)
    if cfg.family == "hybrid":
        di = cfg.ssm.expand * d
        attn += 2 * d * di + di * d         # mamba in/out proj
    emb = v * d
    total = l * (attn + mlp_total) + emb
    active = l * (attn + mlp_active) + emb
    return total, active


def model_flops(cfg, shape) -> float:
    """6·N_active·D for train; 2·N_active·B for one decode token; prefill
    like train forward (2·N·D)."""
    total, active = count_params(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        return 2.0 * active * tokens
    # decode: one token per slot
    return 2.0 * active * shape.global_batch
