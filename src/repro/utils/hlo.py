"""Loop-weighted cost accounting over compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts every while-loop body exactly once, which
under-counts lax.scan-over-layers (and sequence scans) by the trip count. XLA
annotates ``backend_config={"known_trip_count":{"n":...}}`` on while ops, so
we parse the HLO, build the call graph (while body/cond, fusion calls,
to_apply), weight every computation by the product of trip counts on the path
from ENTRY, and accumulate:

  * flops            — dot ops: 2 × |result| × contraction size (dots are
                       >99% of model flops; elementwise ignored)
  * bytes accessed   — operand + result bytes of top-level ops, with
                       slice-awareness: dynamic-slice reads only the slice,
                       dynamic-update-slice writes only the update (KV-cache
                       appends, scan param slicing), and fusions that merely
                       slice a big operand charge the slice, not the buffer
  * collective bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute

All numbers are per-device (the partitioned module is per-device).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\](?:\{[\d,:TSE()]*\})?")
_COMMENT = re.compile(r"/\*.*?\*/")
_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OPCODE = re.compile(r"\s*([\w\-]+)\(")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*?\)\s*->\s*.*\{")


def _parse_instr_line(line: str):
    """Parse '%name = TYPE opcode(args...), attrs' robustly (TYPE may be a
    huge tuple containing parens/commas). Returns None or
    (name, type_str, opcode, rest)."""
    line = _COMMENT.sub("", line)
    m = _NAME_EQ.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):          # tuple type: find the balanced close
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str, rest = rest[:i + 1], rest[i + 1:]
                    break
        else:
            return None
    else:                             # array type: up to first space
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp:]
    mo = _OPCODE.match(rest)
    if not mo:
        return None
    return name, type_str, mo.group(1), rest[mo.end():]
_CALLS = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')


def parse_shape(s: str) -> Tuple[Optional[Tuple[str, Tuple[int, ...]]], int]:
    """First (dtype, dims) in s, and total bytes of all shapes in s."""
    total = 0
    first = None
    for dtype, dims in _SHAPE_TOKEN.findall(s):
        if dtype not in DTYPE_BYTES:
            continue
        d = tuple(int(x) for x in dims.split(",") if x.strip())
        n = 1
        for x in d:
            n *= x
        total += n * DTYPE_BYTES[dtype]
        if first is None:
            first = (dtype, d)
    return first, total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_shape: Optional[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str
    param_idx: int = -1


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]


def _split_operands(argstr: str) -> Tuple[List[str], str, str]:
    """Split 'a, b, c), attrs' -> (operand names, inner text, attrs)."""
    depth = 1
    for i, ch in enumerate(argstr):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner, attrs = argstr[:i], argstr[i + 1:]
                ops = re.findall(r"%([\w\.\-]+)", inner)
                return ops, inner, attrs
    return re.findall(r"%([\w\.\-]+)", argstr), argstr, ""


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    current: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip())
        if hdr and "->" in line:
            name = hdr.group(2)
            current = Computation(name, {})
            comps[name] = current
            if hdr.group(1):
                entry = name
            continue
        if current is None or line.strip() == "}":
            continue
        parsed = _parse_instr_line(line)
        if parsed is None:
            continue
        iname, shape_str, opcode, rest = parsed
        operands, inner, attrs = _split_operands(rest)
        rshape, rbytes = parse_shape(shape_str)
        ins = Instr(iname, opcode, rbytes, rshape, operands, attrs)
        if opcode == "parameter":
            try:
                ins.param_idx = int(inner.strip())
            except ValueError:
                pass
        current.instrs[iname] = ins
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation) -> float:
    if ins.opcode != "dot" or ins.result_shape is None:
        return 0.0
    out_elems = math.prod(ins.result_shape[1]) if ins.result_shape[1] else 1
    lhs = comp.instrs.get(ins.operands[0]) if ins.operands else None
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    contraction = 1
    if lhs is not None and lhs.result_shape is not None and cdims:
        dims = [int(x) for x in cdims.group(1).split(",") if x.strip()]
        for d in dims:
            if d < len(lhs.result_shape[1]):
                contraction *= lhs.result_shape[1][d]
    return 2.0 * out_elems * contraction


def _weights(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """Execution count per computation (product of trip counts from ENTRY)."""
    w: Dict[str, float] = defaultdict(float)

    def visit(cname: str, mult: float, depth=0):
        w[cname] += mult
        comp = comps.get(cname)
        if comp is None or depth > 16:
            return
        for ins in comp.instrs.values():
            callees = _CALLS.findall(ins.attrs)
            if not callees:
                continue
            trip = 1.0
            if ins.opcode == "while":
                mt = _TRIP.search(ins.attrs)
                trip = float(mt.group(1)) if mt else 1.0
            for callee in set(callees):
                visit(callee, mult * trip, depth + 1)

    visit(entry, 1.0)
    return dict(w)


def _param_slice_bytes(comp: Computation) -> Dict[int, int]:
    """For a fused computation: param indices that are only consumed as the
    sliced operand of (dynamic-)slice ops -> bytes actually read."""
    users: Dict[str, List[Instr]] = defaultdict(list)
    for ins in comp.instrs.values():
        for op in ins.operands:
            users[op].append(ins)
    out: Dict[int, int] = {}
    for ins in comp.instrs.values():
        if ins.opcode != "parameter" or ins.param_idx < 0:
            continue
        us = users.get(ins.name, [])
        if not us:
            out[ins.param_idx] = 0
            continue
        total = 0
        ok = True
        for u in us:
            if u.opcode in ("dynamic-slice", "slice") and \
                    u.operands and u.operands[0] == ins.name:
                total += u.result_bytes
            elif u.opcode == "dynamic-update-slice" and \
                    u.operands and u.operands[0] == ins.name:
                # in-place update: the buffer itself isn't streamed
                total += 0
            else:
                ok = False
                break
        if ok:
            out[ins.param_idx] = total
    return out


def _instr_bytes(ins: Instr, comp: Computation,
                 comps: Dict[str, Computation]) -> int:
    """HBM bytes for one top-level instruction (slice-aware)."""
    def opsize(name: str) -> int:
        src = comp.instrs.get(name)
        return src.result_bytes if src is not None else 0

    oc = ins.opcode
    if oc in ("dynamic-slice", "slice", "gather"):
        return 2 * ins.result_bytes
    if oc == "dynamic-update-slice":
        upd = opsize(ins.operands[1]) if len(ins.operands) > 1 else 0
        return 2 * upd
    if oc == "scatter":
        upd = opsize(ins.operands[2]) if len(ins.operands) > 2 else 0
        return 2 * upd + (opsize(ins.operands[1])
                          if len(ins.operands) > 1 else 0)
    if oc == "fusion":
        m = _CALLS.search(ins.attrs)
        callee = comps.get(m.group(1)) if m else None
        sliced = _param_slice_bytes(callee) if callee else {}
        total = ins.result_bytes
        # in-place dus fusions: result aliases operand 0
        if callee is not None and any(
                i.opcode == "dynamic-update-slice"
                for i in callee.instrs.values()):
            total = 0
            for i in callee.instrs.values():
                if i.opcode == "dynamic-update-slice":
                    total += 2 * (callee.instrs[i.operands[1]].result_bytes
                                  if len(i.operands) > 1 and
                                  i.operands[1] in callee.instrs else 0)
        for idx, opname in enumerate(ins.operands):
            if idx in sliced:
                total += sliced[idx]
            else:
                total += opsize(opname)
        return total
    # default: all operands + result
    return sum(opsize(o) for o in ins.operands) + ins.result_bytes


@dataclasses.dataclass
class HLOCost:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collectives: Dict[str, float]
    collective_counts: Dict[str, float]
    weights: Dict[str, float]


def analyze(text: str) -> HLOCost:
    comps, entry = parse_module(text)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else ""
    weights = _weights(comps, entry)

    fused_names = set()
    for comp in comps.values():
        for ins in comp.instrs.values():
            if ins.opcode == "fusion":
                m = _CALLS.search(ins.attrs)
                if m:
                    fused_names.add(m.group(1))
            else:
                for callee in _CALLS.findall(ins.attrs):
                    if ins.opcode in ("reduce", "reduce-window", "sort",
                                      "scatter", "select-and-scatter",
                                      "map", "all-reduce", "reduce-scatter"):
                        fused_names.add(callee)

    flops = 0.0
    nbytes = 0.0
    coll: Dict[str, float] = defaultdict(float)
    coll_counts: Dict[str, float] = defaultdict(float)

    for cname, comp in comps.items():
        wt = weights.get(cname, 0.0)
        if wt == 0.0:
            continue
        interior = cname in fused_names
        for ins in comp.instrs.values():
            f = _dot_flops(ins, comp)
            if f:
                flops += f * wt
            if interior or ins.opcode in FREE_OPS:
                continue
            base = ins.opcode.split(".")[0]
            if base in ("while", "conditional", "call"):
                continue  # attributed inside callees
            b = _instr_bytes(ins, comp, comps)
            nbytes += b * wt
            for kind in COLLECTIVES:
                if ins.opcode.startswith(kind):
                    op_b = sum(
                        comp.instrs[o].result_bytes for o in ins.operands
                        if o in comp.instrs)
                    coll[kind] += op_b * wt
                    coll_counts[kind] += wt
                    break
    return HLOCost(flops, nbytes, sum(coll.values()), dict(coll),
                   dict(coll_counts), weights)


def collective_bytes(text: str, default_trip: int = 1):
    """Compatibility helper returning (bytes_by_kind, counts_by_kind)."""
    cost = analyze(text)
    return cost.collectives, cost.collective_counts


def top_bytes(text: str, n: int = 25):
    """Top-n instructions by loop-weighted HBM bytes — the hillclimb's
    profiler stand-in. Returns [(weighted_bytes, opcode, comp, name,
    result_shape_str)]."""
    comps, entry = parse_module(text)
    if entry is None:
        return []
    weights = _weights(comps, entry)
    fused_names = set()
    for comp in comps.values():
        for ins in comp.instrs.values():
            if ins.opcode == "fusion":
                m = _CALLS.search(ins.attrs)
                if m:
                    fused_names.add(m.group(1))
    rows = []
    for cname, comp in comps.items():
        wt = weights.get(cname, 0.0)
        if wt == 0.0 or cname in fused_names:
            continue
        for ins in comp.instrs.values():
            if ins.opcode in FREE_OPS or ins.opcode in ("while",
                                                        "conditional",
                                                        "call"):
                continue
            b = _instr_bytes(ins, comp, comps) * wt
            if b:
                rows.append((b, ins.opcode, cname, ins.name,
                             str(ins.result_shape)))
    rows.sort(reverse=True)
    return rows[:n]
