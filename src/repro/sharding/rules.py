"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Arrays in the framework carry *logical* axis names; the rules map logical
names to mesh axes. A logical axis is only sharded when the dimension size is
divisible by the product of the mapped mesh axes — otherwise it silently falls
back to replication for that dimension (e.g. kv_heads=2 on a 16-way ``model``
axis). This keeps one rule table valid across all 10 assigned architectures.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

# Default rule table. "fsdp" rides the data axis (ZeRO-3 style), tensor
# parallel dims ride the model axis, batch rides every pure-DP axis.
DEFAULT_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),
    "seq": None,
    # sequence-parallel fallback: the q-chunk dim of attention scores takes
    # the model axis when no head dim divides it (hymba: 25 heads = 5x5 on a
    # 16-way axis). Dedup order in the constraint tuple makes this automatic.
    "act_seq": "model",
    "act_embed": None,
    "embed": "data",              # FSDP shard of the embed/row dim of weights
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv": "model",
    "vocab": "model",
    "expert": "model",
    "moe_group": ("pod", "data"),   # token-group dim of the MoE dispatch
    # fallback compute shard for MoE when n_experts doesn't divide the model
    # axis (granite: 40 experts, 16-way axis): the expert-capacity dim takes
    # the axis instead (spec_for dedups, first divisible axis wins)
    "expert_capacity": "model",
    # decode-time KV cache sequence dim. Tuple + dedup gives the right
    # sharding at both batch regimes: decode_32k (B=128 takes "data", the
    # cache seq gets "model" = 16-way) and long_500k (B=1 takes nothing,
    # the 512k-token cache shards over BOTH axes = 256-way).
    "kv_seq": ("data", "model"),
    "kv_seq_long": ("data", "model"),  # alias (kept for config overrides)
    "head_dim": None,
    "state": None,
    "conv": None,
    "pos": None,
}


def _mesh_axis_size(mesh: Mesh, ax: AxisVal) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax] if ax in mesh.axis_names else 0
    return math.prod(_mesh_axis_size(mesh, a) for a in ax)


def _present(mesh: Mesh, ax: AxisVal) -> Optional[AxisVal]:
    """Drop mesh axes not present in this mesh (e.g. 'pod' on single-pod)."""
    if ax is None:
        return None
    if isinstance(ax, str):
        return ax if ax in mesh.axis_names else None
    kept = tuple(a for a in ax if a in mesh.axis_names)
    return kept if kept else None


def spec_for(
    logical_axes: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    mesh: Optional[Mesh] = None,
    rules: Optional[Dict[str, AxisVal]] = None,
    allow_padded: bool = False,
) -> P:
    """Map logical axis names to a PartitionSpec, honoring divisibility.

    ``shape`` and ``mesh`` are optional; when given, any dimension that is not
    divisible by its mapped mesh-axis product is replicated instead.
    """
    rules = dict(DEFAULT_RULES, **(rules or {}))
    cands = []
    for name in logical_axes:
        ax = rules.get(name) if name else None
        if mesh is not None:
            ax = _present(mesh, ax)
        cands.append(ax)
    out = [None] * len(cands)
    used: set = set()

    def _claim(i, ax, mode):
        """Try to give dim i mesh axes `ax` (minus already-used ones)."""
        flat = (ax,) if isinstance(ax, str) else tuple(ax)
        flat = tuple(a for a in flat if a not in used)
        if not flat:
            return None
        ax = flat[0] if len(flat) == 1 else flat
        if shape is not None and mesh is not None:
            if i >= len(shape):      # logical axes longer than tensor rank
                return None
            n = _mesh_axis_size(mesh, ax)
            dim = shape[i]
            if n == 0:
                return None
            if mode == "exact" and dim % n != 0:
                return None
            if mode == "padded":
                # second chance for non-divisible dims: GSPMD pads; accept
                # when padding waste is bounded (24 heads on 16 -> pad 32,
                # 1.33x; but kv_heads=2 on 16 -> 8x, rejected)
                if dim % n == 0 or dim < n:
                    return None
                if (-(-dim // n) * n) / dim > 1.5:
                    return None
        for a in ((ax,) if isinstance(ax, str) else ax):
            used.add(a)
        return ax

    checked = shape is not None and mesh is not None
    # pass 1: dims that divide their mesh axes exactly claim them, in order
    for i, ax in enumerate(cands):
        if ax is not None:
            out[i] = _claim(i, ax, "exact" if checked else "any")
    # pass 2: leftover axes go to dims where padded sharding still wins.
    # Padded (non-divisible) specs are only legal as sharding *constraints*
    # (GSPMD pads internally) -- jit input shardings must divide exactly.
    if checked and allow_padded:
        for i, ax in enumerate(cands):
            if ax is not None and out[i] is None:
                out[i] = _claim(i, ax, "padded")
    # trim trailing Nones for tidier specs
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(mesh: Mesh, logical_axes: Sequence[Optional[str]],
                   shape: Optional[Sequence[int]] = None,
                   rules: Optional[Dict[str, AxisVal]] = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logical_axes, shape, mesh, rules))


def tree_specs(logical_tree, shapes_tree, mesh: Mesh, rules=None):
    """Map a pytree of logical-axis tuples + matching ShapeDtypeStruct tree to
    a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda ax, s: spec_for(ax, s.shape, mesh, rules),
        logical_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(logical_tree, shapes_tree, mesh: Mesh, rules=None):
    specs = tree_specs(logical_tree, shapes_tree, mesh, rules)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _current_mesh():
    """The active mesh, across jax versions: ``get_abstract_mesh`` where it
    exists (>= 0.5), else the thread-local physical mesh (0.4.x)."""
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        return get_am()
    from jax._src.mesh import thread_resources
    return thread_resources.env.physical_mesh


def use_mesh(mesh):
    """Context manager activating ``mesh`` for sharding constraints, across
    jax versions: ``jax.sharding.set_mesh`` where it exists (>= 0.5), else
    the Mesh object itself (a context manager in 0.4.x)."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def constrain(x, logical_axes: Sequence[Optional[str]], rules=None):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        mesh = _current_mesh()
        if mesh is None or mesh.empty:  # pragma: no cover - env dependent
            return x
        if len(logical_axes) != x.ndim:
            # rank-mismatched constraints (train-shaped axes on squeezed
            # decode tensors) are no-ops, never active replication
            return x
        # the abstract mesh carries axis names AND sizes, so the divisibility
        # fallback applies here too (kv_heads=2 must NOT grab a 16-way axis)
        spec = spec_for(logical_axes, x.shape, mesh, rules,
                        allow_padded=True)
        return jax.lax.with_sharding_constraint(x, spec)
    except (AttributeError, ValueError, RuntimeError):
        return x
