"""Logical-axis assignment for parameter and cache pytrees.

Leaves are matched by their dict key name; the returned logical-axes tuple is
left-padded with ``None`` to the leaf's rank (so stacked (L, ...) scan params
and unstacked params share one table).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np

PARAM_AXES = {
    "table": ("vocab", "embed"),
    "wq": ("embed", "qkv"), "wk": ("embed", "qkv"), "wv": ("embed", "qkv"),
    "wo": ("qkv", "embed"),
    "bq": ("qkv",), "bk": ("qkv",), "bv": ("qkv",),
    "pca": ("kv_heads", None, None),
    "router": ("embed", None),
    "in_proj": ("embed", "mlp"),
    "conv_w": (None, "mlp"),
    "x_proj": ("mlp", None),
    "dt_proj": (None, "mlp"),
    "dt_bias": ("mlp",),
    "a_log": ("mlp", None),
    "d_skip": ("mlp",),
    "out_proj": ("mlp", "embed"),
    "w_if": ("embed", None),
    "b_if": (None,),
    "wo_gate": ("embed", "qkv"),
    "w_gates": ("embed", "qkv"),
    "r_gates": (None, None, None),
    "b_gates": (None,),
    "scale": (None,), "bias": (None,),
    "vision_adapter": ("embed", None),
}

# moe expert weights share names with the dense mlp but have rank 3
PARAM_AXES_3D = {
    "w_in": ("expert", "embed", "mlp"),
    "w_out": ("expert", "mlp", "embed"),
}
PARAM_AXES_2D = {
    "w_in": ("embed", "mlp"),
    "w_out": ("mlp", "embed"),
}

CACHE_AXES = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "cross_k": ("batch", None, "kv_heads", None),
    "cross_v": ("batch", None, "kv_heads", None),
    "acc": ("batch", "kv_heads", None),
    "conv": ("batch", None, "mlp"),
    "ssm": ("batch", "mlp", None),
}


def _leaf_name(path) -> str:
    for k in reversed(path):
        if hasattr(k, "key"):
            return k.key
    return ""


def _pad(core: Tuple, ndim: int) -> Tuple:
    core = tuple(core)[:ndim]
    return (None,) * (ndim - len(core)) + core


def _stack_depth(path, name: str, ndim: int, core_len: int) -> int:
    return ndim - core_len


def param_axes_tree(params_shapes):
    """Pytree of logical-axes tuples matching a params shape tree."""
    def assign(path, leaf):
        name = _leaf_name(path)
        ndim = len(leaf.shape)
        in_layer = any(getattr(k, "key", None) in ("layers", "enc_layers")
                       for k in path)
        if name in ("w_in", "w_out"):
            under_moe = any(getattr(k, "key", None) == "moe" for k in path)
            core = (PARAM_AXES_3D if under_moe else PARAM_AXES_2D)[name]
        else:
            core = PARAM_AXES.get(name, ())
        if not core:
            core = (None,) * ndim
        return _pad(core, ndim)

    return jax.tree_util.tree_map_with_path(assign, params_shapes)


def cache_axes_tree(cache_shapes):
    def assign(path, leaf):
        name = _leaf_name(path)
        ndim = len(leaf.shape)
        core = CACHE_AXES.get(name)
        if core is not None:
            return _pad(core, ndim)
        # generic: batch-shard the first non-stacked dim
        stacked = (any(getattr(k, "key", None) == "layers" for k in path)
                   and not any(hasattr(k, "idx") for k in path))
        lead = (None,) if stacked else ()
        axes = lead + ("batch",)
        return (axes + (None,) * (ndim - len(axes)))[:ndim]

    return jax.tree_util.tree_map_with_path(assign, cache_shapes)


def batch_axes(batch_shapes):
    def assign(path, leaf):
        return _pad(("batch", "seq") + (None,) * 8, len(leaf.shape))
    return jax.tree_util.tree_map_with_path(assign, batch_shapes)
