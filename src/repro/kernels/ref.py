"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors its kernel's semantics exactly, in straight-line jnp —
tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def block_max_scores_ref(q_hat, k_hat, cur_len, *, d, block_size=128,
                         scale=None):
    bh, dim = q_hat.shape
    s_len = k_hat.shape[1]
    nb = s_len // block_size
    scale = scale if scale is not None else dim ** -0.5
    s = jnp.einsum("bd,bsd->bs", q_hat[:, :d].astype(jnp.float32),
                   k_hat[..., :d].astype(jnp.float32)) * scale
    pos = jnp.arange(s_len)
    s = jnp.where(pos[None] < cur_len[:, None], s, NEG_INF)
    return s.reshape(bh, nb, block_size).max(-1)


def block_sparse_attention_ref(q_hat, k_hat, v, blk_idx, cur_len, *,
                               block_size=128, scale=None):
    bh, dim = q_hat.shape
    s_len = k_hat.shape[1]
    bs = block_size
    scale = scale if scale is not None else dim ** -0.5
    # token indices of selected blocks
    tok = (blk_idx[..., None] * bs + jnp.arange(bs)).reshape(bh, -1)
    k_sel = jnp.take_along_axis(k_hat, tok[..., None], axis=1)
    v_sel = jnp.take_along_axis(v, tok[..., None], axis=1)
    s = jnp.einsum("bd,bkd->bk", q_hat.astype(jnp.float32),
                   k_sel.astype(jnp.float32)) * scale
    s = jnp.where(tok < cur_len[:, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    # all-masked guard: softmax of all -inf -> uniform; zero it instead
    any_live = jnp.any(tok < cur_len[:, None], axis=-1, keepdims=True)
    w = jnp.where(any_live, w, 0.0)
    return jnp.einsum("bk,bkd->bd", w, v_sel.astype(jnp.float32)
                      ).astype(q_hat.dtype)


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    bh, sq, dim = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else dim ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
