"""Pallas-TPU kernel: block-sparse top-k attention (Loki lines 8-9).

Given the indices of the selected KV blocks (from the approx-score block
top-k), run exact flash-style attention over ONLY those blocks. The sparse
HBM read happens in the grid itself: the BlockSpec ``index_map`` looks up the
prefetched block index, so the selected K̂/V blocks stream from HBM directly
into VMEM — no dense gather copy is ever materialized (the paper's Triton
kernels achieve this with register-level indexing; scalar-prefetched index
maps are the TPU-native equivalent, DESIGN.md §3).

Grid: (BH, n_sel). The n_sel axis is sequential per row — the online-softmax
accumulator lives in VMEM scratch across grid steps.

  q_hat    (BH, D)        PCA-basis query (full D -> exact, Lemma 4.1)
  k_hat    (BH, S, D)     PCA-basis key cache
  v        (BH, S, D)
  blk_idx  (BH, n_sel)    selected block indices (scalar-prefetched)
  cur_len  (BH,)          valid prefix length
Output:
  out      (BH, D)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.registry import kernel_entry

NEG_INF = -1e30


def _kernel(blk_idx_ref, len_ref, q_ref, k_ref, v_ref, out_ref,
            m_ref, l_ref, acc_ref, *, bs: int, scale: float, n_sel: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[0] = NEG_INF
        l_ref[0] = 0.0
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                       # (D,)
    k = k_ref[0].astype(jnp.float32)                       # (bs, D)
    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale

    blk = blk_idx_ref[i, j]
    pos = blk * bs + jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
    live = pos < len_ref[i]
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    # guard: all-masked block with empty accumulator
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0)) * (m_prev > NEG_INF / 2)
    p = jnp.exp(s - m_safe) * live                         # (bs,)
    v_blk = v_ref[0].astype(jnp.float32)                   # (bs, D)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_blk, preferred_element_type=jnp.float32)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    m_ref[0] = m_new

    @pl.when(j == n_sel - 1)
    def _fini():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[0], 1e-30)).astype(out_ref.dtype)


@kernel_entry(scalar_prefetch=("blk_idx", "cur_len"), grid="(BH, n_sel)")
def block_sparse_attention(q_hat, k_hat, v, blk_idx, cur_len, *,
                           block_size: int = 128, scale=None,
                           interpret: bool = False):
    bh, dim = q_hat.shape
    s_len = k_hat.shape[1]
    bs = block_size
    n_sel = blk_idx.shape[1]
    assert s_len % bs == 0
    scale = float(scale if scale is not None else dim ** -0.5)

    kernel = functools.partial(_kernel, bs=bs, scale=scale, n_sel=n_sel)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(bh, n_sel),
            in_specs=[
                pl.BlockSpec((1, dim), lambda i, j, bi, ln: (i, 0)),
                # the sparse read: block index comes from the prefetched
                # selection, so only chosen blocks leave HBM
                pl.BlockSpec((1, bs, dim),
                             lambda i, j, bi, ln: (i, bi[i, j], 0)),
                pl.BlockSpec((1, bs, dim),
                             lambda i, j, bi, ln: (i, bi[i, j], 0)),
            ],
            out_specs=pl.BlockSpec((1, dim), lambda i, j, bi, ln: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((1,), jnp.float32),   # running max
                pltpu.VMEM((1,), jnp.float32),   # running denom
                pltpu.VMEM((dim,), jnp.float32), # accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, dim), q_hat.dtype),
        interpret=interpret,
    )(blk_idx.astype(jnp.int32), cur_len.astype(jnp.int32), q_hat, k_hat, v)
    return out


# ------------------------------------------------- GQA-batched variant

def _gkernel(*args, paged: bool, quant: bool, bs: int, bpp: int,
             scale: float, n_sel: int, sliding_window: int):
    if quant:
        (blk_idx_ref, len_ref, pt_ref, q_ref, k_ref, v_ref,
         ksc_ref, vsc_ref, out_ref, m_ref, l_ref, acc_ref) = args
    elif paged:
        (blk_idx_ref, len_ref, pt_ref, q_ref, k_ref, v_ref, out_ref,
         m_ref, l_ref, acc_ref) = args
    else:
        (blk_idx_ref, len_ref, q_ref, k_ref, v_ref, out_ref,
         m_ref, l_ref, acc_ref) = args
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, W)
    # paged pools have no batch dim: the k/v block arrives as (bs, 1, W)
    k = (k_ref[:, 0] if paged else k_ref[0, :, 0]).astype(jnp.float32)
    if quant:
        # one physical page per staged block (bs divides page_size): its
        # SMEM-resident scale dequantizes the codes right after the DMA
        page = pt_ref[b, jnp.maximum(blk_idx_ref[b, h, j], 0) // bpp]
        k = k * ksc_ref[page, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)

    blk = blk_idx_ref[b, h, j]
    pos = jnp.maximum(blk, 0) * bs + jax.lax.broadcasted_iota(
        jnp.int32, (1, bs), 1)
    # blk == -1: selection exhausted (fewer live blocks than n_sel) — the
    # staged block is a clamped re-read and must contribute nothing
    live = (pos < len_ref[b]) & (blk >= 0)                 # (1, bs)
    if sliding_window:
        live &= pos >= len_ref[b] - sliding_window
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[...]                                    # (G,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0)) * (m_prev > NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None]) * live                # (G, bs)
    v_blk = (v_ref[:, 0] if paged else v_ref[0, :, 0]).astype(jnp.float32)
    if quant:
        v_blk = v_blk * vsc_ref[page, 0]
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v_blk, preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_new

    @pl.when(j == n_sel - 1)
    def _fini():
        out_ref[0, 0] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            out_ref.dtype)


@kernel_entry(scalar_prefetch=("blk_idx", "cur_len", "page_table"),
              smem_sidecars=("k_scale", "v_scale"),
              paged_operand="page_table", grid="(B, Hkv, n_sel)")
def block_sparse_attention_grouped(q_hat, k_hat, v, blk_idx, cur_len, *,
                                   block_size: int = 128, scale=None,
                                   sliding_window: int = 0,
                                   page_table=None, page_size: int = 0,
                                   k_scale=None, v_scale=None,
                                   interpret: bool = False):
    """GQA-batched sparse attention over a *group-shared* block selection.

    All G query heads of a KV group ride one grid row, so each selected
    K̂/V block is streamed from HBM once per group and the score/value
    products are (G, D) @ (D, bs) / (G, bs) @ (bs, D) MXU tiles instead of
    G matrix-vector products (DESIGN.md §4). Operates on the model-native
    cache layout — no transpose copies.

      q_hat    (B, Hkv, G, D)    PCA-basis grouped queries
      k_hat    (B, S, Hkv, D)    PCA-basis key cache
      v        (B, S, Hkv, D)
      blk_idx  (B, Hkv, n_sel)   group-shared selected blocks (prefetched)
      cur_len  (B,)
    Output:    (B, Hkv, G, D)

    With ``page_table``/``page_size`` the caches are pooled
    (n_pages * page_size, Hkv, D) and the selected *logical* block indices
    resolve to physical blocks inside the BlockSpec index map — the sparse
    paged read costs exactly one extra SMEM lookup per block (DESIGN.md §7).
    """
    b, n_kv, g, kdim = q_hat.shape
    dim = v.shape[-1]
    assert k_hat.shape[-1] == kdim, "q_hat/k_hat latent widths must match"
    bs = block_size
    n_sel = blk_idx.shape[-1]
    paged = page_table is not None
    quant = k_scale is not None
    assert not quant or (paged and v_scale is not None), \
        "per-page scales require paged caches"
    bpp = 0
    if paged:
        assert page_size > 0 and page_size % bs == 0, \
            "kernel blocks must tile pages exactly"
        assert k_hat.ndim == 3, "paged caches are pooled (R, Hkv, D)"
        bpp = page_size // bs                 # blocks per page
        assert (page_table.shape[1] * page_size) % bs == 0
    else:
        assert k_hat.shape[1] % bs == 0
    scale = float(scale if scale is not None else dim ** -0.5)

    kernel = functools.partial(_gkernel, paged=paged, quant=quant, bs=bs,
                               bpp=bpp, scale=scale, n_sel=n_sel,
                               sliding_window=sliding_window)
    if paged:
        def kv_map(i, h, j, bi, ln, pt):
            # clamp the -1 "exhausted" sentinel, then translate the logical
            # block to its physical home: page_table picks the page, the
            # block's offset inside the page is preserved
            blk = jnp.maximum(bi[i, h, j], 0)
            return (pt[i, blk // bpp] * bpp + blk % bpp, h, 0)
        in_specs = [
            pl.BlockSpec((1, 1, g, kdim),
                         lambda i, h, j, bi, ln, pt: (i, h, 0, 0)),
            pl.BlockSpec((bs, 1, kdim), kv_map),
            pl.BlockSpec((bs, 1, dim), kv_map),
        ]
        o_map = lambda i, h, j, bi, ln, pt: (i, h, 0, 0)
        prefetch = (blk_idx.astype(jnp.int32), cur_len.astype(jnp.int32),
                    page_table.astype(jnp.int32))
    else:
        def kv_map(i, h, j, bi, ln):
            # clamp the -1 "exhausted" sentinel to a safe block address;
            # the kernel masks its contribution to zero
            return (i, jnp.maximum(bi[i, h, j], 0), h, 0)
        in_specs = [
            pl.BlockSpec((1, 1, g, kdim),
                         lambda i, h, j, bi, ln: (i, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, kdim), kv_map),
            pl.BlockSpec((1, bs, 1, dim), kv_map),
        ]
        o_map = lambda i, h, j, bi, ln: (i, h, 0, 0)
        prefetch = (blk_idx.astype(jnp.int32), cur_len.astype(jnp.int32))
    inputs = [q_hat, k_hat, v]
    if quant:
        # per-page f32 scale sidecars live whole in SMEM beside the table
        in_specs += [pl.BlockSpec(memory_space=pltpu.SMEM),
                     pl.BlockSpec(memory_space=pltpu.SMEM)]
        inputs += [k_scale.astype(jnp.float32).reshape(-1, 1),
                   v_scale.astype(jnp.float32).reshape(-1, 1)]
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, n_kv, n_sel),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, g, dim), o_map),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),       # running max per head
                pltpu.VMEM((g,), jnp.float32),       # running denom per head
                pltpu.VMEM((g, dim), jnp.float32),   # accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, dim), q_hat.dtype),
        interpret=interpret,
    )(*prefetch, *inputs)
    return out
