"""Pallas-TPU kernel: block-sparse top-k attention (Loki lines 8-9).

Given the indices of the selected KV blocks (from the approx-score block
top-k), run exact flash-style attention over ONLY those blocks. The sparse
HBM read happens in the grid itself: the BlockSpec ``index_map`` looks up the
prefetched block index, so the selected K̂/V blocks stream from HBM directly
into VMEM — no dense gather copy is ever materialized (the paper's Triton
kernels achieve this with register-level indexing; scalar-prefetched index
maps are the TPU-native equivalent, DESIGN.md §3).

Grid: (BH, n_sel). The n_sel axis is sequential per row — the online-softmax
accumulator lives in VMEM scratch across grid steps.

  q_hat    (BH, D)        PCA-basis query (full D -> exact, Lemma 4.1)
  k_hat    (BH, S, D)     PCA-basis key cache
  v        (BH, S, D)
  blk_idx  (BH, n_sel)    selected block indices (scalar-prefetched)
  cur_len  (BH,)          valid prefix length
Output:
  out      (BH, D)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.registry import kernel_entry

NEG_INF = -1e30


def _kernel(blk_idx_ref, len_ref, q_ref, k_ref, v_ref, out_ref,
            m_ref, l_ref, acc_ref, *, bs: int, scale: float, n_sel: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[0] = NEG_INF
        l_ref[0] = 0.0
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                       # (D,)
    k = k_ref[0].astype(jnp.float32)                       # (bs, D)
    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale

    blk = blk_idx_ref[i, j]
    pos = blk * bs + jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
    live = pos < len_ref[i]
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    # guard: all-masked block with empty accumulator
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0)) * (m_prev > NEG_INF / 2)
    p = jnp.exp(s - m_safe) * live                         # (bs,)
    v_blk = v_ref[0].astype(jnp.float32)                   # (bs, D)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_blk, preferred_element_type=jnp.float32)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    m_ref[0] = m_new

    @pl.when(j == n_sel - 1)
    def _fini():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[0], 1e-30)).astype(out_ref.dtype)


@kernel_entry(scalar_prefetch=("blk_idx", "cur_len"), grid="(BH, n_sel)")
def block_sparse_attention(q_hat, k_hat, v, blk_idx, cur_len, *,
                           block_size: int = 128, scale=None,
                           interpret: bool = False):
    bh, dim = q_hat.shape
    s_len = k_hat.shape[1]
    bs = block_size
    n_sel = blk_idx.shape[1]
    assert s_len % bs == 0
    scale = float(scale if scale is not None else dim ** -0.5)

    kernel = functools.partial(_kernel, bs=bs, scale=scale, n_sel=n_sel)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(bh, n_sel),
            in_specs=[
                pl.BlockSpec((1, dim), lambda i, j, bi, ln: (i, 0)),
                # the sparse read: block index comes from the prefetched
                # selection, so only chosen blocks leave HBM
                pl.BlockSpec((1, bs, dim),
                             lambda i, j, bi, ln: (i, bi[i, j], 0)),
                pl.BlockSpec((1, bs, dim),
                             lambda i, j, bi, ln: (i, bi[i, j], 0)),
            ],
            out_specs=pl.BlockSpec((1, dim), lambda i, j, bi, ln: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((1,), jnp.float32),   # running max
                pltpu.VMEM((1,), jnp.float32),   # running denom
                pltpu.VMEM((dim,), jnp.float32), # accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, dim), q_hat.dtype),
        interpret=interpret,
    )(blk_idx.astype(jnp.int32), cur_len.astype(jnp.int32), q_hat, k_hat, v)
    return out


# ------------------------------------------- streaming full-decode variant

def _full_kernel(*args, paged: bool, quant: bool, ps: int, bs: int,
                 scale: float, g: int, kdim: int, dim: int,
                 sliding_window: int):
    if quant:
        (len_ref, pt_ref, q_ref, k_ref, v_ref, ksc_ref, vsc_ref, out_ref,
         kbuf, vbuf, sem_k, sem_v) = args
    elif paged:
        (len_ref, pt_ref, q_ref, k_ref, v_ref, out_ref,
         kbuf, vbuf, sem_k, sem_v) = args
    else:
        (len_ref, q_ref, k_ref, v_ref, out_ref,
         kbuf, vbuf, sem_k, sem_v) = args
    b = pl.program_id(0)
    h = pl.program_id(1)
    ln = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, W)

    def k_slice(ref, blk, width):
        """HBM source for (logical) block ``blk``: direct for contiguous
        caches, through the page table for pooled ones."""
        tok = blk * bs
        if paged:
            row = pt_ref[b, tok // ps] * ps + tok % ps
            return ref.at[pl.ds(row, bs), h, pl.ds(0, width)]
        return ref.at[b, pl.ds(tok, bs), h, pl.ds(0, width)]

    def page_of(blk):
        return pt_ref[b, (blk * bs) // ps]

    def copies(j, slot):
        ck = pltpu.make_async_copy(k_slice(k_ref, j, kdim), kbuf.at[slot],
                                   sem_k.at[slot])
        cv = pltpu.make_async_copy(k_slice(v_ref, j, dim), vbuf.at[slot],
                                   sem_v.at[slot])
        return ck, cv

    if sliding_window:
        # only the window's blocks are live: under window page recycling
        # the older table entries point at trash anyway, so their DMAs
        # would be pure waste — start at the first overlapping block
        lo = jnp.maximum(ln - sliding_window, 0) // bs
    else:
        lo = jnp.int32(0)
    # stream live blocks only: the trip count follows cur_len, not smax —
    # this is the whole point versus gathering the logical view (decode
    # reads scale with the live prefix / window, never the table capacity)
    hi = (ln + bs - 1) // bs
    ck0, cv0 = copies(lo, jax.lax.rem(lo, 2))
    ck0.start()
    cv0.start()

    def att_blk(j, carry):
        m_prev, l_prev, acc = carry
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < hi)
        def _prefetch():
            ck, cv = copies(j + 1, 1 - slot)
            ck.start()
            cv.start()

        ck, cv = copies(j, slot)
        ck.wait()
        cv.wait()
        kb = kbuf[slot].astype(jnp.float32)                # (bs, W)
        if quant:
            # per-page scale from SMEM, applied in the DMA epilogue —
            # HBM only ever moves the narrow codes (DESIGN.md §10)
            kb = kb * ksc_ref[page_of(j), 0]
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        live = pos < ln                                    # (1, bs)
        if sliding_window:
            live &= pos >= ln - sliding_window
        s = jnp.where(live, s, NEG_INF)                    # (G, bs)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        # guard: an all-masked block with an empty accumulator
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0)) \
            * (m_prev > NEG_INF / 2)
        p = jnp.exp(s - m_safe[:, None]) * live            # (G, bs)
        vb = vbuf[slot].astype(jnp.float32)                # (bs, D)
        if quant:
            vb = vb * vsc_ref[page_of(j), 0]
        acc = acc * alpha[:, None] + jnp.dot(
            p, vb, preferred_element_type=jnp.float32)
        return m_new, l_prev * alpha + jnp.sum(p, axis=1), acc

    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    a0 = jnp.zeros((g, dim), jnp.float32)
    _, l, acc = jax.lax.fori_loop(lo, hi, att_blk, (m0, l0, a0))
    out_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(
        out_ref.dtype)


@kernel_entry(scalar_prefetch=("cur_len", "page_table"),
              smem_sidecars=("k_scale", "v_scale"),
              paged_operand="page_table", grid="(B, Hkv)")
def paged_full_decode(q_hat, k_hat, v, cur_len, *, block_size: int = 128,
                      scale=None, sliding_window: int = 0,
                      page_table=None, page_size: int = 0,
                      k_scale=None, v_scale=None,
                      interpret: bool = False):
    """Streaming full-attention decode over live blocks only.

    The ``full`` policy's paged fast path: instead of gathering the whole
    logical KV view per layer (the jnp route), one grid step per
    (batch, kv-head) double-buffer DMAs K/V block-by-block through the
    scalar-prefetched page table and folds each block into a (G,)-wide
    online softmax. The block loop runs ``ceil(cur_len/bs)`` iterations
    (from the window's first block under ``sliding_window``), so HBM
    traffic follows the *live* prefix, never the table capacity.

      q_hat    (B, Hkv, G, W)  grouped queries, already in the storage
                               basis (W <= D: rank-r latent keys)
      k_hat    (B, S, Hkv, W)  or pooled (R, Hkv, W) with ``page_table``
      v        (B, S, Hkv, D)  or pooled (R, Hkv, D)
      cur_len  (B,)
    Output:    (B, Hkv, G, D)

    Requires cur_len >= 1 per row (the decode invariant). Quantized
    layouts pass the pools' (n_pages,) f32 ``k_scale``/``v_scale``
    sidecars (paged only); dequantization happens in the DMA epilogue."""
    b, n_kv, g, kdim = q_hat.shape
    dim = v.shape[-1]
    assert k_hat.shape[-1] == kdim, "q_hat/k_hat widths must match"
    bs = block_size
    paged = page_table is not None
    if paged:
        assert page_size > 0 and page_size % bs == 0, \
            "kernel blocks must tile pages exactly (page_size % bs == 0)"
        assert k_hat.ndim == 3, "paged caches are pooled (R, Hkv, D)"
        s_len = page_table.shape[1] * page_size
        prefetch = (cur_len.astype(jnp.int32), page_table.astype(jnp.int32))
    else:
        s_len = k_hat.shape[1]
        prefetch = (cur_len.astype(jnp.int32),)
    quant = k_scale is not None
    assert not quant or (paged and v_scale is not None), \
        "per-page scales require paged caches"
    assert s_len % bs == 0, "cache length must be a multiple of block_size"
    scale = float(scale if scale is not None else dim ** -0.5)

    kernel = functools.partial(
        _full_kernel, paged=paged, quant=quant, ps=page_size, bs=bs,
        scale=scale, g=g, kdim=kdim, dim=dim, sliding_window=sliding_window)
    if paged:
        io_map = lambda i, j, ln, pt: (i, j, 0, 0)
    else:
        io_map = lambda i, j, ln: (i, j, 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, g, kdim), io_map),
        # caches stay in HBM; the kernel DMAs live blocks itself
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    inputs = [q_hat, k_hat, v]
    if quant:
        # (n_pages, 1) f32 sidecars land whole in SMEM (scalar prefetch
        # itself is int32-only)
        in_specs += [pl.BlockSpec(memory_space=pltpu.SMEM),
                     pl.BlockSpec(memory_space=pltpu.SMEM)]
        inputs += [k_scale.astype(jnp.float32).reshape(-1, 1),
                   v_scale.astype(jnp.float32).reshape(-1, 1)]
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, n_kv),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, g, dim), io_map),
            scratch_shapes=[
                pltpu.VMEM((2, bs, kdim), k_hat.dtype),  # K stream buffers
                pltpu.VMEM((2, bs, dim), v.dtype),       # V stream buffers
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, dim), q_hat.dtype),
        interpret=interpret,
    )(*prefetch, *inputs)
    return out


# ------------------------------------------------- GQA-batched variant

def _gkernel(*args, paged: bool, quant: bool, bs: int, bpp: int,
             scale: float, n_sel: int, sliding_window: int):
    if quant:
        (blk_idx_ref, len_ref, pt_ref, q_ref, k_ref, v_ref,
         ksc_ref, vsc_ref, out_ref, m_ref, l_ref, acc_ref) = args
    elif paged:
        (blk_idx_ref, len_ref, pt_ref, q_ref, k_ref, v_ref, out_ref,
         m_ref, l_ref, acc_ref) = args
    else:
        (blk_idx_ref, len_ref, q_ref, k_ref, v_ref, out_ref,
         m_ref, l_ref, acc_ref) = args
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, W)
    # paged pools have no batch dim: the k/v block arrives as (bs, 1, W)
    k = (k_ref[:, 0] if paged else k_ref[0, :, 0]).astype(jnp.float32)
    if quant:
        # one physical page per staged block (bs divides page_size): its
        # SMEM-resident scale dequantizes the codes right after the DMA
        page = pt_ref[b, jnp.maximum(blk_idx_ref[b, h, j], 0) // bpp]
        k = k * ksc_ref[page, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)

    blk = blk_idx_ref[b, h, j]
    pos = jnp.maximum(blk, 0) * bs + jax.lax.broadcasted_iota(
        jnp.int32, (1, bs), 1)
    # blk == -1: selection exhausted (fewer live blocks than n_sel) — the
    # staged block is a clamped re-read and must contribute nothing
    live = (pos < len_ref[b]) & (blk >= 0)                 # (1, bs)
    if sliding_window:
        live &= pos >= len_ref[b] - sliding_window
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[...]                                    # (G,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0)) * (m_prev > NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None]) * live                # (G, bs)
    v_blk = (v_ref[:, 0] if paged else v_ref[0, :, 0]).astype(jnp.float32)
    if quant:
        v_blk = v_blk * vsc_ref[page, 0]
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v_blk, preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_new

    @pl.when(j == n_sel - 1)
    def _fini():
        out_ref[0, 0] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
            out_ref.dtype)


@kernel_entry(scalar_prefetch=("blk_idx", "cur_len", "page_table"),
              smem_sidecars=("k_scale", "v_scale"),
              paged_operand="page_table", grid="(B, Hkv, n_sel)")
def block_sparse_attention_grouped(q_hat, k_hat, v, blk_idx, cur_len, *,
                                   block_size: int = 128, scale=None,
                                   sliding_window: int = 0,
                                   page_table=None, page_size: int = 0,
                                   k_scale=None, v_scale=None,
                                   interpret: bool = False):
    """GQA-batched sparse attention over a *group-shared* block selection.

    All G query heads of a KV group ride one grid row, so each selected
    K̂/V block is streamed from HBM once per group and the score/value
    products are (G, D) @ (D, bs) / (G, bs) @ (bs, D) MXU tiles instead of
    G matrix-vector products (DESIGN.md §4). Operates on the model-native
    cache layout — no transpose copies.

      q_hat    (B, Hkv, G, D)    PCA-basis grouped queries
      k_hat    (B, S, Hkv, D)    PCA-basis key cache
      v        (B, S, Hkv, D)
      blk_idx  (B, Hkv, n_sel)   group-shared selected blocks (prefetched)
      cur_len  (B,)
    Output:    (B, Hkv, G, D)

    With ``page_table``/``page_size`` the caches are pooled
    (n_pages * page_size, Hkv, D) and the selected *logical* block indices
    resolve to physical blocks inside the BlockSpec index map — the sparse
    paged read costs exactly one extra SMEM lookup per block (DESIGN.md §7).
    """
    b, n_kv, g, kdim = q_hat.shape
    dim = v.shape[-1]
    assert k_hat.shape[-1] == kdim, "q_hat/k_hat latent widths must match"
    bs = block_size
    n_sel = blk_idx.shape[-1]
    paged = page_table is not None
    quant = k_scale is not None
    assert not quant or (paged and v_scale is not None), \
        "per-page scales require paged caches"
    bpp = 0
    if paged:
        assert page_size > 0 and page_size % bs == 0, \
            "kernel blocks must tile pages exactly"
        assert k_hat.ndim == 3, "paged caches are pooled (R, Hkv, D)"
        bpp = page_size // bs                 # blocks per page
        assert (page_table.shape[1] * page_size) % bs == 0
    else:
        assert k_hat.shape[1] % bs == 0
    scale = float(scale if scale is not None else dim ** -0.5)

    kernel = functools.partial(_gkernel, paged=paged, quant=quant, bs=bs,
                               bpp=bpp, scale=scale, n_sel=n_sel,
                               sliding_window=sliding_window)
    if paged:
        def kv_map(i, h, j, bi, ln, pt):
            # clamp the -1 "exhausted" sentinel, then translate the logical
            # block to its physical home: page_table picks the page, the
            # block's offset inside the page is preserved
            blk = jnp.maximum(bi[i, h, j], 0)
            return (pt[i, blk // bpp] * bpp + blk % bpp, h, 0)
        in_specs = [
            pl.BlockSpec((1, 1, g, kdim),
                         lambda i, h, j, bi, ln, pt: (i, h, 0, 0)),
            pl.BlockSpec((bs, 1, kdim), kv_map),
            pl.BlockSpec((bs, 1, dim), kv_map),
        ]
        o_map = lambda i, h, j, bi, ln, pt: (i, h, 0, 0)
        prefetch = (blk_idx.astype(jnp.int32), cur_len.astype(jnp.int32),
                    page_table.astype(jnp.int32))
    else:
        def kv_map(i, h, j, bi, ln):
            # clamp the -1 "exhausted" sentinel to a safe block address;
            # the kernel masks its contribution to zero
            return (i, jnp.maximum(bi[i, h, j], 0), h, 0)
        in_specs = [
            pl.BlockSpec((1, 1, g, kdim),
                         lambda i, h, j, bi, ln: (i, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, kdim), kv_map),
            pl.BlockSpec((1, bs, 1, dim), kv_map),
        ]
        o_map = lambda i, h, j, bi, ln: (i, h, 0, 0)
        prefetch = (blk_idx.astype(jnp.int32), cur_len.astype(jnp.int32))
    inputs = [q_hat, k_hat, v]
    if quant:
        # per-page f32 scale sidecars live whole in SMEM beside the table
        in_specs += [pl.BlockSpec(memory_space=pltpu.SMEM),
                     pl.BlockSpec(memory_space=pltpu.SMEM)]
        inputs += [k_scale.astype(jnp.float32).reshape(-1, 1),
                   v_scale.astype(jnp.float32).reshape(-1, 1)]
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, n_kv, n_sel),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, g, dim), o_map),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),       # running max per head
                pltpu.VMEM((g,), jnp.float32),       # running denom per head
                pltpu.VMEM((g, dim), jnp.float32),   # accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, dim), q_hat.dtype),
        interpret=interpret,
    )(*prefetch, *inputs)
    return out
