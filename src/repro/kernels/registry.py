"""Kernel entry-point registry: declared contracts for static checking.

Every public Pallas entry point registers itself here with a
:class:`KernelContract` describing the operands the static checker
(repro/analysis/kernel_contracts.py) must be able to see without running
the kernel:

  scalar_prefetch  operand names that ride the PrefetchScalarGridSpec's
                   int32 scalar-prefetch path (grid-visible: page tables,
                   lengths, block selections)
  smem_sidecars    operand names of the per-page f32 scale sidecars that
                   land whole in SMEM (quantized PageLayouts; scalar
                   prefetch itself is int32-only)
  paged_operand    the page-table kwarg name, or None for entry points
                   that only read contiguous caches
  supports_quant   the entry point accepts k/v scale sidecars

The decorator attaches the contract to the function
(``fn.__kernel_contract__``) and records it in :data:`REGISTRY`, so the
checker can sweep "every registered kernel entry point" instead of a
hand-maintained list that silently rots. Importing this module is free of
kernel imports; :func:`load_all` pulls in the kernel modules (which import
*us*) and returns the populated registry.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Tuple

#: modules whose import registers entry points (kept explicit so a new
#: kernel file that forgets to register is caught by test_analysis.py's
#: registry-coverage check, not silently skipped)
KERNEL_MODULES = (
    "repro.kernels.fused_decode",
    "repro.kernels.gather_attention",
    "repro.kernels.approx_scores",
    "repro.kernels.approx_scores_fm",
    "repro.kernels.flash_attention",
)


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """Statically-checkable facts about one Pallas entry point."""
    name: str
    module: str
    scalar_prefetch: Tuple[str, ...] = ()
    smem_sidecars: Tuple[str, ...] = ()
    paged_operand: str = ""
    supports_quant: bool = False
    grid: str = ""

    @property
    def uses_prefetch_grid(self) -> bool:
        return bool(self.scalar_prefetch)


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    fn: Callable[..., object]
    contract: KernelContract


REGISTRY: Dict[str, KernelEntry] = {}


def kernel_entry(*, scalar_prefetch: Tuple[str, ...] = (),
                 smem_sidecars: Tuple[str, ...] = (),
                 paged_operand: str = "",
                 grid: str = "") -> Callable[[Callable[..., object]],
                                             Callable[..., object]]:
    """Register a Pallas entry point with its declared contract."""
    def deco(fn: Callable[..., object]) -> Callable[..., object]:
        contract = KernelContract(
            name=fn.__name__, module=fn.__module__,
            scalar_prefetch=tuple(scalar_prefetch),
            smem_sidecars=tuple(smem_sidecars),
            paged_operand=paged_operand,
            supports_quant=bool(smem_sidecars),
            grid=grid)
        REGISTRY[fn.__name__] = KernelEntry(fn=fn, contract=contract)
        fn.__kernel_contract__ = contract  # type: ignore[attr-defined]
        return fn
    return deco


def load_all() -> Dict[str, KernelEntry]:
    """Import every kernel module and return the populated registry."""
    for mod in KERNEL_MODULES:
        importlib.import_module(mod)
    return dict(REGISTRY)
