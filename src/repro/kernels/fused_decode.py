"""Pallas-TPU kernel: fused GQA-batched Loki decode (DESIGN.md §4, §7).

One grid step per (batch, kv-head) pair runs the *entire* Loki decode for
that KV group — approximate scoring, block top-k selection and exact sparse
attention — without any intermediate tensor ever returning to HBM:

  1. score stream: the leading-``d`` feature slice of each K̂ block is
     double-buffer DMA'd from HBM and hit with a (G, d) @ (d, bs) MXU tile —
     all G query heads of the GQA group score the block at once. Only the
     per-group block maximum survives, in a VMEM scratch row.
  2. selection: ``k_blocks`` iterations of argmax-and-suppress over that
     VMEM row (equivalent to ``lax.top_k`` incl. lower-index tie-breaking);
     winners land in SMEM. The (B·Hkv, S)-sized score tensor and the block
     maxima that the two-pass path materializes in HBM never exist here.
  3. exact pass: each winning K̂/V block is DMA'd once *per group* (not per
     head) and folded into a (G,)-wide online softmax; the (G, bs) @ (bs, D)
     value product again batches the group onto the MXU.

Window semantics match the token-granular reference (core/loki.py):
``local_window`` inflates the recency window's approximate scores by 1e4 so
those blocks always win selection; ``sliding_window`` masks positions older
than the window out of both the selection and the exact pass.

Inputs are the model-native layouts — no transposes or flattening copies:

  q_hat    (B, Hkv, G, D)   PCA-basis post-RoPE queries, grouped
  k_hat    (B, S, Hkv, D)   key cache in PCA basis (full D, Lemma 4.1)
  v        (B, S, Hkv, D)
  cur_len  (B,)             valid prefix length per slot (scalar-prefetched)
Output:
  out      (B, Hkv, G, D)

**Paged mode** (DESIGN.md §7): pass ``page_table (B, max_pages)`` and
``page_size``; the caches are then the serving engine's shared pools
``(n_pages * page_size, Hkv, D)`` with no batch dim, and every block DMA
resolves its HBM address through the scalar-prefetched table —
``row = table[b, tok // page_size] * page_size + tok % page_size``. Pages
are a whole number of kernel blocks (``page_size % block_size == 0``), so
a block never straddles two pages and the kernel math is untouched: paged
decode is pure index indirection on the DMA source.

``select_blocks`` exposes phases 1-2 as a standalone kernel (scores still
never leave VMEM; only the tiny index rows do) for the two-kernel fallback
that feeds ``gather_attention.block_sparse_attention_grouped``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.registry import kernel_entry
from repro.kernels.tuning import pad_lanes

NEG_INF = -1e30


def _score_and_select(ln, q_hat, kd_src, kd_buf, scores, sem_kd,
                      write_sel, *, d: int, bs: int, nb: int, nb_pad: int,
                      k_blocks: int, scale: float, local_window: int = 0,
                      sliding_window: int = 0, k_scale_at=None):
    """Phases 1-2: stream d-slices, keep block maxima in VMEM, emit top-k.

    ``kd_src(j)`` returns the HBM ref slice holding block j's leading-d
    feature columns (contiguous caches address it directly; paged caches
    resolve it through the page table). ``write_sel(t, idx)`` receives the
    t-th winning block index (descending score, ties to the lower index —
    lax.top_k order), or ``-1`` once the finite maxima are exhausted (fewer
    live blocks than k_blocks): argmax over an all-NEG_INF row would
    otherwise re-emit index 0 and double-count a live block in the
    attention pass."""
    qd = q_hat[:, :d] * scale                              # (G, d) f32

    def kd_copy(j, slot):
        return pltpu.make_async_copy(kd_src(j), kd_buf.at[slot],
                                     sem_kd.at[slot])

    if sliding_window:
        # window decode only streams the live window's blocks: positions
        # older than ln - sliding_window are masked out of selection anyway
        # (and under window page recycling their pages point at trash), so
        # their score DMAs are pure waste — start at the first block that
        # overlaps the window. Blocks never selected are never DMA'd in the
        # attention pass either, so a windowed decode touches
        # ceil(window/bs)+1 blocks of HBM, not smax/bs.
        lo = jnp.maximum(ln - sliding_window, 0) // bs
    else:
        lo = jnp.int32(0)
    kd_copy(lo, jax.lax.rem(lo, 2)).start()
    scores[...] = jnp.full((1, nb_pad), NEG_INF, jnp.float32)

    def score_blk(j, carry):
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < nb)
        def _prefetch():
            kd_copy(j + 1, 1 - slot).start()

        kd_copy(j, slot).wait()
        kd = kd_buf[slot].astype(jnp.float32)              # (bs, d)
        if k_scale_at is not None:
            # quantized layout: per-page scale rides in SMEM; the multiply
            # happens here, inside the DMA epilogue — HBM only ever moves
            # the narrow codes (DESIGN.md §10)
            kd = kd * k_scale_at(j)
        s = jax.lax.dot_general(qd, kd, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        live = pos < ln
        if sliding_window:
            live &= pos >= ln - sliding_window
        s = jnp.where(live, s, NEG_INF)                    # (G, bs)
        if local_window:
            # recency inflation: force the local window into the selection
            recent = live & (pos >= ln - local_window)
            s = jnp.where(recent, s + jnp.float32(1e4), s)
        scores[0, j] = jnp.max(s)
        return carry

    jax.lax.fori_loop(lo, nb, score_blk, 0)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, nb_pad), 1)
    for t in range(k_blocks):
        row = scores[...]                                  # (1, nb_pad)
        idx = jnp.argmax(row, axis=1)[0].astype(jnp.int32)
        valid = jnp.max(row) > NEG_INF / 2
        write_sel(t, jnp.where(valid, idx, -1))
        scores[...] = jnp.where(lanes == idx, NEG_INF, row)


def _fused_kernel(*args, paged: bool, quant: bool, ps: int, d: int, bs: int,
                  nb: int, nb_pad: int, k_blocks: int, scale: float, g: int,
                  kdim: int, dim: int, local_window: int,
                  sliding_window: int):
    if quant:
        (len_ref, pt_ref, q_ref, k_ref, v_ref, ksc_ref, vsc_ref, out_ref,
         kd_buf, kbuf, vbuf, scores, sel, sem_kd, sem_kv) = args
    elif paged:
        (len_ref, pt_ref, q_ref, k_ref, v_ref, out_ref,
         kd_buf, kbuf, vbuf, scores, sel, sem_kd, sem_kv) = args
    else:
        (len_ref, q_ref, k_ref, v_ref, out_ref,
         kd_buf, kbuf, vbuf, scores, sel, sem_kd, sem_kv) = args
    b = pl.program_id(0)
    h = pl.program_id(1)
    ln = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                    # (G, W)

    def k_slice(ref, blk, width):
        """HBM source for (logical) block ``blk``: direct for contiguous
        caches, through the page table for pooled ones (the paged
        index-indirection — blocks tile pages exactly)."""
        tok = blk * bs
        if paged:
            row = pt_ref[b, tok // ps] * ps + tok % ps
            return ref.at[pl.ds(row, bs), h, pl.ds(0, width)]
        return ref.at[b, pl.ds(tok, bs), h, pl.ds(0, width)]

    def page_of(blk):
        # blocks tile pages exactly (ps % bs == 0), so one physical page —
        # hence one quantization scale — covers the whole DMA'd block
        return pt_ref[b, (blk * bs) // ps]

    def write_sel(t, idx):
        sel[t] = idx

    _score_and_select(ln, q, lambda j: k_slice(k_ref, j, d), kd_buf, scores,
                      sem_kd, write_sel, d=d, bs=bs, nb=nb, nb_pad=nb_pad,
                      k_blocks=k_blocks, scale=scale,
                      local_window=local_window,
                      sliding_window=sliding_window,
                      k_scale_at=(lambda j: ksc_ref[page_of(j), 0])
                      if quant else None)

    qs = q * scale                                         # (G, W)

    def att_blk(t, carry):
        m_prev, l_prev, acc = carry
        blk = sel[t]
        safe = jnp.maximum(blk, 0)

        @pl.when(blk >= 0)
        def _fetch():
            # -1 sentinel (exhausted selection): skip the DMA; the stale
            # buffer contents are fully masked below
            ck = pltpu.make_async_copy(k_slice(k_ref, safe, kdim), kbuf,
                                       sem_kv.at[0])
            cv = pltpu.make_async_copy(k_slice(v_ref, safe, dim), vbuf,
                                       sem_kv.at[1])
            ck.start()
            cv.start()
            ck.wait()
            cv.wait()

        kb = kbuf[...].astype(jnp.float32)                 # (bs, W)
        if quant:
            kb = kb * ksc_ref[page_of(safe), 0]
        s = jax.lax.dot_general(qs, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = safe * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        live = (pos < ln) & (blk >= 0)                     # (1, bs)
        if sliding_window:
            live &= pos >= ln - sliding_window
        s = jnp.where(live, s, NEG_INF)                    # (G, bs)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        # guard: selected-but-dead block with an empty accumulator
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0)) \
            * (m_prev > NEG_INF / 2)
        p = jnp.exp(s - m_safe[:, None]) * live            # (G, bs)
        vb = vbuf[...].astype(jnp.float32)                 # (bs, D)
        if quant:
            vb = vb * vsc_ref[page_of(safe), 0]
        acc = acc * alpha[:, None] + jnp.dot(
            p, vb, preferred_element_type=jnp.float32)
        return m_new, l_prev * alpha + jnp.sum(p, axis=1), acc

    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    a0 = jnp.zeros((g, dim), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, k_blocks, att_blk, (m0, l0, a0))
    out_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(
        out_ref.dtype)


def _paged_args(q_hat, k_hat, cur_len, page_table, page_size, block_size):
    """Validate/resolve the (paged?, logical length) of a kernel call."""
    paged = page_table is not None
    if paged:
        assert page_size > 0 and page_size % block_size == 0, \
            "kernel blocks must tile pages exactly (page_size % bs == 0)"
        assert k_hat.ndim == 3, "paged caches are pooled (R, Hkv, D)"
        s_len = page_table.shape[1] * page_size
        prefetch = (cur_len.astype(jnp.int32),
                    page_table.astype(jnp.int32))
    else:
        s_len = k_hat.shape[1]
        prefetch = (cur_len.astype(jnp.int32),)
    return paged, s_len, prefetch


@kernel_entry(scalar_prefetch=("cur_len", "page_table"),
              smem_sidecars=("k_scale", "v_scale"),
              paged_operand="page_table", grid="(B, Hkv)")
def fused_loki_decode(q_hat, k_hat, v, cur_len, *, d: int, k_blocks: int,
                      block_size: int = 128, scale=None,
                      local_window: int = 0, sliding_window: int = 0,
                      page_table=None, page_size: int = 0,
                      k_scale=None, v_scale=None,
                      interpret: bool = False):
    """Single-pass Loki decode. (B,Hkv,G,W),(B,S,Hkv,W),(B,S,Hkv,D),(B,)
    -> (B,Hkv,G,D). Requires cur_len >= 1 per row (the decode invariant:
    the new token is already in the cache). With ``page_table``/``page_size``
    the caches are pooled (R,Hkv,W) and block DMAs resolve through the
    table. ``W <= D`` is the stored latent key width (rank-r PageLayout);
    queries arrive already projected/truncated to W, values stay full D.
    Quantized layouts pass ``k_scale``/``v_scale`` (n_pages,) f32 per-page
    scales (paged only); the kernel multiplies them in right after each
    block's DMA lands — dequantization never touches HBM."""
    b, n_kv, g, kdim = q_hat.shape
    dim = v.shape[-1]
    assert k_hat.shape[-1] == kdim, "q_hat/k_hat latent widths must match"
    bs = block_size
    paged, s_len, prefetch = _paged_args(q_hat, k_hat, cur_len, page_table,
                                         page_size, bs)
    quant = k_scale is not None
    assert not quant or (paged and v_scale is not None), \
        "per-page scales require paged caches"
    assert s_len % bs == 0, "cache length must be a multiple of block_size"
    nb = s_len // bs
    nb_pad = pad_lanes(nb)
    k_blocks = min(k_blocks, nb)
    scale = float(scale if scale is not None else dim ** -0.5)

    kernel = functools.partial(
        _fused_kernel, paged=paged, quant=quant, ps=page_size, d=d, bs=bs,
        nb=nb, nb_pad=nb_pad, k_blocks=k_blocks, scale=scale, g=g,
        kdim=kdim, dim=dim, local_window=local_window,
        sliding_window=sliding_window)
    if paged:
        io_map = lambda i, j, ln, pt: (i, j, 0, 0)
    else:
        io_map = lambda i, j, ln: (i, j, 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, g, kdim), io_map),
        # the caches stay in HBM; the kernel DMAs d-slices and the
        # winning blocks itself
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    inputs = [q_hat, k_hat, v]
    if quant:
        # (n_pages, 1) f32 sidecars land whole in SMEM: one scalar read per
        # block resolves the page's scale (scalar prefetch is int32-only)
        in_specs += [pl.BlockSpec(memory_space=pltpu.SMEM),
                     pl.BlockSpec(memory_space=pltpu.SMEM)]
        inputs += [k_scale.astype(jnp.float32).reshape(-1, 1),
                   v_scale.astype(jnp.float32).reshape(-1, 1)]
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, n_kv),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, g, dim), io_map),
            scratch_shapes=[
                pltpu.VMEM((2, bs, d), k_hat.dtype),    # score-stream buffers
                pltpu.VMEM((bs, kdim), k_hat.dtype),    # winner K̂ block
                pltpu.VMEM((bs, dim), v.dtype),         # winner V block
                pltpu.VMEM((1, nb_pad), jnp.float32),   # block maxima
                pltpu.SMEM((k_blocks,), jnp.int32),     # selected blocks
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, dim), q_hat.dtype),
        interpret=interpret,
    )(*prefetch, *inputs)
    return out


@kernel_entry(scalar_prefetch=("cur_len", "page_table"),
              smem_sidecars=("k_scale", "v_scale"),
              paged_operand="page_table", grid="(B, Hkv)")
def fused_exact_topk_decode(q_hat, k_hat, v, cur_len, *, k_blocks: int,
                            block_size: int = 128, scale=None,
                            sliding_window: int = 0,
                            page_table=None, page_size: int = 0,
                            k_scale=None, v_scale=None,
                            interpret: bool = False):
    """Single-pass exact-top-k decode: the ``exact_topk`` baseline's score
    pass and block top-k fused the same way the Loki kernel's approximate
    pass is — but the score stream reads the *full* stored key width, so
    selection is over exact scores (the quality-upper-bound baseline,
    Section 5). No recency inflation: the baseline has none.

    Shapes/paging/quantization follow ``fused_loki_decode`` exactly:
    (B,Hkv,G,W),(B,S,Hkv,W),(B,S,Hkv,D),(B,) -> (B,Hkv,G,D), pooled
    (R,Hkv,·) caches with ``page_table``/``page_size``, per-page f32
    scale sidecars for quantized layouts, cur_len >= 1 per row."""
    b, n_kv, g, kdim = q_hat.shape
    dim = v.shape[-1]
    assert k_hat.shape[-1] == kdim, "q_hat/k_hat widths must match"
    bs = block_size
    paged, s_len, prefetch = _paged_args(q_hat, k_hat, cur_len, page_table,
                                         page_size, bs)
    quant = k_scale is not None
    assert not quant or (paged and v_scale is not None), \
        "per-page scales require paged caches"
    assert s_len % bs == 0, "cache length must be a multiple of block_size"
    nb = s_len // bs
    nb_pad = pad_lanes(nb)
    k_blocks = min(k_blocks, nb)
    scale = float(scale if scale is not None else dim ** -0.5)

    # d = kdim: the "approximate" stream IS the exact score pass
    kernel = functools.partial(
        _fused_kernel, paged=paged, quant=quant, ps=page_size, d=kdim,
        bs=bs, nb=nb, nb_pad=nb_pad, k_blocks=k_blocks, scale=scale, g=g,
        kdim=kdim, dim=dim, local_window=0, sliding_window=sliding_window)
    if paged:
        io_map = lambda i, j, ln, pt: (i, j, 0, 0)
    else:
        io_map = lambda i, j, ln: (i, j, 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, g, kdim), io_map),
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    inputs = [q_hat, k_hat, v]
    if quant:
        in_specs += [pl.BlockSpec(memory_space=pltpu.SMEM),
                     pl.BlockSpec(memory_space=pltpu.SMEM)]
        inputs += [k_scale.astype(jnp.float32).reshape(-1, 1),
                   v_scale.astype(jnp.float32).reshape(-1, 1)]
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, n_kv),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, g, dim), io_map),
            scratch_shapes=[
                pltpu.VMEM((2, bs, kdim), k_hat.dtype),  # full-width stream
                pltpu.VMEM((bs, kdim), k_hat.dtype),     # winner K block
                pltpu.VMEM((bs, dim), v.dtype),          # winner V block
                pltpu.VMEM((1, nb_pad), jnp.float32),    # block maxima
                pltpu.SMEM((k_blocks,), jnp.int32),      # selected blocks
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, dim), q_hat.dtype),
        interpret=interpret,
    )(*prefetch, *inputs)
    return out


def _select_kernel(*args, paged: bool, quant: bool, ps: int, d: int,
                   bs: int, nb: int, nb_pad: int, k_blocks: int,
                   scale: float, local_window: int, sliding_window: int):
    if quant:
        (len_ref, pt_ref, q_ref, k_ref, ksc_ref, out_ref,
         kd_buf, scores, sem_kd) = args
    elif paged:
        (len_ref, pt_ref, q_ref, k_ref, out_ref,
         kd_buf, scores, sem_kd) = args
    else:
        len_ref, q_ref, k_ref, out_ref, kd_buf, scores, sem_kd = args
    b = pl.program_id(0)
    h = pl.program_id(1)
    ln = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                    # (G, W)

    def kd_src(j):
        tok = j * bs
        if paged:
            row = pt_ref[b, tok // ps] * ps + tok % ps
            return k_ref.at[pl.ds(row, bs), h, pl.ds(0, d)]
        return k_ref.at[b, pl.ds(tok, bs), h, pl.ds(0, d)]

    def write_sel(t, idx):
        out_ref[0, 0, t] = idx

    _score_and_select(ln, q, kd_src, kd_buf, scores, sem_kd, write_sel,
                      d=d, bs=bs, nb=nb, nb_pad=nb_pad, k_blocks=k_blocks,
                      scale=scale, local_window=local_window,
                      sliding_window=sliding_window,
                      k_scale_at=(lambda j: ksc_ref[
                          pt_ref[b, (j * bs) // ps], 0]) if quant else None)


@kernel_entry(scalar_prefetch=("cur_len", "page_table"),
              smem_sidecars=("k_scale",),
              paged_operand="page_table", grid="(B, Hkv)")
def select_blocks(q_hat, k_hat, cur_len, *, d: int, k_blocks: int,
                  block_size: int = 128, scale=None, local_window: int = 0,
                  sliding_window: int = 0, page_table=None,
                  page_size: int = 0, k_scale=None,
                  interpret: bool = False):
    """Fused score+select: (B,Hkv,G,W),(B,S,Hkv,W),(B,) -> (B,Hkv,kb) int32
    block indices, group-shared; ``-1`` marks exhausted entries (fewer live
    blocks than kb). Scores live only in VMEM scratch. Paged caches resolve
    block reads through ``page_table`` exactly like ``fused_loki_decode``;
    quantized layouts pass the K pool's (n_pages,) ``k_scale`` sidecar."""
    b, n_kv, g, kdim = q_hat.shape
    bs = block_size
    paged, s_len, prefetch = _paged_args(q_hat, k_hat, cur_len, page_table,
                                         page_size, bs)
    quant = k_scale is not None
    assert not quant or paged, "per-page scales require paged caches"
    assert s_len % bs == 0, "cache length must be a multiple of block_size"
    nb = s_len // bs
    nb_pad = pad_lanes(nb)
    k_blocks = min(k_blocks, nb)
    scale = float(scale if scale is not None else kdim ** -0.5)

    kernel = functools.partial(
        _select_kernel, paged=paged, quant=quant, ps=page_size, d=d, bs=bs,
        nb=nb, nb_pad=nb_pad, k_blocks=k_blocks, scale=scale,
        local_window=local_window, sliding_window=sliding_window)
    if paged:
        q_map = lambda i, j, ln, pt: (i, j, 0, 0)
        o_map = lambda i, j, ln, pt: (i, j, 0)
    else:
        q_map = lambda i, j, ln: (i, j, 0, 0)
        o_map = lambda i, j, ln: (i, j, 0)
    in_specs = [
        pl.BlockSpec((1, 1, g, kdim), q_map),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    inputs = [q_hat, k_hat]
    if quant:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
        inputs.append(k_scale.astype(jnp.float32).reshape(-1, 1))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, n_kv),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, k_blocks), o_map),
            scratch_shapes=[
                pltpu.VMEM((2, bs, d), k_hat.dtype),
                pltpu.VMEM((1, nb_pad), jnp.float32),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, k_blocks), jnp.int32),
        interpret=interpret,
    )(*prefetch, *inputs)
    return out
