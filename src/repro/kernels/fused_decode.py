"""Pallas-TPU kernel: fused GQA-batched Loki decode (DESIGN.md §4).

One grid step per (batch, kv-head) pair runs the *entire* Loki decode for
that KV group — approximate scoring, block top-k selection and exact sparse
attention — without any intermediate tensor ever returning to HBM:

  1. score stream: the leading-``d`` feature slice of each K̂ block is
     double-buffer DMA'd from HBM and hit with a (G, d) @ (d, bs) MXU tile —
     all G query heads of the GQA group score the block at once. Only the
     per-group block maximum survives, in a VMEM scratch row.
  2. selection: ``k_blocks`` iterations of argmax-and-suppress over that
     VMEM row (equivalent to ``lax.top_k`` incl. lower-index tie-breaking);
     winners land in SMEM. The (B·Hkv, S)-sized score tensor and the block
     maxima that the two-pass path materializes in HBM never exist here.
  3. exact pass: each winning K̂/V block is DMA'd once *per group* (not per
     head) and folded into a (G,)-wide online softmax; the (G, bs) @ (bs, D)
     value product again batches the group onto the MXU.

Inputs are the model-native layouts — no transposes or flattening copies:

  q_hat    (B, Hkv, G, D)   PCA-basis post-RoPE queries, grouped
  k_hat    (B, S, Hkv, D)   key cache in PCA basis (full D, Lemma 4.1)
  v        (B, S, Hkv, D)
  cur_len  (B,)             valid prefix length per slot (scalar-prefetched)
Output:
  out      (B, Hkv, G, D)

``select_blocks`` exposes phases 1-2 as a standalone kernel (scores still
never leave VMEM; only the tiny index rows do) for the two-kernel fallback
that feeds ``gather_attention.block_sparse_attention_grouped``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tuning import pad_lanes

NEG_INF = -1e30


def _score_and_select(b, h, ln, q_hat, k_ref, kd_buf, scores, sem_kd,
                      write_sel, *, d: int, bs: int, nb: int, nb_pad: int,
                      k_blocks: int, scale: float):
    """Phases 1-2: stream d-slices, keep block maxima in VMEM, emit top-k.

    ``write_sel(t, idx)`` receives the t-th winning block index (descending
    score, ties to the lower index — lax.top_k order), or ``-1`` once the
    finite maxima are exhausted (fewer live blocks than k_blocks): argmax
    over an all-NEG_INF row would otherwise re-emit index 0 and double-count
    a live block in the attention pass."""
    qd = q_hat[:, :d] * scale                              # (G, d) f32

    def kd_copy(j, slot):
        return pltpu.make_async_copy(
            k_ref.at[b, pl.ds(j * bs, bs), h, pl.ds(0, d)],
            kd_buf.at[slot], sem_kd.at[slot])

    kd_copy(0, 0).start()
    scores[...] = jnp.full((1, nb_pad), NEG_INF, jnp.float32)

    def score_blk(j, carry):
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < nb)
        def _prefetch():
            kd_copy(j + 1, 1 - slot).start()

        kd_copy(j, slot).wait()
        kd = kd_buf[slot].astype(jnp.float32)              # (bs, d)
        s = jax.lax.dot_general(qd, kd, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        s = jnp.where(pos < ln, s, NEG_INF)                # (G, bs)
        scores[0, j] = jnp.max(s)
        return carry

    jax.lax.fori_loop(0, nb, score_blk, 0)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, nb_pad), 1)
    for t in range(k_blocks):
        row = scores[...]                                  # (1, nb_pad)
        idx = jnp.argmax(row, axis=1)[0].astype(jnp.int32)
        valid = jnp.max(row) > NEG_INF / 2
        write_sel(t, jnp.where(valid, idx, -1))
        scores[...] = jnp.where(lanes == idx, NEG_INF, row)


def _fused_kernel(len_ref, q_ref, k_ref, v_ref, out_ref,
                  kd_buf, kbuf, vbuf, scores, sel, sem_kd, sem_kv, *,
                  d: int, bs: int, nb: int, nb_pad: int, k_blocks: int,
                  scale: float, g: int, dim: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    ln = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)

    def write_sel(t, idx):
        sel[t] = idx

    _score_and_select(b, h, ln, q, k_ref, kd_buf, scores, sem_kd, write_sel,
                      d=d, bs=bs, nb=nb, nb_pad=nb_pad, k_blocks=k_blocks,
                      scale=scale)

    qs = q * scale                                         # (G, D)

    def att_blk(t, carry):
        m_prev, l_prev, acc = carry
        blk = sel[t]
        start = jnp.maximum(blk, 0) * bs

        @pl.when(blk >= 0)
        def _fetch():
            # -1 sentinel (exhausted selection): skip the DMA; the stale
            # buffer contents are fully masked below
            ck = pltpu.make_async_copy(
                k_ref.at[b, pl.ds(start, bs), h, pl.ds(0, dim)],
                kbuf, sem_kv.at[0])
            cv = pltpu.make_async_copy(
                v_ref.at[b, pl.ds(start, bs), h, pl.ds(0, dim)],
                vbuf, sem_kv.at[1])
            ck.start()
            cv.start()
            ck.wait()
            cv.wait()

        kb = kbuf[...].astype(jnp.float32)                 # (bs, D)
        s = jax.lax.dot_general(qs, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        live = (pos < ln) & (blk >= 0)                     # (1, bs)
        s = jnp.where(live, s, NEG_INF)                    # (G, bs)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        # guard: selected-but-dead block with an empty accumulator
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0)) \
            * (m_prev > NEG_INF / 2)
        p = jnp.exp(s - m_safe[:, None]) * live            # (G, bs)
        vb = vbuf[...].astype(jnp.float32)                 # (bs, D)
        acc = acc * alpha[:, None] + jnp.dot(
            p, vb, preferred_element_type=jnp.float32)
        return m_new, l_prev * alpha + jnp.sum(p, axis=1), acc

    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    a0 = jnp.zeros((g, dim), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, k_blocks, att_blk, (m0, l0, a0))
    out_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(
        out_ref.dtype)


def fused_loki_decode(q_hat, k_hat, v, cur_len, *, d: int, k_blocks: int,
                      block_size: int = 128, scale=None,
                      interpret: bool = False):
    """Single-pass Loki decode. (B,Hkv,G,D),(B,S,Hkv,D),(B,S,Hkv,D),(B,)
    -> (B,Hkv,G,D). Requires cur_len >= 1 per row (the decode invariant:
    the new token is already in the cache)."""
    b, n_kv, g, dim = q_hat.shape
    s_len = k_hat.shape[1]
    bs = block_size
    assert s_len % bs == 0, "cache length must be a multiple of block_size"
    nb = s_len // bs
    nb_pad = pad_lanes(nb)
    k_blocks = min(k_blocks, nb)
    scale = float(scale if scale is not None else dim ** -0.5)

    kernel = functools.partial(
        _fused_kernel, d=d, bs=bs, nb=nb, nb_pad=nb_pad, k_blocks=k_blocks,
        scale=scale, g=g, dim=dim)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, n_kv),
            in_specs=[
                pl.BlockSpec((1, 1, g, dim), lambda i, j, ln: (i, j, 0, 0)),
                # the caches stay in HBM; the kernel DMAs d-slices and the
                # winning blocks itself
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec((1, 1, g, dim),
                                   lambda i, j, ln: (i, j, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((2, bs, d), k_hat.dtype),    # score-stream buffers
                pltpu.VMEM((bs, dim), k_hat.dtype),     # winner K̂ block
                pltpu.VMEM((bs, dim), v.dtype),         # winner V block
                pltpu.VMEM((1, nb_pad), jnp.float32),   # block maxima
                pltpu.SMEM((k_blocks,), jnp.int32),     # selected blocks
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, dim), q_hat.dtype),
        interpret=interpret,
    )(cur_len.astype(jnp.int32), q_hat, k_hat, v)
    return out


def _select_kernel(len_ref, q_ref, k_ref, out_ref, kd_buf, scores, sem_kd, *,
                   d: int, bs: int, nb: int, nb_pad: int, k_blocks: int,
                   scale: float):
    b = pl.program_id(0)
    h = pl.program_id(1)
    ln = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)

    def write_sel(t, idx):
        out_ref[0, 0, t] = idx

    _score_and_select(b, h, ln, q, k_ref, kd_buf, scores, sem_kd, write_sel,
                      d=d, bs=bs, nb=nb, nb_pad=nb_pad, k_blocks=k_blocks,
                      scale=scale)


def select_blocks(q_hat, k_hat, cur_len, *, d: int, k_blocks: int,
                  block_size: int = 128, scale=None,
                  interpret: bool = False):
    """Fused score+select: (B,Hkv,G,D),(B,S,Hkv,D),(B,) -> (B,Hkv,kb) int32
    block indices, group-shared; ``-1`` marks exhausted entries (fewer live
    blocks than kb). Scores live only in VMEM scratch."""
    b, n_kv, g, dim = q_hat.shape
    s_len = k_hat.shape[1]
    bs = block_size
    assert s_len % bs == 0, "cache length must be a multiple of block_size"
    nb = s_len // bs
    nb_pad = pad_lanes(nb)
    k_blocks = min(k_blocks, nb)
    scale = float(scale if scale is not None else dim ** -0.5)

    kernel = functools.partial(
        _select_kernel, d=d, bs=bs, nb=nb, nb_pad=nb_pad, k_blocks=k_blocks,
        scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, n_kv),
            in_specs=[
                pl.BlockSpec((1, 1, g, dim), lambda i, j, ln: (i, j, 0, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec((1, 1, k_blocks),
                                   lambda i, j, ln: (i, j, 0)),
            scratch_shapes=[
                pltpu.VMEM((2, bs, d), k_hat.dtype),
                pltpu.VMEM((1, nb_pad), jnp.float32),
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, k_blocks), jnp.int32),
        interpret=interpret,
    )(cur_len.astype(jnp.int32), q_hat, k_hat)
    return out
