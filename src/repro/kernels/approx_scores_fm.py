"""Pallas-TPU kernel: feature-major approximate scores (DESIGN.md §3.1).

The token-major kernel (approx_scores.py) stages (bs, d) cache blocks into
VMEM; at small d (16/32) the d lanes of each (8,128) VMEM tile are mostly
empty — the slice wastes up to 7/8 of every tile's lane dimension.

This variant keeps the cache **feature-major**: K̂ᵀ with shape (D, S). The
d-slice is then a *sublane* slice (d ∈ {8..64} is a multiple of the 8-row
sublane granule) while the lane dimension stays a full ``bs``-token run —
every staged tile is dense. The dot becomes q̂[:d] · K̂ᵀ[:d, block], an
(1×d)·(d×bs) MXU matmul with hardware-aligned lanes.

The layout transform itself is free at cache-write time (the decode cache is
written one token-column at a time either way); ``ops.py`` exposes both
layouts and ``ref.py``'s oracle validates them against each other.

Inputs:
  q_hat    (BH, D)      query in PCA basis
  k_hat_T  (BH, D, S)   key cache in PCA basis, feature-major
  cur_len  (BH,)        valid prefix length per row (scalar-prefetched)
Outputs:
  block_max (BH, S/bs) f32 — identical semantics to the token-major kernel
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.registry import kernel_entry

NEG_INF = -1e30


def _kernel(len_ref, q_ref, kT_ref, out_ref, *, d: int, bs: int,
            scale: float):
    i = pl.program_id(0)
    j = pl.program_id(1)
    # staged blocks: q (1, d); kT (1, d, bs) — a sublane slice of the
    # feature-major cache; the bs-token lane dimension is fully dense
    q = q_ref[0].astype(jnp.float32)                      # (d,)
    kT = kT_ref[0].astype(jnp.float32)                    # (d, bs)
    s = jnp.dot(q, kT, preferred_element_type=jnp.float32) * scale  # (bs,)
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
    live = pos < len_ref[i]
    s = jnp.where(live, s, NEG_INF)
    out_ref[0, 0] = jnp.max(s)


@kernel_entry(scalar_prefetch=("cur_len",), grid="(BH, n_blocks)")
def block_max_scores_fm(q_hat, k_hat_T, cur_len, *, d: int,
                        block_size: int = 128, scale=None,
                        interpret: bool = False):
    """(BH,D),(BH,D,S),(BH,) -> (BH, S/bs) block maxima, feature-major."""
    bh, dim = q_hat.shape
    s_len = k_hat_T.shape[2]
    bs = block_size
    assert s_len % bs == 0, "cache length must be a multiple of block_size"
    assert d % 8 == 0, "feature-major slice must be sublane-aligned (8)"
    nb = s_len // bs
    scale = float(scale if scale is not None else dim ** -0.5)

    grid = (bh, nb)
    out = pl.pallas_call(
        functools.partial(_kernel, d=d, bs=bs, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, d), lambda i, j, ln: (i, 0)),
                # sublane slice: feature-block index pinned to 0, width d
                pl.BlockSpec((1, d, bs), lambda i, j, ln: (i, 0, j)),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda i, j, ln: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((bh, nb), jnp.float32),
        interpret=interpret,
    )(cur_len.astype(jnp.int32), q_hat, k_hat_T)
    return out
