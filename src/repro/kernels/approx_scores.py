"""Pallas-TPU kernel: Loki approximate scores -> per-block maxima.

Computes, for each (batch×head) row and each sequence block of the KV cache,
``max_{s in block} q̂[:d] · K̂[s,:d]`` — the statistic the block top-k
selection ranks on. Only the **leading d feature columns** of the cache ever
leave HBM: the BlockSpec's index_map pins the feature-dim block index to 0
with block width d, which is the TPU realization of the paper's "contiguous
PCA slice beats SparQ's scattered column gather" insight (DESIGN.md §3).

Also emits the masked score block itself when ``return_scores`` (used by the
token-granular variant and tests).

Inputs (already flattened over batch and query heads; GQA dedup upstream):
  q_hat   (BH, D)      query in PCA basis (post-RoPE, rotated)
  k_hat   (BH, S, D)   key cache in PCA basis
  cur_len (BH,)        valid prefix length per row (scalar-prefetched)
Outputs:
  block_max (BH, S/bs) f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.registry import kernel_entry

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, out_ref, *, d: int, bs: int,
            scale: float):
    i = pl.program_id(0)
    j = pl.program_id(1)
    # blocks are (1, d) / (1, bs, d): only the first d feature columns of
    # the cache are ever staged into VMEM
    q = q_ref[0].astype(jnp.float32)                      # (d,)
    k = k_ref[0].astype(jnp.float32)                      # (bs, d)
    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
    live = pos < len_ref[i]
    s = jnp.where(live, s, NEG_INF)
    out_ref[0, 0] = jnp.max(s)


@kernel_entry(scalar_prefetch=("cur_len",), grid="(BH, n_blocks)")
def block_max_scores(q_hat, k_hat, cur_len, *, d: int, block_size: int = 128,
                     scale=None, interpret: bool = False):
    """(BH,D),(BH,S,D),(BH,) -> (BH, S/bs) block maxima of approx scores."""
    bh, dim = q_hat.shape
    s_len = k_hat.shape[1]
    bs = block_size
    assert s_len % bs == 0, "cache length must be a multiple of block_size"
    nb = s_len // bs
    scale = float(scale if scale is not None else dim ** -0.5)

    grid = (bh, nb)
    out = pl.pallas_call(
        functools.partial(_kernel, d=d, bs=bs, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, d), lambda i, j, ln: (i, 0)),
                pl.BlockSpec((1, bs, d), lambda i, j, ln: (i, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda i, j, ln: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((bh, nb), jnp.float32),
        interpret=interpret,
    )(cur_len.astype(jnp.int32), q_hat, k_hat)
    return out
