# Pallas-TPU kernel layer for the compute hot-spots the paper itself
# optimizes (Loki's approx-score + sparse-attention decode pipeline).
#
#   approx_scores[_fm]  — block maxima of the leading-d approximate scores
#   gather_attention    — block-sparse online-softmax attention (+ GQA-batched)
#   fused_decode        — single-pass score→select→attend decode kernel
#   flash_attention     — dense flash attention (train/prefill)
#   tuning              — tile/variant selection table for decode shapes
#   ops                 — jit'd public wrappers; ref — pure-jnp oracles
