"""Tile selection for the fused Loki decode kernels (DESIGN.md §6).

``plan_decode`` maps a decode shape ``(S, D, G, bs_hint)`` to a concrete
kernel plan: which variant to run (single-pass ``fused`` vs the two-kernel
``two_pass`` fallback) and at what block size. Known-good decode shapes are
pinned in ``TUNED`` (measured on v5e; the table is tiny because the decode
problem is one-dimensional in S once D is fixed); everything else goes
through a VMEM-budget heuristic. ``None`` means no Pallas tiling works —
the dispatcher falls back to the jnp path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


# TPU tile geometry (contract metadata for repro/analysis): the lane
# (minor) dimension of a VMEM tile is always 128; the minimum sublane
# granule depends on the element width — 4-byte types pack (8, 128)
# tiles, 2-byte (16, 128), 1-byte (32, 128).
LANE = 128
SUBLANE = {4: 8, 2: 16, 1: 32}     # itemsize (bytes) -> sublane granule


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    variant: str          # "fused" | "two_kernel"
    block_size: int

    def vmem_bytes(self, *, smax: int, d: int, kdim: int, dim: int,
                   g: int, itemsize: int = 4) -> int:
        """Per-grid-step VMEM footprint of this plan, in bytes, counting
        the *padded* tiles the hardware actually allocates (every scratch
        row is rounded up to the 128-lane granule — this mirrors the
        scratch_shapes of fused_decode.py exactly, so the static checker
        and the kernel can never disagree about what fits)."""
        bs = self.block_size
        nb = smax // bs
        sub = SUBLANE.get(itemsize, 8)
        rows = -(-bs // sub) * sub
        # score stream: double-buffered (bs, d) K̂ slices + the (1, nb)
        # block-maxima row (f32)
        select = 2 * rows * pad_lanes(d) * itemsize + pad_lanes(nb) * 4
        if self.variant != "fused":
            return select
        # fused adds the winner K̂/V blocks and the (G,)-wide online
        # softmax state incl. the (G, dim) f32 accumulator + I/O blocks
        winners = rows * pad_lanes(kdim) * itemsize \
            + rows * pad_lanes(dim) * itemsize
        accum = 4 * max(g, 8) * pad_lanes(dim) * 4
        return select + winners + accum


# Per-core VMEM is ~16 MB; leave headroom for Mosaic's own pipeline buffers.
VMEM_BUDGET = 4 * 1024 * 1024

# (S, D, G, block_size hint) -> (variant, block_size). The ShapeConfig decode
# cells plus the bench shapes; extend as new cells are measured.
TUNED = {
    (32_768, 128, 1, 128): ("fused", 128),
    (32_768, 128, 4, 128): ("fused", 128),
    (32_768, 128, 8, 128): ("fused", 128),
    (524_288, 128, 1, 128): ("fused", 256),
    (524_288, 128, 8, 128): ("fused", 256),
    (4_096, 128, 4, 128): ("fused", 128),
    (4_096, 64, 4, 128): ("fused", 128),
}

_BS_CANDIDATES = (128, 64, 32, 16, 8)


def pad_lanes(n: int) -> int:
    """Round up to the 128-lane granule (shared with fused_decode's scratch
    shapes — the planner's budget must match what the kernel allocates)."""
    return -(-n // 128) * 128


def plan_full_decode(smax: int, dim: int, g: int, kdim: int,
                     block_size: int,
                     itemsize: int = 4) -> Optional[KernelPlan]:
    """Block size for the streaming full-decode kernel
    (gather_attention.paged_full_decode), or None for no-kernel.

    The streaming kernel holds only the double-buffered K/V block pair
    plus the (G,)-wide online-softmax state — no score row, no selection
    — so its working set is independent of S and the only constraints
    are divisibility and the stream buffers fitting VMEM."""
    bs = 0
    for cand in dict.fromkeys((block_size,) + _BS_CANDIDATES):
        if cand > 0 and smax % cand == 0 and smax >= cand:
            bs = cand
            break
    if not bs:
        return None
    sub = SUBLANE.get(itemsize, 8)
    rows = -(-bs // sub) * sub
    stream = 2 * rows * (pad_lanes(kdim) + pad_lanes(dim)) * itemsize
    accum = 4 * max(g, 8) * pad_lanes(dim) * 4
    if stream + accum > VMEM_BUDGET:
        return None
    return KernelPlan("stream", bs)


def plan_decode(smax: int, dim: int, g: int, d: int, block_size: int,
                itemsize: int = 4) -> Optional[KernelPlan]:
    """Pick (variant, block_size) for one decode step, or None for no-kernel.

    ``d`` is the approximate-score feature width, ``block_size`` the config
    hint, ``itemsize`` the cache dtype width in bytes."""
    key = (smax, dim, g, block_size)
    if key in TUNED:
        variant, bs = TUNED[key]
        if smax % bs == 0:
            return KernelPlan(variant, bs)

    bs = 0
    for cand in dict.fromkeys((block_size,) + _BS_CANDIDATES):
        if cand > 0 and smax % cand == 0 and smax >= cand:
            bs = cand
            break
    if not bs:
        return None

    nb = smax // bs
    score_bytes = pad_lanes(nb) * 4
    select_bytes = 2 * bs * d * itemsize + score_bytes
    if select_bytes > VMEM_BUDGET:
        return None                       # selection itself can't live on-chip
    # the single-pass kernel additionally holds both winner blocks and the
    # (G, D) accumulator set; if that working set doesn't fit, split into
    # select + pipelined gather-attention (which streams via BlockSpecs)
    fused_bytes = select_bytes + 2 * bs * dim * itemsize + 4 * g * dim * 4
    variant = "fused" if fused_bytes <= VMEM_BUDGET else "two_kernel"
    return KernelPlan(variant, bs)
