"""Pallas-TPU kernel: causal flash attention (prefill / train).

Standard online-softmax tiling: grid (BH, n_q_blocks, n_kv_blocks) with the
kv axis sequential and the accumulator in VMEM scratch. Fully-masked
(non-causal) kv blocks are skipped arithmetically (alpha=1, p=0) — on real
hardware the j > i blocks are pruned by the grid's causal upper bound per i,
which we express by masking; Mosaic hoists the no-op blocks.

The paper defers FlashAttention integration to future work (§7 Limitations);
this kernel plus gather_attention.py is that integration: prefill uses dense
flash, decode uses block-sparse flash over Loki's selection.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.registry import kernel_entry

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, scale: float, n_kv: int, causal: bool):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale               # (bq, D)
    k = k_ref[0].astype(jnp.float32)                       # (bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    if causal:
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_ref[...]                                    # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    alpha = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0)) * (m_prev > NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)                # (bq, bk)
    v_blk = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v_blk, preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _fini():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(out_ref.dtype)


@kernel_entry(grid="(BH, n_q, n_kv)")
def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    causal: bool = True, scale=None,
                    interpret: bool = False):
    """q (BH, Sq, D); k, v (BH, Sk, D) -> (BH, Sq, D)."""
    bh, sq, dim = q.shape
    sk = k.shape[1]
    bq, bk = min(block_q, sq), min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    scale = float(scale if scale is not None else dim ** -0.5)
    nq, nk = sq // bq, sk // bk

    kernel = functools.partial(_kernel, bq=bq, bk=bk, scale=scale,
                               n_kv=nk, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dim), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dim), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dim), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dim), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dim), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bh, sq, dim), q.dtype),
        interpret=interpret,
    )(q, k, v)
