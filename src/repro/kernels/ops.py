"""Jit'd public wrappers around the Pallas kernels.

``loki_decode_attention`` is the full TPU decode pipeline of the paper:

  1. block_max_scores kernel      — approx scores from d PCA dims, reading
                                    only d/D of the cache bytes
  2. lax.top_k over block maxima  — S/bs-long selection (128× cheaper than
                                    the token-level torch.topk the paper
                                    identifies as a bottleneck, §6.4)
  3. block_sparse_attention kernel — exact attention over selected blocks,
                                    streamed via scalar-prefetch index maps

``interpret=True`` runs the kernel bodies in Python on CPU (how this repo
validates them); on TPU hardware the same calls compile through Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.approx_scores import block_max_scores
from repro.kernels.approx_scores_fm import block_max_scores_fm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_decode import (fused_exact_topk_decode,
                                        fused_loki_decode, select_blocks)
from repro.kernels.gather_attention import (block_sparse_attention,
                                            block_sparse_attention_grouped,
                                            paged_full_decode)


@functools.partial(jax.jit, static_argnames=("d", "k_blocks", "block_size",
                                             "interpret"))
def loki_decode_attention(q_hat, k_hat, v, cur_len, *, d: int,
                          k_blocks: int, block_size: int = 128,
                          interpret: bool = False):
    """Full Loki decode step over flattened (BH) rows.

    q_hat (BH,D) PCA-basis post-RoPE query; k_hat (BH,S,D) PCA-basis cache;
    v (BH,S,D); cur_len (BH,). Returns (BH,D).
    """
    dim = q_hat.shape[-1]
    scale = dim ** -0.5
    blk_max = block_max_scores(q_hat, k_hat, cur_len, d=d,
                               block_size=block_size, scale=scale,
                               interpret=interpret)
    _, blk_idx = jax.lax.top_k(blk_max, k_blocks)
    return block_sparse_attention(q_hat, k_hat, v, blk_idx, cur_len,
                                  block_size=block_size, scale=scale,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("d", "k_blocks", "block_size",
                                             "interpret"))
def loki_decode_attention_fm(q_hat, k_hat_T, v, cur_len, *, d: int,
                             k_blocks: int, block_size: int = 128,
                             interpret: bool = False):
    """Feature-major scoring variant: the cache's K half is stored (BH,D,S)
    so the d-slice is sublane-aligned (DESIGN.md §3.1). The exact pass takes
    the token-major view (transpose is free for the gathered blocks)."""
    dim = q_hat.shape[-1]
    scale = dim ** -0.5
    blk_max = block_max_scores_fm(q_hat, k_hat_T, cur_len, d=d,
                                  block_size=block_size, scale=scale,
                                  interpret=interpret)
    _, blk_idx = jax.lax.top_k(blk_max, k_blocks)
    k_hat = jnp.swapaxes(k_hat_T, 1, 2)
    return block_sparse_attention(q_hat, k_hat, v, blk_idx, cur_len,
                                  block_size=block_size, scale=scale,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash(q, k, v, *, causal=True, block_q=128, block_k=128,
          interpret=False):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=interpret)


# ------------------------------------------------ GQA-batched decode paths

@functools.partial(jax.jit, static_argnames=("d", "k_blocks", "block_size",
                                             "scale", "local_window",
                                             "sliding_window", "page_size",
                                             "interpret"))
def loki_decode_fused(q_hat, k_hat, v, cur_len, *, d: int, k_blocks: int,
                      block_size: int = 128, scale=None,
                      local_window: int = 0, sliding_window: int = 0,
                      page_table=None, page_size: int = 0,
                      k_scale=None, v_scale=None,
                      interpret: bool = False):
    """Single-pass fused decode (DESIGN.md §4): score, select and attend in
    one kernel; no score/selection tensor ever reaches HBM.

    q_hat (B,Hkv,G,W) grouped PCA-basis queries (W = stored latent K width,
    <= D); k_hat (B,S,Hkv,W) / v (B,S,Hkv,D) model-native caches (or pooled
    (R,Hkv,·) with ``page_table``); cur_len (B,). Quantized PageLayouts pass
    the pools' (n_pages,) f32 ``k_scale``/``v_scale`` sidecars (paged only).
    Returns (B,Hkv,G,D)."""
    return fused_loki_decode(q_hat, k_hat, v, cur_len, d=d,
                             k_blocks=k_blocks, block_size=block_size,
                             scale=scale, local_window=local_window,
                             sliding_window=sliding_window,
                             page_table=page_table, page_size=page_size,
                             k_scale=k_scale, v_scale=v_scale,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_size", "scale",
                                             "sliding_window", "page_size",
                                             "interpret"))
def full_decode(q_hat, k_hat, v, cur_len, *, block_size: int = 128,
                scale=None, sliding_window: int = 0,
                page_table=None, page_size: int = 0,
                k_scale=None, v_scale=None, interpret: bool = False):
    """Streaming full-attention decode (the ``full`` policy's paged fast
    path): K/V stream block-by-block through the page table into a
    (G,)-wide online softmax, reading only the live prefix (or window).
    Shapes and scale sidecars follow ``loki_decode_fused``."""
    return paged_full_decode(q_hat, k_hat, v, cur_len,
                             block_size=block_size, scale=scale,
                             sliding_window=sliding_window,
                             page_table=page_table, page_size=page_size,
                             k_scale=k_scale, v_scale=v_scale,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k_blocks", "block_size",
                                             "scale", "sliding_window",
                                             "page_size", "interpret"))
def exact_topk_decode_fused(q_hat, k_hat, v, cur_len, *, k_blocks: int,
                            block_size: int = 128, scale=None,
                            sliding_window: int = 0,
                            page_table=None, page_size: int = 0,
                            k_scale=None, v_scale=None,
                            interpret: bool = False):
    """Single-pass exact-top-k decode: full-width exact scores, block
    top-k and sparse attention in one kernel — ``exact_topk``'s analogue
    of ``loki_decode_fused`` (whose paging/quantization rules it shares)."""
    return fused_exact_topk_decode(q_hat, k_hat, v, cur_len,
                                   k_blocks=k_blocks, block_size=block_size,
                                   scale=scale,
                                   sliding_window=sliding_window,
                                   page_table=page_table,
                                   page_size=page_size,
                                   k_scale=k_scale, v_scale=v_scale,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("d", "k_blocks", "block_size",
                                             "scale", "local_window",
                                             "sliding_window", "page_size",
                                             "interpret"))
def loki_decode_two_kernel(q_hat, k_hat, v, cur_len, *, d: int,
                           k_blocks: int, block_size: int = 128, scale=None,
                           local_window: int = 0, sliding_window: int = 0,
                           page_table=None, page_size: int = 0,
                           k_scale=None, v_scale=None,
                           interpret: bool = False):
    """Two-kernel fallback for shapes the single-pass kernel can't tile:
    fused score+select (scores stay in VMEM, only the (B,Hkv,kb) index rows
    cross HBM) feeding the GQA-batched sparse-attention kernel. Latent
    widths and per-page scale sidecars follow ``loki_decode_fused``."""
    blk_idx = select_blocks(q_hat, k_hat, cur_len, d=d, k_blocks=k_blocks,
                            block_size=block_size, scale=scale,
                            local_window=local_window,
                            sliding_window=sliding_window,
                            page_table=page_table, page_size=page_size,
                            k_scale=k_scale, interpret=interpret)
    return block_sparse_attention_grouped(q_hat, k_hat, v, blk_idx, cur_len,
                                          block_size=block_size, scale=scale,
                                          sliding_window=sliding_window,
                                          page_table=page_table,
                                          page_size=page_size,
                                          k_scale=k_scale, v_scale=v_scale,
                                          interpret=interpret)
