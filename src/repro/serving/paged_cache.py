"""Paged KV-cache: block-granular cache storage for the serving engine.

The dense engine preallocates one ``(n_slots, Smax, Hkv, D)`` cache per
layer, so total context is hard-capped at ``n_slots * smax`` and every slot
pays for its worst case. Here the cache is a shared **page pool**:

  pool      (n_pages * page_size, Hkv, D)   per layer, no batch dim
  page table(n_slots, max_pages) int32      logical page -> physical page

A request's logical position ``p`` lives at pool row
``table[slot, p // page_size] * page_size + p % page_size``. Pages are
handed out on demand as a request's context grows and **released** — not
destroyed — the moment it finishes (or is preempted), so memory scales with
the *live* token count, not with ``n_slots * smax``.

``page_size`` defaults to ``LokiConfig.block_size``: the fused Loki decode
kernel already treats the cache as fixed-size blocks, so a page is exactly
the kernel's DMA unit and paged decode is pure index indirection
(DESIGN.md §7).

Physical page 0 is reserved as a trash page: freed slots point their whole
table at it, so the batched decode step's unconditional cache write lands
in the trash instead of corrupting pages that have been reallocated to
other requests.

Refcounts + prefix cache (DESIGN.md §9): every held page carries a
refcount, and full prompt pages can be *registered* in a content-hash
index (a chain hash over the page's tokens and everything before them, so
two prompts share a physical page iff their token prefixes are identical).
A later request whose prompt starts with the same pages **acquires** them
(refcount++) instead of recomputing their K/V. Releasing a page whose
refcount drops to zero sends it to

  * the free list, if it was never registered, or
  * an LRU of *cached-but-unreferenced* pages, if it is in the index —
    still servable as prefix hits, reclaimed (LRU-first, index entry
    dropped) only when the free list runs dry. Eviction of unreferenced
    cached pages therefore always happens *before* the scheduler has to
    preempt a live request.

Tiered pool (DESIGN.md §13): with ``device_pages`` set, the pool splits
logical pages from device **frames**. Page ids stay the unit of the page
tables, refcounts and the prefix index; only ``device_pages`` frames of
full-D K/V rows exist in HBM. Every logical page additionally owns an
always-resident rank-r latent-K sidecar row range (allocated by
``init_paged_cache``), which is all the Loki score pass reads. A page is
in exactly one tier state:

  RESIDENT   full-D rows live in a device frame (``frame_of(page)``)
  HOST       full-D rows live in the engine's pinned host buffers
  IN_FLIGHT  a host->HBM fetch owns a frame but has not landed yet

``demote``/``promote_begin``/``promote_complete`` move pages between the
states with double-free-style guards (demoting a HOST page or promoting a
RESIDENT page raises). ``FetchQueue`` wraps the promote pair into a
bounded async queue with double-buffered staging frames.

This module is deliberately two-layered:
  * pure-jnp array helpers (``gather_logical``, ``write_token_rows``,
    ``write_chunk_rows``, ``copy_page_rows``) used inside jit,
  * the host-side ``PagePool`` allocator driven by the scheduler.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

TRASH_PAGE = 0

# tier states of a logical page in a tiered pool (DESIGN.md §13)
RESIDENT = "resident"
HOST = "host"
IN_FLIGHT = "in_flight"

_UINT_OF = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}

#: PageLayout.dtype -> jnp storage dtype of the physical pool
STORAGE_DTYPE = {"fp32": jnp.float32, "fp16": jnp.float16,
                 "bf16": jnp.bfloat16, "int8": jnp.int8,
                 "fp8": jnp.float8_e4m3fn}

# chain-hash root: the "prefix" before a prompt's first page
ROOT_KEY = b""


# ------------------------------------------------------------ jnp helpers

def logical_rows(page_table, page_size: int):
    """(B, max_pages) int32 -> (B, max_pages * page_size) pool row ids."""
    b, n = page_table.shape
    rows = page_table[:, :, None] * page_size + jnp.arange(page_size)
    return rows.reshape(b, n * page_size)


def gather_logical(pool, page_table, page_size: int):
    """Materialize the logical per-slot view of a pooled cache.

    pool (R, Hkv, D); page_table (B, max_pages)
    -> (B, max_pages * page_size, Hkv, D).

    This is the jnp-oracle read path: every dense-cache decode/attention
    routine runs unchanged on the gathered view (rows past ``cur_len`` are
    garbage from unallocated/trash pages and are masked by the caller's
    length mask exactly like the dense cache's unwritten rows)."""
    return pool[logical_rows(page_table, page_size)]


def _scatter_rows(pool, rows, new):
    """pool (R, ...) <- new (N, ...) at row ids (N,), bitcast to uint so
    low-precision scatters stay in-place on every backend (§Perf L3)."""
    dt = pool.dtype
    uint = _UINT_OF.get(jnp.dtype(dt).itemsize) if jnp.issubdtype(
        dt, jnp.floating) else None
    p_view = jax.lax.bitcast_convert_type(pool, uint) if uint else pool
    n_view = jax.lax.bitcast_convert_type(new.astype(dt), uint) if uint \
        else new.astype(dt)
    out = p_view.at[rows].set(n_view, mode="drop")
    return jax.lax.bitcast_convert_type(out, dt) if uint else out


def token_rows(page_table, pos, page_size: int):
    """Pool rows for one token per slot. page_table (B, max_pages),
    pos (B,) logical positions -> (B,) physical rows."""
    page = (pos // page_size).astype(jnp.int32)
    pid = jnp.take_along_axis(page_table, page[:, None], axis=1)[:, 0]
    return pid * page_size + (pos % page_size).astype(jnp.int32)


def write_token_rows(pool, new, page_table, pos, page_size: int):
    """Decode-step write: new (B, Hkv, D) at logical positions pos (B,)."""
    return _scatter_rows(pool, token_rows(page_table, pos, page_size), new)


def write_chunk_rows(pool, new, table_row, pos_start, page_size: int, *,
                     n_valid=None):
    """Chunked-prefill write: new (C, Hkv, D) at logical positions
    ``pos_start + [0, C)`` of a single request. table_row (max_pages,).

    ``n_valid``: rows at or past it (the zero-padding of a fixed-size final
    chunk) are diverted to the trash page so a padded chunk never needs
    pages beyond the real tokens and never clobbers live rows."""
    c = new.shape[0]
    pos = pos_start + jnp.arange(c)
    page = (pos // page_size).astype(jnp.int32)
    rows = table_row[page] * page_size + (pos % page_size).astype(jnp.int32)
    if n_valid is not None:
        rows = jnp.where(jnp.arange(c) < n_valid, rows,
                         TRASH_PAGE * page_size)
    return _scatter_rows(pool, rows, new)


# ------------------------------------------------- quantized page helpers
#
# Quantized PageLayouts store pool rows in int8/fp8 with one f32 amax scale
# per physical page (kept in a (n_pages,) sidecar next to the page table,
# one per pool — K and V scales are independent). Serving writes are
# strictly sequential per request, so a page's valid rows are always a
# prefix [0, n_valid): every write re-derives the page scale from exactly
# that prefix. A rewrite at an unchanged scale is bit-exact (the amax row
# quantizes to +-qmax, every other row reproduces its code), so the
# read-modify-write below is idempotent and only loses precision when the
# page's amax actually grows.

QUANT_EPS = 1e-8      # scale floor: all-zero (fresh) pages divide safely


def quantize_rows(x, scale, dtype, qmax: float):
    """f32 rows -> quantized codes at a given (scalar) page scale."""
    y = x / scale
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        y = jnp.clip(jnp.round(y), -qmax, qmax)
    return y.astype(dtype)


def _page_scale(rows_f32, n_valid, qmax: float):
    """amax/qmax over the valid prefix of one page's dequantized rows."""
    m = jnp.arange(rows_f32.shape[0]) < n_valid
    amax = jnp.max(jnp.abs(rows_f32) * m[:, None, None])
    return jnp.maximum(amax, QUANT_EPS) / qmax


def gather_scales(scales, page_table, page_size: int):
    """Per logical-row dequant scale. scales (n_pages,) f32;
    page_table (B, max_pages) -> (B, max_pages * page_size)."""
    s = scales[page_table]                       # (B, max_pages)
    return jnp.repeat(s, page_size, axis=1)


def gather_logical_dq(pool, scales, page_table, page_size: int):
    """``gather_logical`` + dequantization: the f32 logical view of a
    quantized pool (``scales=None`` falls through to the plain gather, so
    callers hold one code path per layout)."""
    rows = gather_logical(pool, page_table, page_size)
    if scales is None:
        return rows
    s = gather_scales(scales, page_table, page_size)
    return rows.astype(jnp.float32) * s[:, :, None, None]


def write_token_rows_q(pool, scales, new, page_table, pos, page_size: int,
                       *, qmax: float):
    """Quantized decode-step write: RMW of each slot's current page.

    pool (R, H, W) int8/fp8; scales (n_pages,) f32; new (B, H, W);
    pos (B,) logical positions. Each slot's touched page is dequantized at
    its old scale, the new row overlaid, the scale re-derived over the
    valid prefix [0, pos%ps + 1) and the page re-quantized. Slots of dead
    requests point at the trash page (page 0) and harmlessly RMW it."""
    ps = page_size
    h, w = pool.shape[1], pool.shape[2]

    def body(i, carry):
        pool, scales = carry
        page = page_table[i, pos[i] // ps]
        start = page * ps
        old = jax.lax.dynamic_slice(pool, (start, 0, 0), (ps, h, w))
        dq = old.astype(jnp.float32) * scales[page]
        off = pos[i] % ps
        dq = jax.lax.dynamic_update_slice(
            dq, new[i][None].astype(jnp.float32), (off, 0, 0))
        scale = _page_scale(dq, off + 1, qmax)
        q = quantize_rows(dq, scale, pool.dtype, qmax)
        pool = jax.lax.dynamic_update_slice(pool, q, (start, 0, 0))
        return pool, scales.at[page].set(scale)

    return jax.lax.fori_loop(0, new.shape[0], body, (pool, scales))


def write_chunk_rows_q(pool, scales, new, table_row, pos_start,
                       page_size: int, *, n_valid=None, qmax: float):
    """Quantized chunked-prefill write (one request): RMW of every page
    the chunk touches. new (C, H, W) at logical ``pos_start + [0, C)``;
    rows at or past ``n_valid`` (final-chunk padding) are never written.
    A spanned page that receives no valid row is diverted to the trash
    page so live pages are never re-quantized gratuitously."""
    ps = page_size
    c = new.shape[0]
    h, w = pool.shape[1], pool.shape[2]
    nv = c if n_valid is None else n_valid
    max_pages = table_row.shape[0]
    span = (c + ps - 1) // ps + 1                # static page-span bound

    def body(j, carry):
        pool, scales = carry
        lpage = pos_start // ps + j
        in_range = lpage < max_pages
        page = jnp.where(
            in_range, table_row[jnp.minimum(lpage, max_pages - 1)],
            TRASH_PAGE)
        g0 = lpage * ps                          # page's logical start
        ci = g0 + jnp.arange(ps) - pos_start     # page row -> chunk row
        take = (ci >= 0) & (ci < nv)
        page = jnp.where(take.any() & in_range, page, TRASH_PAGE)
        start = page * ps
        old = jax.lax.dynamic_slice(pool, (start, 0, 0), (ps, h, w))
        dq = old.astype(jnp.float32) * scales[page]
        rows = new[jnp.clip(ci, 0, c - 1)].astype(jnp.float32)
        dq = jnp.where(take[:, None, None], rows, dq)
        nv_page = jnp.clip(pos_start + nv - g0, 0, ps)
        scale = _page_scale(dq, nv_page, qmax)
        q = quantize_rows(dq, scale, pool.dtype, qmax)
        pool = jax.lax.dynamic_update_slice(pool, q, (start, 0, 0))
        return pool, scales.at[page].set(scale)

    return jax.lax.fori_loop(0, span, body, (pool, scales))


def copy_page_rows(pool, src_page, dst_page, page_size: int):
    """Copy-on-write: duplicate one physical page's rows inside a pool.

    pool (R, ...); src_page/dst_page traced int32 scalars. Used when a
    request sharing a cached tail page must diverge from it: the rows it
    read so far are copied to a freshly-allocated page, and only then does
    the request write its own tokens (the shared original stays intact for
    its other readers / the cache index)."""
    rows = jax.lax.dynamic_slice_in_dim(pool, src_page * page_size,
                                        page_size, axis=0)
    return jax.lax.dynamic_update_slice_in_dim(pool, rows,
                                               dst_page * page_size, axis=0)


def copy_page_scale(scales, src_page, dst_page):
    """COW of a quantized page's sidecar scale: codes are copied verbatim
    by ``copy_page_rows``, so the copy only stays a faithful dequant of
    the donor if its scale rides along."""
    return scales.at[dst_page].set(scales[src_page])


# --------------------------------------------------------- host allocator

def page_key(parent: bytes, tokens) -> bytes:
    """Chain hash identifying a full page of prompt tokens *in context*:
    ``parent`` is the preceding pages' key (ROOT_KEY for page 0), so equal
    keys imply equal token prefixes end to end — position-dependent K/V
    (rope, Loki's storage basis) can be shared safely."""
    h = hashlib.blake2b(digest_size=16)
    h.update(parent)
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    return h.digest()


@dataclasses.dataclass
class CacheEntry:
    """One registered (immutable, full) prompt page."""
    page: int
    key: bytes                 # chain hash incl. this page's tokens
    parent: bytes              # chain hash of the preceding pages
    tokens: np.ndarray         # this page's page_size token ids


class PagePool:
    """Host-side refcounted allocator over ``n_pages`` physical pages.

    Page 0 is reserved (trash page for freed slots' writes), so the usable
    capacity is ``n_pages - 1`` pages. Lifecycle of a usable page:

      free -> alloc() -> held (ref 1) -> acquire()/release() ref +-1
        release to ref 0:  unregistered -> free
                           registered   -> cached (LRU, evictable)
      cached -> match_prefix() hit -> held again (ref 1)
      cached -> eviction (free list empty) -> free

    ``free_pages`` counts only truly-free pages; ``cached_pages`` the
    registered-but-unreferenced LRU; ``available_pages`` their sum — the
    number ``alloc`` can actually produce. ``used_pages`` counts pages some
    request currently holds a reference to.
    """

    def __init__(self, n_pages: int, page_size: int,
                 device_pages: Optional[int] = None,
                 max_inflight: int = 2):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        if device_pages is not None and not 2 <= device_pages <= n_pages:
            raise ValueError(
                f"device_pages must be in [2, n_pages={n_pages}], "
                f"got {device_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        # seeded fault plan (serving/faults.py) this pool consults at its
        # injection sites; None = no faults (production default)
        self._faults = None
        self._free: List[int] = list(range(1, n_pages))
        # ---- tier state (None device_pages = single-tier: every page is
        # its own frame and the tier machinery degenerates to identity)
        self.device_pages = device_pages
        self.max_inflight = max_inflight
        self._free_frames: List[int] = (
            list(range(1, device_pages)) if device_pages else [])
        self._frame_of: Dict[int, int] = {}   # RESIDENT | IN_FLIGHT pages
        self._tier: Dict[int, str] = {}       # allocated/cached pages only
        self._pinned: Dict[int, int] = {}     # page -> pin count
        self._inflight: Dict[int, int] = {}   # page -> staging frame
        self.n_demoted = 0
        self.n_promoted = 0
        self._ref: Dict[int, int] = {}
        # prefix-cache index over *full* prompt pages
        self._index: Dict[bytes, CacheEntry] = {}
        self._children: Dict[bytes, List[CacheEntry]] = {}
        self._by_page: Dict[int, CacheEntry] = {}
        # registered pages with refcount 0, oldest-released first
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        # counters (benchmarks / hit-rate assertions)
        self.n_lookups = 0
        self.n_hits = 0
        self.n_hit_tokens = 0
        self.n_evicted = 0
        self._priv_ctr = 0          # unique private-entry keys

    # --------------------------------------------------- fault injection

    def set_faults(self, plan) -> None:
        """Attach a serving/faults.py FaultPlan; the pool consults it at
        ``alloc`` (alloc_fail) and ``available_pages`` (pool_exhaustion).
        The engine owns advancing the plan's tick."""
        self._faults = plan

    def _fault(self, site: str, unit: int = 0) -> bool:
        return self._faults is not None and self._faults.hit(site, unit)

    # ------------------------------------------------------- accounting

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Registered pages no request references — servable as prefix
        hits, reclaimable by ``alloc`` without preempting anyone."""
        return len(self._lru)

    @property
    def available_pages(self) -> int:
        """What ``alloc`` can produce: free plus evictable cached pages.
        An injected ``pool_exhaustion`` fault reads as 0 for the whole
        tick — callers see a full pool and exercise their pressure
        paths — without touching any real accounting."""
        if self._fault("pool_exhaustion"):
            return 0
        return len(self._free) + len(self._lru)

    @property
    def used_pages(self) -> int:
        """Pages some request currently holds a reference to (cached-but-
        unreferenced pages are *not* used — they are reclaimable)."""
        return (self.n_pages - 1) - len(self._free) - len(self._lru)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def is_registered(self, page: int) -> bool:
        return page in self._by_page

    def is_private(self, page: int) -> bool:
        """Is this page a ``register_private`` retained entry (never
        shareable — the auditor's invariant D checks no slot pair ever
        aliases one)?"""
        e = self._by_page.get(page)
        return e is not None and e.key.startswith(b"priv:")

    # read-only views for the invariant auditor (serving/faults.py): the
    # auditor re-derives accounting from these instead of groping private
    # state, so the pool can change representation without breaking it
    def free_page_ids(self) -> List[int]:
        return list(self._free)

    def lru_page_ids(self) -> List[int]:
        return list(self._lru)

    def holders(self) -> Dict[int, int]:
        """page -> refcount for every currently-held page (a copy)."""
        return dict(self._ref)

    # ------------------------------------------------------- tiered state
    #
    # The pool is pure bookkeeping: the *engine* owns the device pools and
    # the host byte buffers and performs the actual copies. The contract
    # is copy-then-demote (full-D rows must be on host before the frame is
    # surrendered) and promote_begin-copy-promote_complete (the frame is
    # owned by the fetch from begin to complete).

    @property
    def tiered(self) -> bool:
        return self.device_pages is not None

    @property
    def free_frames(self) -> int:
        return len(self._free_frames)

    def tier_of(self, page: int) -> str:
        """Tier state of an allocated/cached page (single-tier pools and
        the trash page are RESIDENT by definition)."""
        if not self.tiered or page == TRASH_PAGE:
            return RESIDENT
        state = self._tier.get(page)
        if state is None:
            raise ValueError(f"tier_of() of free page {page}")
        return state

    def frame_of(self, page: int) -> Optional[int]:
        """Device frame holding a page's full-D rows: the page id itself
        in a single-tier pool, the mapped frame for RESIDENT/IN_FLIGHT
        pages of a tiered pool, None for HOST pages."""
        if not self.tiered:
            return page
        if page == TRASH_PAGE:
            return TRASH_PAGE
        return self._frame_of.get(page)

    def pin(self, page: int) -> None:
        """Pin a RESIDENT page against demotion (tail pages receiving
        decode writes, pages of a slot mid-prefill)."""
        if not self.tiered or page == TRASH_PAGE:
            return
        if self._tier.get(page) != RESIDENT:
            raise ValueError(f"pin of non-resident page {page}")
        self._pinned[page] = self._pinned.get(page, 0) + 1

    def unpin(self, page: int) -> None:
        if not self.tiered or page == TRASH_PAGE:
            return
        if self._pinned.get(page, 0) <= 0:
            raise ValueError(f"unpin of unpinned page {page}")
        self._pinned[page] -= 1
        if self._pinned[page] == 0:
            del self._pinned[page]

    def is_pinned(self, page: int) -> bool:
        return self._pinned.get(page, 0) > 0

    def demote(self, page: int) -> int:
        """Surrender a RESIDENT page's frame (its full-D rows must already
        be in the host buffers — the engine copies first). Returns the
        freed frame. Raises like a double-free on a page that is already
        HOST, mid-fetch, pinned, or the trash page."""
        if not self.tiered:
            raise ValueError("demote() on a single-tier pool")
        if page == TRASH_PAGE:
            raise ValueError("demote of the reserved trash page")
        state = self._tier.get(page)
        if state == HOST:
            raise ValueError(f"double-demote of page {page}")
        if state != RESIDENT:
            raise ValueError(f"demote of {state or 'free'} page {page}")
        if self._pinned.get(page, 0):
            raise ValueError(f"demote of pinned page {page}")
        frame = self._frame_of.pop(page)
        self._free_frames.append(frame)
        self._tier[page] = HOST
        self.n_demoted += 1
        return frame

    def promote_begin(self, page: int, faultable: bool = True
                      ) -> Optional[int]:
        """Claim a staging frame for a HOST page's host->HBM fetch.

        Returns the frame (page becomes IN_FLIGHT; the engine copies, then
        ``promote_complete``), or None when no frame is free, the bounded
        in-flight budget is exhausted, or an ``hbm_oom_on_promote`` fault
        fires — callers run their demote/retry/preempt ladder. Promoting a
        RESIDENT or IN_FLIGHT page raises like a double-free."""
        if not self.tiered:
            raise ValueError("promote_begin() on a single-tier pool")
        state = self._tier.get(page)
        if state in (RESIDENT, IN_FLIGHT):
            raise ValueError(f"promote of {state} page {page}")
        if state != HOST:
            raise ValueError(f"promote of free page {page}")
        if faultable and self._fault("hbm_oom_on_promote", page):
            return None
        if not self._free_frames or len(self._inflight) >= self.max_inflight:
            return None
        frame = self._free_frames.pop()
        self._frame_of[page] = frame
        self._tier[page] = IN_FLIGHT
        self._inflight[page] = frame
        return frame

    def promote_complete(self, page: int) -> int:
        """The fetch landed: IN_FLIGHT -> RESIDENT. Returns the frame."""
        if self._tier.get(page) != IN_FLIGHT:
            raise ValueError(
                f"promote_complete of page {page} with no fetch in flight")
        del self._inflight[page]
        self._tier[page] = RESIDENT
        self.n_promoted += 1
        return self._frame_of[page]

    def promote_abort(self, page: int) -> None:
        """A fetch that never landed (dma_timeout): give the staging frame
        back and return the page to HOST so a synchronous retry can claim
        a fresh fetch."""
        if self._tier.get(page) != IN_FLIGHT:
            raise ValueError(
                f"promote_abort of page {page} with no fetch in flight")
        del self._inflight[page]
        self._free_frames.append(self._frame_of.pop(page))
        self._tier[page] = HOST

    def _tier_free(self, page: int) -> None:
        """Clear a page's tier state as it returns to the free list.
        The in-flight check comes before any mutation: a refused free
        must leave the tier partition untouched (the fetch still owns
        its staging frame)."""
        if not self.tiered:
            return
        state = self._tier.get(page)
        if state == IN_FLIGHT:
            raise ValueError(f"free of in-flight page {page}")
        self._tier.pop(page, None)
        if state == RESIDENT:
            self._free_frames.append(self._frame_of.pop(page))
        self._pinned.pop(page, None)

    # auditor views over the tier partition (serving/faults.py invariants
    # G/H/I re-derive the accounting from these copies)
    def resident_page_ids(self) -> List[int]:
        return [p for p, s in self._tier.items() if s == RESIDENT]

    def host_page_ids(self) -> List[int]:
        return [p for p, s in self._tier.items() if s == HOST]

    def inflight_page_ids(self) -> List[int]:
        return list(self._inflight)

    def free_frame_ids(self) -> List[int]:
        return list(self._free_frames)

    def pinned_page_ids(self) -> List[int]:
        return [p for p, n in self._pinned.items() if n > 0]

    def frame_map(self) -> Dict[int, int]:
        """page -> frame for every RESIDENT/IN_FLIGHT page (a copy)."""
        return dict(self._frame_of)

    def deregister(self, page: int) -> None:
        """Drop a *held* page's index entry (no-op if unregistered). The
        sole-reader arm of copy-on-write uses this to take ownership in
        place: the caller is about to overwrite rows, so the cached
        content ceases to exist and a copy would preserve data nobody
        else references. Unreferenced cached pages are reclaimed through
        ``_evict_one`` instead."""
        e = self._by_page.get(page)
        if e is None:
            return
        if self._ref.get(page, 0) <= 0:
            raise ValueError(f"deregister of unheld page {page}")
        self._drop_entry(e)

    def _drop_entry(self, e: CacheEntry) -> None:
        """Remove an entry from all three index views (page stays as-is)."""
        del self._by_page[e.page]
        del self._index[e.key]
        sibs = self._children[e.parent]
        sibs.remove(e)
        if not sibs:
            del self._children[e.parent]

    # ------------------------------------------------------- alloc/free

    def alloc(self, n: int) -> Optional[List[int]]:
        """Grab n fresh pages (refcount 1), or None (and no allocation /
        eviction) if the pool can't. Eviction of cached-but-unreferenced
        pages (LRU first) backs the free list, so a full cache never
        forces a preemption while reclaimable pages exist. ``alloc(0)``
        returns ``[]`` without touching the free list."""
        if n == 0:
            return []
        if self._fault("alloc_fail", n):
            return None       # injected: as if the free list ran dry
        if n > self.available_pages:
            return None
        if self.tiered:
            # fresh pages receive writes, so each needs a device frame;
            # evictable cached pages may carry reclaimable frames, but if
            # even those can't cover the request the caller must demote
            # cold resident pages (policy hook) before retrying
            lru_frames = sum(1 for p in self._lru
                             if self._tier.get(p) == RESIDENT)
            if n > len(self._free_frames) + lru_frames:
                return None
            while len(self._free_frames) < n:
                self._evict_one()
        while len(self._free) < n:
            self._evict_one()
        taken, self._free = self._free[:n], self._free[n:]
        for p in taken:
            self._ref[p] = 1
            if self.tiered:
                self._tier[p] = RESIDENT
                self._frame_of[p] = self._free_frames.pop()
        return taken

    def acquire(self, pages: List[int]) -> List[int]:
        """Take an additional reference on already-held or cached pages
        (sharing). ``acquire([])`` returns ``[]`` without touching any
        state. Raises on a page nobody holds and the index doesn't know —
        that would be acquiring a free page out of thin air."""
        for p in pages:
            if p == TRASH_PAGE:
                raise ValueError("acquire of the reserved trash page")
            if self._ref.get(p, 0) == 0 and p not in self._by_page:
                raise ValueError(f"acquire of unheld page {p}")
        for p in pages:
            self._acquire_one(p)
        return pages

    def _acquire_one(self, page: int) -> None:
        self._ref[page] = self._ref.get(page, 0) + 1
        self._lru.pop(page, None)

    def release(self, pages: List[int]) -> None:
        """Drop one reference per page. At refcount zero the page returns
        to the free list — or, if registered in the prefix index, to the
        cached-unreferenced LRU (still hittable, evicted on demand).

        Raises (rather than asserts, so ``python -O`` keeps the guard) on
        a refcount underflow — the refcounted equivalent of a double-free
        — or an attempt to release the reserved trash page."""
        seen: Dict[int, int] = {}
        for p in pages:
            if p == TRASH_PAGE:
                raise ValueError("free() of the reserved trash page")
            seen[p] = seen.get(p, 0) + 1
            if self._ref.get(p, 0) < seen[p]:
                raise ValueError(
                    f"double-free of page {p} (refcount underflow)")
        for p, c in seen.items():
            # all-or-nothing: a free that would drop an IN_FLIGHT page to
            # the free list must refuse before any refcount moves (the
            # fetch still owns the page's staging frame)
            if self.tiered and self._ref.get(p, 0) == c \
                    and p not in self._by_page \
                    and self._tier.get(p) == IN_FLIGHT:
                raise ValueError(f"free of in-flight page {p}")
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                if p in self._by_page:
                    self._lru[p] = None          # MRU end of the LRU
                else:
                    self._tier_free(p)
                    self._free.append(p)

    # released pages historically went through ``free``; release IS free
    # under refcounts (ref 1 -> 0), so keep the old name as an alias
    free = release

    def _evict_one(self) -> None:
        """Reclaim the least-recently-released cached page: drop its index
        entry and hand the physical page to the free list."""
        page, _ = self._lru.popitem(last=False)
        self._drop_entry(self._by_page[page])
        self._tier_free(page)
        self._free.append(page)
        self.n_evicted += 1

    # ------------------------------------------------------ prefix cache

    def register(self, page: int, parent: bytes, tokens) -> bytes:
        """Publish a held, fully-written prompt page under its chain hash.
        Returns the page's key (the next page's ``parent``). A key that is
        already indexed keeps its existing physical page (first writer
        wins); the caller's copy stays private. Registered pages are
        immutable: the engine never writes a row of a registered page
        again (COW duplicates first)."""
        toks = np.ascontiguousarray(tokens, np.int32)
        if toks.shape[0] != self.page_size:
            raise ValueError("register() needs exactly one full page of "
                             f"tokens ({self.page_size}), got {toks.shape}")
        key = page_key(parent, toks)
        if key in self._index or page in self._by_page:
            return key
        if self._ref.get(page, 0) <= 0:
            raise ValueError(f"register of unheld page {page}")
        e = CacheEntry(page, key, parent, toks)
        self._index[key] = e
        self._children.setdefault(parent, []).append(e)
        self._by_page[page] = e
        return key

    def match_prefix(self, tokens, max_tokens: int
                     ) -> Tuple[List[int], int, bool, bytes]:
        """Longest cached prefix of ``tokens[:max_tokens]``, acquired.

        Walks the chain hash over full pages; after the last full-page hit
        it additionally tries a *partial tail*: a registered sibling page
        whose first rows match the remaining tokens (the classic shared-
        system-prompt case where the split falls mid-page). Matched pages
        come back with a reference taken (caller releases them like any
        other page).

        Returns (pages, n_matched_tokens, tail_is_partial, parent_key)
        where ``parent_key`` is the chain hash after the *full* matches —
        the key the caller threads into ``register`` for the pages it goes
        on to compute itself."""
        ps = self.page_size
        toks = np.ascontiguousarray(tokens, np.int32)
        pages: List[int] = []
        n, parent = 0, ROOT_KEY
        while n + ps <= max_tokens:
            key = page_key(parent, toks[n:n + ps])
            e = self._index.get(key)
            if e is None:
                break
            self._acquire_one(e.page)
            pages.append(e.page)
            parent = key
            n += ps
        tail = False
        rem = min(max_tokens - n, ps)   # rem == ps: full lookup missed but
        if rem > 0:                     # a shorter overlap may still exist
            best, best_j = None, 0
            for e in self._children.get(parent, ()):  # longest overlap wins
                j = int((e.tokens[:rem] == toks[n:n + rem]).cumprod().sum())
                if j > best_j:
                    best, best_j = e, j
            if best is not None:
                self._acquire_one(best.page)
                pages.append(best.page)
                n += best_j
                tail = True
        self.n_lookups += 1
        if pages:
            self.n_hits += 1
        self.n_hit_tokens += n
        return pages, n, tail, parent

    # ---------------------------------------------------- private entries

    def register_private(self, page: int) -> bytes:
        """Index a *held* page under a unique private key.

        Private entries give a page the cached-page lifecycle (release ->
        LRU, evictable under pressure, reclaimable by key) without ever
        being shareable: the key is a counter tag, so it can never collide
        with a chain hash and ``match_prefix`` can never walk into it.
        Preemption uses this to retain a hybrid request's own K/V pages —
        whose content depends on that request's recurrent state, not just
        its tokens — so a state snapshot plus reclaimed pages can resume
        it without recompute."""
        if self._ref.get(page, 0) <= 0:
            raise ValueError(f"register_private of unheld page {page}")
        if page in self._by_page:
            raise ValueError(f"page {page} is already registered")
        self._priv_ctr += 1
        key = b"priv:%d" % self._priv_ctr
        e = CacheEntry(page, key, key, np.empty(0, np.int32))
        self._index[key] = e
        self._children.setdefault(key, []).append(e)
        self._by_page[page] = e
        return key

    def reclaim_private(self, keys) -> Optional[List[int]]:
        """All-or-nothing reclaim of ``register_private`` entries.

        If every key survived eviction: re-acquire each page (ref 0 -> 1,
        out of the LRU), drop the private index entries (the pages go back
        to plain held pages) and return them in key order. If *any* page
        was evicted the retained set is useless — the snapshot's state
        covers exactly the full prefix — so the survivors are dropped from
        the index and freed immediately; returns None (caller recomputes)."""
        if any(k not in self._index for k in keys):
            for k in keys:
                e = self._index.get(k)
                if e is None:
                    continue
                self._drop_entry(e)
                if e.page in self._lru:
                    self._lru.pop(e.page)
                    self._tier_free(e.page)
                    self._free.append(e.page)
            return None
        pages = []
        for k in keys:
            e = self._index[k]
            self._acquire_one(e.page)
            self._drop_entry(e)
            pages.append(e.page)
        return pages

    @staticmethod
    def pages_for(n_tokens: int, page_size: int) -> int:
        """Pages needed to hold n_tokens."""
        return -(-max(n_tokens, 0) // page_size)


# ------------------------------------------------------- async fetch queue

class FetchQueue:
    """Bounded async host->HBM promotion queue over a tiered PagePool.

    ``request(page)`` claims a staging frame (``promote_begin``), dispatches
    the engine-supplied copy (jax dispatch is async, so the DMA overlaps
    whatever the host enqueues next — the next layer's score pass in the
    tiered decode pipeline) and tracks the fetch as IN_FLIGHT. The queue
    holds at most ``pool.max_inflight`` outstanding fetches (default 2:
    double-buffered staging); requesting past the budget completes the
    oldest fetch first, so issue order is also landing order.

    ``drain()`` is the barrier before the sparse-attention pass reads the
    frame table: every outstanding fetch is completed (or, under an
    injected ``dma_timeout``, aborted and re-copied synchronously — the
    counted fallback path).
    """

    def __init__(self, pool: PagePool, copy_fn, faults=None):
        self.pool = pool
        self._copy = copy_fn            # copy_fn(page, frame) -> None
        self._faults = faults
        self._pending: "collections.deque[int]" = collections.deque()
        self.n_issued = 0
        self.n_sync_fallback = 0

    def request(self, page: int) -> bool:
        """Start fetching a HOST page; False if no staging frame could be
        claimed (frame pressure or an hbm_oom_on_promote fault) — the
        caller runs its demote/retry/preempt ladder and may re-request."""
        if self._pending and len(self._pending) >= self.pool.max_inflight:
            self._complete(self._pending.popleft())
        frame = self.pool.promote_begin(page)
        if frame is None:
            return False
        self._copy(page, frame)
        self._pending.append(page)
        self.n_issued += 1
        return True

    def _complete(self, page: int) -> None:
        if self._faults is not None and self._faults.hit("dma_timeout",
                                                         page):
            # the async fetch never landed: give the staging frame back,
            # then fall back to a synchronous claim+copy (not faultable —
            # this *is* the fallback) and count it
            self.pool.promote_abort(page)
            frame = self.pool.promote_begin(page, faultable=False)
            if frame is None:       # budget freed by the abort above
                raise RuntimeError(
                    f"sync fallback could not claim a frame for {page}")
            self._copy(page, frame)
            self.n_sync_fallback += 1
        self.pool.promote_complete(page)

    def drain(self) -> None:
        """Complete every outstanding fetch (barrier before the frame
        table is rebuilt for the sparse-attention pass)."""
        while self._pending:
            self._complete(self._pending.popleft())

    @property
    def in_flight(self) -> int:
        return len(self._pending)
