"""Paged KV-cache: block-granular cache storage for the serving engine.

The dense engine preallocates one ``(n_slots, Smax, Hkv, D)`` cache per
layer, so total context is hard-capped at ``n_slots * smax`` and every slot
pays for its worst case. Here the cache is a shared **page pool**:

  pool      (n_pages * page_size, Hkv, D)   per layer, no batch dim
  page table(n_slots, max_pages) int32      logical page -> physical page

A request's logical position ``p`` lives at pool row
``table[slot, p // page_size] * page_size + p % page_size``. Pages are
handed out on demand as a request's context grows and returned to the free
list the moment it finishes (or is preempted), so memory scales with the
*live* token count, not with ``n_slots * smax``.

``page_size`` defaults to ``LokiConfig.block_size``: the fused Loki decode
kernel already treats the cache as fixed-size blocks, so a page is exactly
the kernel's DMA unit and paged decode is pure index indirection
(DESIGN.md §7).

Physical page 0 is reserved as a trash page: freed slots point their whole
table at it, so the batched decode step's unconditional cache write lands
in the trash instead of corrupting pages that have been reallocated to
other requests.

This module is deliberately two-layered:
  * pure-jnp array helpers (``gather_logical``, ``write_token_rows``,
    ``write_chunk_rows``) used inside jit by models/ and core/,
  * the host-side ``PagePool`` allocator driven by the scheduler.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

TRASH_PAGE = 0

_UINT_OF = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}


# ------------------------------------------------------------ jnp helpers

def logical_rows(page_table, page_size: int):
    """(B, max_pages) int32 -> (B, max_pages * page_size) pool row ids."""
    b, n = page_table.shape
    rows = page_table[:, :, None] * page_size + jnp.arange(page_size)
    return rows.reshape(b, n * page_size)


def gather_logical(pool, page_table, page_size: int):
    """Materialize the logical per-slot view of a pooled cache.

    pool (R, Hkv, D); page_table (B, max_pages)
    -> (B, max_pages * page_size, Hkv, D).

    This is the jnp-oracle read path: every dense-cache decode/attention
    routine runs unchanged on the gathered view (rows past ``cur_len`` are
    garbage from unallocated/trash pages and are masked by the caller's
    length mask exactly like the dense cache's unwritten rows)."""
    return pool[logical_rows(page_table, page_size)]


def _scatter_rows(pool, rows, new):
    """pool (R, ...) <- new (N, ...) at row ids (N,), bitcast to uint so
    low-precision scatters stay in-place on every backend (§Perf L3)."""
    dt = pool.dtype
    uint = _UINT_OF.get(jnp.dtype(dt).itemsize) if jnp.issubdtype(
        dt, jnp.floating) else None
    p_view = jax.lax.bitcast_convert_type(pool, uint) if uint else pool
    n_view = jax.lax.bitcast_convert_type(new.astype(dt), uint) if uint \
        else new.astype(dt)
    out = p_view.at[rows].set(n_view, mode="drop")
    return jax.lax.bitcast_convert_type(out, dt) if uint else out


def token_rows(page_table, pos, page_size: int):
    """Pool rows for one token per slot. page_table (B, max_pages),
    pos (B,) logical positions -> (B,) physical rows."""
    page = (pos // page_size).astype(jnp.int32)
    pid = jnp.take_along_axis(page_table, page[:, None], axis=1)[:, 0]
    return pid * page_size + (pos % page_size).astype(jnp.int32)


def write_token_rows(pool, new, page_table, pos, page_size: int):
    """Decode-step write: new (B, Hkv, D) at logical positions pos (B,)."""
    return _scatter_rows(pool, token_rows(page_table, pos, page_size), new)


def write_chunk_rows(pool, new, table_row, pos_start, page_size: int, *,
                     n_valid=None):
    """Chunked-prefill write: new (C, Hkv, D) at logical positions
    ``pos_start + [0, C)`` of a single request. table_row (max_pages,).

    ``n_valid``: rows at or past it (the zero-padding of a fixed-size final
    chunk) are diverted to the trash page so a padded chunk never needs
    pages beyond the real tokens and never clobbers live rows."""
    c = new.shape[0]
    pos = pos_start + jnp.arange(c)
    page = (pos // page_size).astype(jnp.int32)
    rows = table_row[page] * page_size + (pos % page_size).astype(jnp.int32)
    if n_valid is not None:
        rows = jnp.where(jnp.arange(c) < n_valid, rows,
                         TRASH_PAGE * page_size)
    return _scatter_rows(pool, rows, new)


# --------------------------------------------------------- host allocator

class PagePool:
    """Host-side free-list allocator over ``n_pages`` physical pages.

    Page 0 is reserved (trash page for freed slots' writes), so the usable
    capacity is ``n_pages - 1`` pages. Finished/preempted requests free
    their pages immediately — the eviction policy is "free on finish";
    under pressure the scheduler additionally preempts (see scheduler.py).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(1, n_pages))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Grab n pages, or None (and no allocation) if the pool can't."""
        if n > len(self._free):
            return None
        taken, self._free = self._free[:n], self._free[n:]
        return taken

    def free(self, pages: List[int]) -> None:
        """Return pages to the free list.

        Raises (rather than asserts, so ``python -O`` keeps the guard) on a
        double-free or an attempt to free the reserved trash page — the
        failure mode window-recycling bookkeeping would hit if a recycled
        page were freed again at release/preemption."""
        seen = set()
        for p in pages:
            if p == TRASH_PAGE:
                raise ValueError("free() of the reserved trash page")
            if p in self._free or p in seen:
                raise ValueError(f"double-free of page {p}")
            seen.add(p)
        self._free.extend(pages)

    @staticmethod
    def pages_for(n_tokens: int, page_size: int) -> int:
        """Pages needed to hold n_tokens."""
        return -(-max(n_tokens, 0) // page_size)
