"""Slot-based batched serving engine.

The paper (§6.4) finds >80% of HuggingFace decode time is KV-cache *append*
(concatenation re-allocates the cache every token). This engine removes the
append entirely: the cache is preallocated (B_slots, Smax, ...) ring storage
and decode writes in place — the design the paper defers to "a more advanced
inference system like vLLM".

Continuous batching (lite): requests join free slots; every engine tick runs
one batched decode step over all active slots; finished requests free their
slot. Per-slot positions make ragged batches exact.
"""
from __future__ import annotations

import dataclasses
import time
from typing import (Any, Callable, Dict, List, Optional, Protocol,
                    runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serving import cache_spec as CS


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S_p,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # scheduling class: higher is more urgent. FIFO ignores it; the paged
    # engine's priority policy admits (and, for strictly higher classes,
    # preempts) by it. Ties fall back to arrival order.
    priority: int = 0
    t_submit: float = 0.0         # set by submit(); for latency reporting
    t_first: float = 0.0          # first generated token (TTFT reporting)
    t_done: float = 0.0           # set when the request finishes
    # encoder-decoder (whisper): precomputed frame embeddings (enc_seq,
    # d_model); the engine runs the encoder once at admission
    frames: Optional[np.ndarray] = None


def context_cap(smax: int, gen_tokens: int) -> int:
    """Prompt rows a fresh admission may occupy: reserve headroom for the
    generation, capped at half the context so an outsized max_new degrades
    to a capacity-capped run instead of eating the whole prompt (full
    max_new is guaranteed for max_new <= smax//2). Shared by both engines
    so their admitted context — and therefore greedy outputs — agree."""
    return max(smax - min(gen_tokens, smax // 2), 1)


@runtime_checkable
class Engine(Protocol):
    """What a serving engine looks like to harnesses (benchmarks, serve
    CLI, tests): submit requests, advance ticks, drain to completion, and
    report counters — one surface across the dense and paged engines, so
    callers never branch on the engine kind."""

    def submit(self, req: "Request") -> None: ...

    def tick(self, rng: Optional[jax.Array] = None) -> None: ...

    def drain(self, max_ticks: int = 10_000,
              rng: Optional[jax.Array] = None) -> None: ...

    def stats(self) -> Dict[str, Any]: ...


def sample_next(logits, *, greedy: bool, rng, ticks: int):
    """Shared next-token rule for both engines: greedy argmax, or
    categorical with the caller's key (falling back to PRNGKey(tick) —
    thread a real rng via run_until_done for independent draws)."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    rng = rng if rng is not None else jax.random.PRNGKey(ticks)
    return jax.random.categorical(rng, logits).astype(jnp.int32)


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 smax: int = 512, eos_id: Optional[int] = None,
                 greedy: bool = True, backend: Optional[str] = None):
        if backend is not None:
            # route the decode hot path through the chosen kernel backend
            # (core/dispatch.py): "pallas" | "xla" | "auto"
            cfg = cfg.replace(
                loki=dataclasses.replace(cfg.loki, backend=backend))
        self.params, self.cfg = params, cfg
        self.n_slots, self.smax = n_slots, smax
        self.eos_id, self.greedy = eos_id, greedy
        self.cache = lm.init_cache(cfg, n_slots, smax, jnp.float32)
        # recurrent-state families only: batch-1 init values so an
        # admission that skips prefill (1-token prompt) can reset its
        # slot's state — a previous occupant's mamba/xlstm state must not
        # leak into the new request. Attention-only families need nothing:
        # stale K/V rows beyond the slot's position are unreachable.
        self._fresh_state = CS.fresh_state_tree(cfg, jnp.float32,
                                                include_cross=False)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.live = np.zeros((n_slots,), bool)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.last_tok = jnp.zeros((n_slots,), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t, pl: lm.decode_step(p, cfg, c, t, pl))
        # admission-path prefill, compiled; jit's cache retraces only per
        # distinct prompt length
        self._prefill = jax.jit(
            lambda p, t, fr: lm.prefill(p, cfg, t, smax, frames=fr,
                                        cache_dtype=jnp.float32))
        self._queue: List[Request] = []
        self.ticks = 0

    # ------------------------------------------------------------ admin

    def submit(self, req: Request) -> None:
        req.t_submit = time.time()
        self._queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.live[slot] or not self._queue:
                continue
            req = self._queue.pop(0)
            self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Single-request batched prefill into one slot.

        One causal-attention pass over the whole prompt, scattered into the
        slot's cache rows only — live slots are untouched. (The previous
        token-by-token fill ran a full batched decode step per prompt token,
        rewriting every live slot's cache at its current position.)"""
        toks = req.prompt.astype(np.int32)
        # cache can hold smax rows; keep the most recent context AND leave
        # generation headroom — truncating to smax itself left pos at
        # smax-1, so the finish guard ended the request after a single
        # generated token
        cap = context_cap(self.smax, req.max_new)
        if len(toks) > cap:
            toks = toks[-cap:]
        self.pos = self.pos.at[slot].set(0)
        fr = None
        if self.cfg.is_encoder_decoder:
            if req.frames is None:
                raise ValueError("encoder-decoder serving needs "
                                 "Request.frames (enc_seq, d_model)")
            fr = jnp.asarray(req.frames)[None]
        if len(toks) > 1:
            _, filled, _ = self._prefill(self.params,
                                         jnp.asarray(toks[None, :-1]), fr)
            self._write_slot(slot, filled)
            self.pos = self.pos.at[slot].set(len(toks) - 1)
        elif self.cfg.is_encoder_decoder:
            # 1-token prompt: nothing to cache, but the slot still needs
            # its cross K/V — prefill the single token and keep pos=0 (the
            # decode step rewrites the same cache row with identical
            # values, so the continuation is unchanged)
            _, filled, _ = self._prefill(self.params, jnp.asarray(toks[None]),
                                         fr)
            self._write_slot(slot, filled)
        elif self._fresh_state is not None:
            self.cache = {"layers": CS.reset_slot_state(
                self.cache["layers"], self._fresh_state, slot,
                lm.uses_scan(self.cfg))}
        self.last_tok = self.last_tok.at[slot].set(int(toks[-1]))
        self.slot_req[slot] = req
        self.live[slot] = True

    def _write_slot(self, slot: int, one) -> None:
        """Overwrite one slot's cache slice with a (batch-1) cache tree."""
        axis = 1 if lm.uses_scan(self.cfg) else 0      # skip the layer axis
        self.cache = jax.tree.map(
            lambda full, single: jax.lax.dynamic_update_slice_in_dim(
                full, single.astype(full.dtype), slot, axis=axis),
            self.cache, one)

    # ------------------------------------------------------------- tick

    def tick(self, rng: Optional[jax.Array] = None) -> None:
        self._admit()
        if not self.live.any():
            return
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_tok, self.pos)
        self.pos = self.pos + jnp.asarray(self.live, jnp.int32)
        nxt_np = np.asarray(sample_next(logits, greedy=self.greedy,
                                        rng=rng, ticks=self.ticks))
        # one device->host sync for all slots (a per-slot int(self.pos[slot])
        # in the loop below serialized a transfer per live slot per tick)
        pos_np = np.asarray(self.pos)
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None or not self.live[slot]:
                continue
            tok = int(nxt_np[slot])
            req.out.append(tok)
            if len(req.out) == 1:
                req.t_first = time.time()
            finished = (len(req.out) >= req.max_new
                        or (self.eos_id is not None and tok == self.eos_id)
                        or int(pos_np[slot]) >= self.smax - 1)
            if finished:
                req.done = True
                req.t_done = time.time()
                self.live[slot] = False
                self.slot_req[slot] = None
            else:
                self.last_tok = self.last_tok.at[slot].set(tok)
        self.ticks += 1

    def run_until_done(self, max_ticks: int = 10_000,
                       rng: Optional[jax.Array] = None) -> None:
        """Drive ticks to completion. ``rng`` (non-greedy sampling): split a
        fresh subkey per tick — without it every run re-derives
        PRNGKey(tick) and two engines sampling the same tick draw identical
        tokens."""
        for _ in range(max_ticks):
            if not self._queue and not self.live.any():
                return
            sub = None
            if rng is not None:
                rng, sub = jax.random.split(rng)
            self.tick(sub)

    # ------------------------------------------- Engine protocol surface

    def drain(self, max_ticks: int = 10_000,
              rng: Optional[jax.Array] = None) -> None:
        """Engine protocol: run ticks until no request is queued or live."""
        self.run_until_done(max_ticks, rng)

    def stats(self) -> Dict[str, Any]:
        """Engine protocol: serving counters. The dense engine has no pool,
        so pool-specific keys are simply absent — shared keys match the
        paged engine's."""
        return {"engine": "dense", "ticks": self.ticks}
