"""Slot-based batched serving engine.

The paper (§6.4) finds >80% of HuggingFace decode time is KV-cache *append*
(concatenation re-allocates the cache every token). This engine removes the
append entirely: the cache is preallocated (B_slots, Smax, ...) ring storage
and decode writes in place — the design the paper defers to "a more advanced
inference system like vLLM".

Continuous batching (lite): requests join free slots; every engine tick runs
one batched decode step over all active slots; finished requests free their
slot. Per-slot positions make ragged batches exact.
"""
from __future__ import annotations

import dataclasses
import time
from typing import (Any, Callable, Dict, List, Optional, Protocol,
                    runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serving import cache_spec as CS
from repro.serving import lifecycle as LC
from repro.serving.lifecycle import Deadline, Status


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S_p,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False            # finished *normally* (== status DONE)
    # scheduling class: higher is more urgent. FIFO ignores it; the paged
    # engine's priority policy admits (and, for strictly higher classes,
    # preempts) by it. Ties fall back to arrival order.
    priority: int = 0
    t_submit: float = 0.0         # set by submit(); for latency reporting
    t_first: float = 0.0          # first generated token (TTFT reporting)
    t_done: float = 0.0           # set at any terminal status
    # encoder-decoder (whisper): precomputed frame embeddings (enc_seq,
    # d_model); the engine runs the encoder once at admission
    frames: Optional[np.ndarray] = None
    # lifecycle (serving/lifecycle.py): where the request is, why it
    # ended (terminal detail), and its wall budgets on the engine clock
    status: Status = Status.QUEUED
    detail: str = ""
    deadline: Optional[Deadline] = None
    # SHED only: the scheduler's estimate (in ticks) of when resubmitting
    # is worth trying — the backlog it shed this request to clear
    retry_after: float = 0.0
    # times this request lost its slot to preemption (scheduler-stamped;
    # feeds the shed policy's churn tie-break)
    n_preempts: int = 0


def context_cap(smax: int, gen_tokens: int) -> int:
    """Prompt rows a fresh admission may occupy: reserve headroom for the
    generation, capped at half the context so an outsized max_new degrades
    to a capacity-capped run instead of eating the whole prompt (full
    max_new is guaranteed for max_new <= smax//2). Shared by both engines
    so their admitted context — and therefore greedy outputs — agree."""
    return max(smax - min(gen_tokens, smax // 2), 1)


@runtime_checkable
class Engine(Protocol):
    """What a serving engine looks like to harnesses (benchmarks, serve
    CLI, tests): submit requests, advance ticks, cancel mid-flight, drain
    to completion, and report counters — one surface across the dense and
    paged engines, so callers never branch on the engine kind."""

    def submit(self, req: "Request") -> None: ...

    def tick(self, rng: Optional[jax.Array] = None) -> None: ...

    def cancel(self, rid: int, detail: str = "client cancel") -> bool: ...

    def drain(self, max_ticks: int = 10_000,
              rng: Optional[jax.Array] = None) -> None: ...

    def stats(self) -> Dict[str, Any]: ...


def oversized_reason(prompt_len: int, max_new: int,
                     smax: int) -> Optional[str]:
    """Why a request can never be held whole in an ``smax``-row context,
    or None if it fits. Shared by both engines' strict admission so a
    doomed request FAILs at ``submit()`` with a clear reason instead of
    being silently truncated (prompt) or capped (generation) deep inside
    admission (a request with ``prompt + max_new == smax`` exactly fills
    the context: its last token lands at row smax - 1)."""
    if prompt_len < 1:
        return "empty prompt"
    if max_new < 1:
        return f"max_new={max_new} < 1"
    if prompt_len + max_new > smax:
        return (f"prompt ({prompt_len}) + max_new ({max_new}) exceeds "
                f"context capacity {smax}; shorten one or raise smax")
    return None


def sample_next(logits, *, greedy: bool, rng, ticks: int):
    """Shared next-token rule for both engines: greedy argmax, or
    categorical with the caller's key (falling back to PRNGKey(tick) —
    thread a real rng via run_until_done for independent draws)."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    rng = rng if rng is not None else jax.random.PRNGKey(ticks)
    return jax.random.categorical(rng, logits).astype(jnp.int32)


class ServingEngine:
    """Dense slot engine.

    admission  'strict' (default) FAILs requests whose prompt + max_new
               can never fit the smax-row context at ``submit()``;
               'lenient' keeps the legacy degraded modes (prompt
               truncated to the most recent context, generation capped
               at capacity)
    clock      zero-arg wall clock (default time.time) stamping
               t_submit/t_first/t_done and driving Request.deadline
               expiry — inject lifecycle.ManualClock for determinism
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 smax: int = 512, eos_id: Optional[int] = None,
                 greedy: bool = True, backend: Optional[str] = None,
                 admission: str = "strict", clock=None,
                 trace_guard=None):
        if backend is not None:
            # route the decode hot path through the chosen kernel backend
            # (core/dispatch.py): "pallas" | "xla" | "auto"
            cfg = cfg.replace(
                loki=dataclasses.replace(cfg.loki, backend=backend))
        if admission not in ("strict", "lenient"):
            raise ValueError(f"admission={admission!r}; "
                             "use 'strict' or 'lenient'")
        self.params, self.cfg = params, cfg
        self.n_slots, self.smax = n_slots, smax
        self.eos_id, self.greedy = eos_id, greedy
        self.admission = admission
        self._clock = clock or time.time
        self.lifecycle_counts: Dict[str, int] = {}
        self.n_stalled = 0
        self.stalled_rids: List[int] = []
        self.cache = lm.init_cache(cfg, n_slots, smax, jnp.float32)
        # recurrent-state families only: batch-1 init values so an
        # admission that skips prefill (1-token prompt) can reset its
        # slot's state — a previous occupant's mamba/xlstm state must not
        # leak into the new request. Attention-only families need nothing:
        # stale K/V rows beyond the slot's position are unreachable.
        self._fresh_state = CS.fresh_state_tree(cfg, jnp.float32,
                                                include_cross=False)
        # positions / last tokens live on the HOST: per-slot bookkeeping
        # writes stay cheap in-place numpy ops and cross to the device
        # once per jitted call, never the other way around
        self.pos = np.zeros((n_slots,), np.int32)
        self.live = np.zeros((n_slots,), bool)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.last_tok = np.zeros((n_slots,), np.int32)
        wrap = trace_guard.wrap if trace_guard is not None \
            else (lambda _n, f: f)
        # the cache is donated: tick always replaces self.cache with the
        # result, so the old buffer is dead on return (no-op on CPU)
        self._decode = jax.jit(
            wrap("decode_step",
                 lambda p, c, t, pl: lm.decode_step(p, cfg, c, t, pl)),
            donate_argnums=(1,))
        # admission-path prefill, compiled; jit's cache retraces only per
        # distinct prompt length. It *creates* the returned cache, so
        # there is nothing to donate.
        self._prefill = jax.jit(
            wrap("prefill",
                 lambda p, t, fr: lm.prefill(p, cfg, t, smax, frames=fr,
                                             cache_dtype=jnp.float32)))
        self._queue: List[Request] = []
        self.ticks = 0

    # -------------------------------------------------------- lifecycle

    def _terminal(self, req: Request, status: Status,
                  detail: str = "") -> None:
        """Move a request to a terminal status with the shared stamps."""
        # lifecycle: live -> terminal
        LC.transition(req, status, detail)
        req.t_done = self._clock()
        self.lifecycle_counts[str(status)] = \
            self.lifecycle_counts.get(str(status), 0) + 1

    def _evict_slot(self, slot: int) -> None:
        """Drop a slot's occupant without a DONE transition (cancel /
        timeout): the stale cache rows beyond a future occupant's
        position are unreachable, so clearing the bookkeeping is enough."""
        self.live[slot] = False
        self.slot_req[slot] = None

    def cancel(self, rid: int, detail: str = "client cancel") -> bool:
        """Terminate a request by id, queued or mid-generation. Returns
        False when no live request has this rid (already terminal ids
        are not resurrected)."""
        for i, req in enumerate(self._queue):
            if req.rid == rid:
                del self._queue[i]
                self._terminal(req, Status.CANCELLED, detail)
                return True
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is not None and req.rid == rid:
                self._terminal(req, Status.CANCELLED, detail)
                self._evict_slot(slot)
                return True
        return False

    def _expire_deadlines(self) -> None:
        now = self._clock()
        for req in [r for r in self._queue
                    if LC.breach(r.deadline, now, r.t_submit, bool(r.out))]:
            why = LC.breach(req.deadline, now, req.t_submit, bool(req.out))
            self._queue.remove(req)
            self._terminal(req, Status.TIMED_OUT, why)
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None:
                continue
            why = LC.breach(req.deadline, now, req.t_submit, bool(req.out))
            if why:
                self._terminal(req, Status.TIMED_OUT, why)
                self._evict_slot(slot)

    # ------------------------------------------------------------ admin

    def submit(self, req: Request) -> None:
        req.t_submit = self._clock()
        if self.admission == "strict":
            why = oversized_reason(len(req.prompt), req.max_new, self.smax)
            if why:
                self._terminal(req, Status.FAILED, f"oversized: {why}")
                return
        self._queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.live[slot] or not self._queue:
                continue
            req = self._queue.pop(0)
            self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Single-request batched prefill into one slot.

        One causal-attention pass over the whole prompt, scattered into the
        slot's cache rows only — live slots are untouched. (The previous
        token-by-token fill ran a full batched decode step per prompt token,
        rewriting every live slot's cache at its current position.)"""
        # lifecycle: QUEUED -> PREFILL
        LC.transition(req, Status.PREFILL)
        toks = req.prompt.astype(np.int32)
        # cache can hold smax rows; keep the most recent context AND leave
        # generation headroom — truncating to smax itself left pos at
        # smax-1, so the finish guard ended the request after a single
        # generated token
        cap = context_cap(self.smax, req.max_new)
        if len(toks) > cap:
            toks = toks[-cap:]
        self.pos[slot] = 0
        fr = None
        if self.cfg.is_encoder_decoder:
            if req.frames is None:
                raise ValueError("encoder-decoder serving needs "
                                 "Request.frames (enc_seq, d_model)")
            fr = jnp.asarray(req.frames)[None]
        if len(toks) > 1:
            _, filled, _ = self._prefill(self.params,
                                         jnp.asarray(toks[None, :-1]), fr)
            self._write_slot(slot, filled)
            self.pos[slot] = len(toks) - 1
        elif self.cfg.is_encoder_decoder:
            # 1-token prompt: nothing to cache, but the slot still needs
            # its cross K/V — prefill the single token and keep pos=0 (the
            # decode step rewrites the same cache row with identical
            # values, so the continuation is unchanged)
            _, filled, _ = self._prefill(self.params, jnp.asarray(toks[None]),
                                         fr)
            self._write_slot(slot, filled)
        elif self._fresh_state is not None:
            self.cache = {"layers": CS.reset_slot_state(
                self.cache["layers"], self._fresh_state, slot,
                lm.uses_scan(self.cfg))}
        self.last_tok[slot] = int(toks[-1])
        self.slot_req[slot] = req
        self.live[slot] = True
        # lifecycle: PREFILL -> DECODE
        LC.transition(req, Status.DECODE)

    def _write_slot(self, slot: int, one) -> None:
        """Overwrite one slot's cache slice with a (batch-1) cache tree."""
        axis = 1 if lm.uses_scan(self.cfg) else 0      # skip the layer axis
        self.cache = jax.tree.map(
            lambda full, single: jax.lax.dynamic_update_slice_in_dim(
                full, single.astype(full.dtype), slot, axis=axis),
            self.cache, one)

    # ------------------------------------------------------------- tick

    def tick(self, rng: Optional[jax.Array] = None) -> None:
        self._expire_deadlines()
        self._admit()
        if not self.live.any():
            return
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_tok, self.pos)
        self.pos += self.live.astype(np.int32)
        nxt = sample_next(logits, greedy=self.greedy, rng=rng,
                          ticks=self.ticks)
        # host-sync: the one batched device->host sync of the tick — the
        # sampled tokens must reach Python to drive per-request lifecycle
        nxt_np = jax.device_get(nxt)
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None or not self.live[slot]:
                continue
            tok = int(nxt_np[slot])
            req.out.append(tok)
            if len(req.out) == 1:
                req.t_first = self._clock()
            finished = (len(req.out) >= req.max_new
                        or (self.eos_id is not None and tok == self.eos_id)
                        or int(self.pos[slot]) >= self.smax - 1)
            if finished:
                self._terminal(req, Status.DONE)
                self._evict_slot(slot)
            else:
                self.last_tok[slot] = tok
        self.ticks += 1

    def run_until_done(self, max_ticks: int = 10_000,
                       rng: Optional[jax.Array] = None) -> None:
        """Drive ticks to completion. ``rng`` (non-greedy sampling): split a
        fresh subkey per tick — without it every run re-derives
        PRNGKey(tick) and two engines sampling the same tick draw identical
        tokens.

        Hitting ``max_ticks`` with work still pending is a *stall*, and it
        is reported instead of silently returned from: every still-queued
        or still-running request is marked TIMED_OUT and counted in
        ``stats()['n_stalled']`` so hangs show up in tests and benches."""
        for _ in range(max_ticks):
            if not self._queue and not self.live.any():
                return
            sub = None
            if rng is not None:
                rng, sub = jax.random.split(rng)
            self.tick(sub)
        self._report_stall()

    def _report_stall(self) -> None:
        detail = "stalled: drain hit max_ticks"
        for req in list(self._queue):
            self._queue.remove(req)
            self._terminal(req, Status.TIMED_OUT, detail)
            self.n_stalled += 1
            self.stalled_rids.append(req.rid)
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None:
                continue
            self._terminal(req, Status.TIMED_OUT, detail)
            self._evict_slot(slot)
            self.n_stalled += 1
            self.stalled_rids.append(req.rid)

    # ------------------------------------------- Engine protocol surface

    def drain(self, max_ticks: int = 10_000,
              rng: Optional[jax.Array] = None) -> None:
        """Engine protocol: run ticks until no request is queued or live."""
        self.run_until_done(max_ticks, rng)

    def stats(self) -> Dict[str, Any]:
        """Engine protocol: serving counters. The dense engine has no pool,
        so pool-specific keys are simply absent — shared keys match the
        paged engine's."""
        return {"engine": "dense", "ticks": self.ticks,
                "lifecycle": dict(self.lifecycle_counts),
                "n_stalled": self.n_stalled,
                "stalled_rids": list(self.stalled_rids)}
