"""Slot-based batched serving engine.

The paper (§6.4) finds >80% of HuggingFace decode time is KV-cache *append*
(concatenation re-allocates the cache every token). This engine removes the
append entirely: the cache is preallocated (B_slots, Smax, ...) ring storage
and decode writes in place — the design the paper defers to "a more advanced
inference system like vLLM".

Continuous batching (lite): requests join free slots; every engine tick runs
one batched decode step over all active slots; finished requests free their
slot. Per-slot positions make ragged batches exact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S_p,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 smax: int = 512, eos_id: Optional[int] = None,
                 greedy: bool = True, backend: Optional[str] = None):
        if backend is not None:
            # route the decode hot path through the chosen kernel backend
            # (core/dispatch.py): "pallas" | "xla" | "auto"
            cfg = cfg.replace(
                loki=dataclasses.replace(cfg.loki, backend=backend))
        self.params, self.cfg = params, cfg
        self.n_slots, self.smax = n_slots, smax
        self.eos_id, self.greedy = eos_id, greedy
        self.cache = lm.init_cache(cfg, n_slots, smax, jnp.float32)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.live = np.zeros((n_slots,), bool)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.last_tok = jnp.zeros((n_slots,), jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t, pl: lm.decode_step(p, cfg, c, t, pl))
        # admission-path prefill, compiled; jit's cache retraces only per
        # distinct prompt length
        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, cfg, t, smax,
                                    cache_dtype=jnp.float32))
        self._queue: List[Request] = []
        self.ticks = 0

    # ------------------------------------------------------------ admin

    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.live[slot] or not self._queue:
                continue
            req = self._queue.pop(0)
            self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Single-request batched prefill into one slot.

        One causal-attention pass over the whole prompt, scattered into the
        slot's cache rows only — live slots are untouched. (The previous
        token-by-token fill ran a full batched decode step per prompt token,
        rewriting every live slot's cache at its current position.)"""
        toks = req.prompt.astype(np.int32)
        if len(toks) > self.smax:
            # cache can hold smax rows; keep the most recent context rather
            # than crashing the batched step mid-service
            toks = toks[-self.smax:]
        self.pos = self.pos.at[slot].set(0)
        if len(toks) > 1:
            _, filled, _ = self._prefill(self.params,
                                         jnp.asarray(toks[None, :-1]))
            axis = 1 if lm.uses_scan(self.cfg) else 0  # skip the layer axis
            self.cache = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=axis),
                self.cache, filled)
            self.pos = self.pos.at[slot].set(len(toks) - 1)
        self.last_tok = self.last_tok.at[slot].set(int(toks[-1]))
        self.slot_req[slot] = req
        self.live[slot] = True

    # ------------------------------------------------------------- tick

    def tick(self, rng: Optional[jax.Array] = None) -> None:
        self._admit()
        if not self.live.any():
            return
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_tok, self.pos)
        self.pos = self.pos + jnp.asarray(self.live, jnp.int32)
        if self.greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            rng = rng if rng is not None else jax.random.PRNGKey(self.ticks)
            nxt = jax.random.categorical(rng, logits).astype(jnp.int32)
        nxt_np = np.asarray(nxt)
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None or not self.live[slot]:
                continue
            tok = int(nxt_np[slot])
            req.out.append(tok)
            finished = (len(req.out) >= req.max_new
                        or (self.eos_id is not None and tok == self.eos_id)
                        or int(self.pos[slot]) >= self.smax - 1)
            if finished:
                req.done = True
                self.live[slot] = False
                self.slot_req[slot] = None
            else:
                self.last_tok = self.last_tok.at[slot].set(tok)
        self.ticks += 1

    def run_until_done(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self._queue and not self.live.any():
                return
            self.tick()
