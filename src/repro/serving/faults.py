"""Deterministic fault injection + the per-tick pool invariant auditor.

Robustness claims are worthless untested: this module gives the serving
stack a **seeded FaultPlan** that the page pool, the scheduler, and the
kernel dispatch layer consult at well-defined sites, so every failure mode
the engine claims to survive can be reproduced bit-exactly in CI.

Fault sites (``FaultPlan.SITES``):

  pool_exhaustion  PagePool.available_pages reads 0 this tick — admission
                   and growth see a full pool, driving the preemption /
                   shedding machinery exactly as sustained pressure would.
  alloc_fail       PagePool.alloc returns None (as if the free list ran
                   dry mid-operation) even when pages exist — exercises
                   every caller's contended-allocation path.
  nan_logits       the decode step's logits row for one slot is poisoned
                   to NaN, as a numerically-failing backend would — the
                   engine must quarantine that slot (FAIL it) without
                   poisoning the rest of the batch.
  slot_corrupt     one live slot's host page bookkeeping is silently
                   corrupted (its tail entry repointed at another held
                   page). Nothing crashes by itself — the point is that
                   the **auditor** turns this into a loud AuditError
                   instead of cross-request cache corruption.
  kernel_fail      the fused-Pallas decode raises this tick — the engine
                   must fall back to the XLA path (core/dispatch.py) and
                   keep serving.
  dma_timeout      an in-flight host->HBM page fetch never lands
                   (FetchQueue completion finds the DMA dead) — the queue
                   must repair with a synchronous copy, counted, and the
                   decode stream must not change.
  hbm_oom_on_promote
                   PagePool.promote_begin finds no stageable frame even
                   though accounting says one exists (as a fragmented /
                   transiently-overcommitted HBM allocator would) — the
                   engine walks its demote-retry-defer ladder instead of
                   crashing or corrupting the tier partition.

A site fires deterministically from ``blake2b(seed, site, tick, unit)``
compared against its configured rate — no RNG state, so two runs with the
same plan and schedule inject identical faults — plus an explicit
``at={site: {(tick, unit), ...}}`` schedule for point injections in tests.
``FaultPlan.parse`` reads the CLI spec, e.g.
``"seed=3,nan_logits=0.05,alloc_fail=0.1,slot_corrupt@17"``.

The **auditor** (:func:`audit_engine`) re-derives the pool's accounting
from scratch every tick and cross-checks it against the scheduler's
per-slot state and the device page table. Invariants (DESIGN.md §11):

  A. partition      every non-trash physical page is in exactly one of
                    {free list, cached LRU, held (refcount >= 1)}
  B. holder balance every held page's refcount equals the number of slot
                    page-table references to it (pages retained private
                    across preemption sit in the LRU at refcount 0)
  C. no wild refs   no slot references the trash page, an out-of-range
                    page, or a page the pool considers free
  D. share safety   a page referenced by two slots is registered in the
                    public prefix index — never private, never anonymous
  E. table mirror   the device page table rows equal the host
                    ``slot_pages`` lists (0 where recycled / unmapped)
  F. LRU sanity     every LRU page is registered and unreferenced

Tiered pools (DESIGN.md §13) add three more:

  G. tier partition every non-free page is in exactly one of
                    {RESIDENT, HOST, IN_FLIGHT}; device frames partition
                    into {free frames} ∪ {mapped frames} with no frame
                    mapped twice and every frame in [1, device_pages)
  H. tier safety    every pinned page is RESIDENT; the engine's pin
                    ledger is consistent (each recorded slot->page pin is
                    that slot's current tail and actually pinned in the
                    pool); the engine holds host bytes for every HOST and
                    IN_FLIGHT page (demotion without bytes = data loss)
  I. fetch budget   the in-flight set never exceeds the fetch queue's
                    configured budget

Any violation raises :class:`AuditError` naming the invariant — silent
corruption becomes a loud, attributable failure at the tick it happened.
"""
from __future__ import annotations

import hashlib
from collections import Counter
from typing import Dict, Iterable, Optional, Set, Tuple

import numpy as np


class FaultInjected(RuntimeError):
    """Raised by injection shims standing in for a real failure (e.g. a
    Pallas kernel abort) so recovery paths can be driven in tests."""


class AuditError(AssertionError):
    """A serving invariant does not hold. AssertionError subclass so test
    harnesses that expect assertion semantics treat it naturally, but it
    is raised unconditionally (``python -O`` keeps the guard)."""


class FaultPlan:
    """Seeded, deterministic fault schedule.

    rates  {site: probability in [0, 1]} — site fires at a tick/unit when
           the hash of (seed, site, tick, unit) falls below the rate.
    at     {site: {tick, ... | (tick, unit), ...}} — point schedule; a
           bare tick fires for every unit that consults the site then.

    ``advance(tick)`` is called by the engine at the top of each tick;
    ``hit(site, unit)`` is what the instrumented sites consult. Each
    distinct (site, tick, unit) is counted at most once in ``counts`` no
    matter how often it is consulted within the tick, so the counters
    read as "faults injected", not "times asked".
    """

    SITES = ("pool_exhaustion", "alloc_fail", "nan_logits",
             "slot_corrupt", "kernel_fail", "dma_timeout",
             "hbm_oom_on_promote")

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 at: Optional[Dict[str, Iterable]] = None):
        rates = dict(rates or {})
        at = {k: set(v) for k, v in (at or {}).items()}
        for site in list(rates) + list(at):
            if site not in self.SITES:
                raise ValueError(f"unknown fault site {site!r}; "
                                 f"have {self.SITES}")
        self.seed = int(seed)
        self.rates = rates
        self.at = at
        self.counts: Counter = Counter()
        self._tick = 0
        self._fired: Set[Tuple[str, int, int]] = set()

    def advance(self, tick: int) -> None:
        self._tick = int(tick)

    def _u(self, site: str, tick: int, unit: int) -> float:
        h = hashlib.blake2b(
            f"{self.seed}:{site}:{tick}:{unit}".encode(), digest_size=8)
        return int.from_bytes(h.digest(), "big") / 2.0 ** 64

    def hit(self, site: str, unit: int = 0) -> bool:
        """Does ``site`` fire for ``unit`` at the current tick?"""
        tick = self._tick
        fires = False
        sched = self.at.get(site)
        if sched and (tick in sched or (tick, unit) in sched):
            fires = True
        rate = self.rates.get(site, 0.0)
        if not fires and rate > 0.0:
            fires = self._u(site, tick, unit) < rate
        if fires:
            key = (site, tick, unit)
            if key not in self._fired:
                self._fired.add(key)
                self.counts[site] += 1
        return fires

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """CLI spec -> plan. Comma-separated terms: ``seed=N``,
        ``site=rate`` and/or ``site@tick`` (repeatable)."""
        seed, rates, at = 0, {}, {}
        for term in (t.strip() for t in spec.split(",") if t.strip()):
            if term.startswith("seed="):
                seed = int(term[5:])
            elif "@" in term:
                site, tick = term.split("@", 1)
                at.setdefault(site, set()).add(int(tick))
            elif "=" in term:
                site, rate = term.split("=", 1)
                rates[site] = float(rate)
            else:
                raise ValueError(f"bad fault term {term!r} in {spec!r}")
        return cls(seed, rates, at)

    def describe(self) -> str:
        parts = [f"seed={self.seed}"]
        parts += [f"{s}={r}" for s, r in sorted(self.rates.items())]
        parts += [f"{s}@{t}" for s, ts in sorted(self.at.items())
                  for t in sorted(ts, key=str)]
        return ",".join(parts)


# ----------------------------------------------------------------- audit

def _fail(invariant: str, msg: str):
    raise AuditError(f"[invariant {invariant}] {msg}")


def audit_pool(pool) -> None:
    """Invariants A + F on the pool alone (no scheduler context)."""
    n = pool.n_pages
    free = set(pool.free_page_ids())
    lru = set(pool.lru_page_ids())
    held = pool.holders()
    for name, s in (("free list", free), ("LRU", lru), ("held", held)):
        bad = [p for p in s if not 1 <= p < n]
        if bad:
            _fail("A", f"{name} contains out-of-range pages {bad}")
    if free & lru or free & set(held) or lru & set(held):
        _fail("A", "free/LRU/held page sets overlap: "
                   f"free&lru={free & lru} free&held={free & set(held)} "
                   f"lru&held={lru & set(held)}")
    every = free | lru | set(held)
    missing = set(range(1, n)) - every
    if missing:
        _fail("A", f"pages {sorted(missing)} leaked: neither free, "
                   "cached, nor held")
    zero = [p for p, r in held.items() if r <= 0]
    if zero:
        _fail("A", f"held pages with non-positive refcount {zero}")
    for p in lru:
        if not pool.is_registered(p):
            _fail("F", f"LRU page {p} is not registered")

    # G: tier partition (tiered pools only)
    if getattr(pool, "tiered", False):
        resident = set(pool.resident_page_ids())
        host = set(pool.host_page_ids())
        inflight = set(pool.inflight_page_ids())
        live = lru | set(held)
        if resident & host or resident & inflight or host & inflight:
            _fail("G", "tier sets overlap: "
                       f"r&h={resident & host} r&i={resident & inflight} "
                       f"h&i={host & inflight}")
        untiered = live - (resident | host | inflight)
        if untiered:
            _fail("G", f"pages {sorted(untiered)} are held or cached but "
                       "in no tier")
        ghosts = (resident | host | inflight) - live
        if ghosts:
            _fail("G", f"pages {sorted(ghosts)} carry tier state but are "
                       "neither held nor cached")
        fmap = pool.frame_map()
        frames = list(fmap.values())
        free_frames = set(pool.free_frame_ids())
        if len(frames) != len(set(frames)):
            dup = [f for f, c in Counter(frames).items() if c > 1]
            _fail("G", f"frames {dup} mapped by more than one page")
        for p, f in fmap.items():
            if not 1 <= f < pool.device_pages:
                _fail("G", f"page {p} mapped to out-of-range frame {f}")
            if f in free_frames:
                _fail("G", f"page {p} mapped to frame {f} which is on "
                           "the free-frame list")
        if set(fmap) != resident | inflight:
            _fail("G", "frame map keys != RESIDENT ∪ IN_FLIGHT: "
                       f"{sorted(set(fmap) ^ (resident | inflight))}")
        missing_f = set(range(1, pool.device_pages)) - free_frames \
            - set(frames)
        if missing_f:
            _fail("G", f"frames {sorted(missing_f)} leaked: neither free "
                       "nor mapped")


def audit_engine(engine) -> None:
    """Full per-tick audit of a PagedServingEngine: pool invariants plus
    the scheduler's slot bookkeeping and the device page table. O(pages +
    slots * max_pages) host work plus one device->host table transfer —
    cheap at serving scale, and priceless when something corrupts."""
    pool = engine.pool
    audit_pool(pool)
    n = pool.n_pages
    held = pool.holders()
    free = set(pool.free_page_ids())

    # B + C: slot references, counted against refcounts — over the
    # primary table's pages AND every aux page-table group's (per-layer
    # window groups hold pages the primary list never sees)
    aux_pages = getattr(engine, "aux_pages", [])
    holders: Counter = Counter()
    for gi, group in enumerate([engine.slot_pages] + list(aux_pages)):
        for slot, pages in enumerate(group):
            for p in pages:
                if p is None:
                    continue
                if not isinstance(p, (int, np.integer)) or not 1 <= p < n:
                    _fail("C", f"slot {slot} group {gi} references wild "
                               f"page {p!r}")
                if p in free:
                    _fail("C", f"slot {slot} group {gi} references page "
                               f"{p} which is on the free list")
                holders[int(p)] += 1
    for p, cnt in holders.items():
        if held.get(p, 0) != cnt:
            _fail("B", f"page {p}: refcount {held.get(p, 0)} != "
                       f"{cnt} slot reference(s)")
    unheld = [p for p in held if holders.get(p, 0) == 0]
    if unheld:
        _fail("B", f"pages {sorted(unheld)} hold references but no slot "
                   "lists them")

    # D: multi-slot pages must be publicly registered (prefix-shareable)
    for p, cnt in holders.items():
        if cnt > 1:
            if not pool.is_registered(p):
                _fail("D", f"page {p} shared by {cnt} slots but not "
                           "registered in the prefix index")
            if pool.is_private(p):
                _fail("D", f"page {p} shared by {cnt} slots is a "
                           "*private* retained entry")

    # E: every group's table mirrors its host bookkeeping
    aux_tables = getattr(engine, "aux_tables", [])
    groups = zip([engine.page_table] + list(aux_tables),
                 [engine.slot_pages] + list(aux_pages))
    for gi, (tbl, plists) in enumerate(groups):
        table = np.asarray(tbl)
        for slot, pages in enumerate(plists):
            want = np.zeros((engine.max_pages,), np.int32)
            for i, p in enumerate(pages):
                want[i] = 0 if p is None else p
            if not np.array_equal(table[slot], want):
                _fail("E", f"slot {slot} group {gi} table "
                           f"{table[slot].tolist()} != host pages "
                           f"{want.tolist()}")

    # H + I: tiered-engine safety (tail residency, host bytes, budget)
    if getattr(pool, "tiered", False):
        host_bytes = getattr(engine, "_host_kv", {})
        for p in set(pool.host_page_ids()) | set(pool.inflight_page_ids()):
            if p not in host_bytes:
                _fail("H", f"page {p} is off-device but the engine holds "
                           "no host bytes for it")
        for p in pool.pinned_page_ids():
            if pool.tier_of(p) != "resident":
                _fail("H", f"pinned page {p} is {pool.tier_of(p)}, "
                           "not RESIDENT")
        # pins are best-effort under frame starvation (the decode phase
        # re-ensures residency and defers frame-starved slots), so the
        # invariant is *ledger consistency*, not universal coverage: every
        # pin the engine records must name that slot's current tail and
        # be a real pin in the pool
        for slot, page in getattr(engine, "_pinned_tail", {}).items():
            live = [p for p in engine.slot_pages[slot] if p is not None]
            tail = int(live[-1]) if live else None
            if tail != page:
                _fail("H", f"slot {slot} pins page {page} but its tail "
                           f"is {tail} — a stale pin blocks demotion "
                           "forever")
            if not pool.is_pinned(page):
                _fail("H", f"slot {slot} records a pin on page {page} "
                           "the pool does not hold")
        inflight = pool.inflight_page_ids()
        if len(inflight) > pool.max_inflight:
            _fail("I", f"{len(inflight)} fetches in flight exceeds the "
                       f"budget of {pool.max_inflight}")
