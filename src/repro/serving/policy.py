"""Pluggable scheduling policies for the paged serving engine.

PR 3's ``PagedServingEngine.tick`` hard-coded one policy: FIFO admission,
one prefill chunk, one batched decode. This module factors the *decisions*
out of the tick so the engine runs three policy-driven phases —

  admission  which waiting request gets a slot next, and whether a waiting
             request may *preempt* a running one for its slot
  prefill    which mid-prefill slots advance, and by how many tokens
  decode     which live slots decode this tick when the decode budget is
             smaller than the live set

— while the mechanism (pages, refcounts, chunked prefill, preemption
bookkeeping) stays in serving/scheduler.py.

A policy is two total orders plus one capability flag:

  sort_key(req, arrival)      urgency: smaller = served first. Admission
                              pops the minimum; preemption victims are the
                              *maximum* among strictly-less-urgent
                              requests, so the most urgent request always
                              makes progress and preemption cannot
                              livelock (the running key multiset strictly
                              decreases at every swap).
  decode_key(req, arrival, last_tick)
                              decode-phase order under a token budget.
                              Includes the slot's last-decoded tick so a
                              budget smaller than the live set round-
                              robins instead of starving the largest key.
  preempt_for_admission       may a strictly-more-urgent *waiting* request
                              evict a running one just to get a slot?
                              False for FIFO (arrival order already means
                              no waiter is ever more urgent than a
                              runner); True for priority classes.

Budgets are vLLM-style per-tick token counts (``TickBudget``): prefill
spends ``prefill_tokens`` prompt tokens per tick across any number of
chunks and slots; decode spends ``decode_tokens`` (one token per live
slot per tick). Both default to the legacy behavior — one chunk, every
live slot.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TickBudget:
    """Per-tick work caps, in tokens.

    prefill_tokens  prompt tokens computed per tick (>= chunk size lets
                    several small chunks / several waiting prompts share
                    one tick; the engine never splits a chunk)
    decode_tokens   live slots decoded per tick (each costs one token);
                    slots left out are simply masked from the batched
                    step and resume on a later tick — per-slot positions
                    keep their streams exact regardless of schedule
    """
    prefill_tokens: int
    decode_tokens: int


class SchedulerPolicy:
    """FIFO: serve in arrival order, never preempt for admission."""

    name = "fifo"
    preempt_for_admission = False

    def sort_key(self, req, arrival: int):
        return (0, arrival)

    def decode_key(self, req, arrival: int, last_tick: int):
        return (0, last_tick, arrival)

    def shed_key(self, req, arrival: int, n_preempts: int):
        """Load-shedding order under sustained pool pressure: the engine
        sheds the *maximum* of this key — the least-urgent request, ties
        broken toward the one that has already churned through the most
        preemptions (its progress is the cheapest to abandon, and it is
        the one feeding the preemption livelock being broken)."""
        return (self.sort_key(req, arrival), n_preempts)

    def demote_key(self, page: int, cached_unreferenced: bool,
                   lru_order: int, last_use_tick: int):
        """Demotion order for a tiered pool (DESIGN.md §13): the engine
        demotes the *minimum* of this key when it needs device frames.
        Cached-but-unreferenced pages (prefix-cache residue no live slot
        holds) go first in pool-LRU order — their bytes keep prefix value
        on the host but their frames serve nobody; then cold resident
        pages by last-use tick (a page no recent Loki selection touched
        is the cheapest to push off-device). Demotion always precedes
        preemption or shedding: losing a frame costs one prefetch,
        losing a slot costs a re-prefill."""
        return ((0, lru_order) if cached_unreferenced
                else (1, last_use_tick))


class FifoPolicy(SchedulerPolicy):
    pass


class PriorityPolicy(SchedulerPolicy):
    """Priority classes: higher ``Request.priority`` is served first;
    arrival order breaks ties inside a class (so equal-priority traffic
    degrades to FIFO). A waiting request of a strictly higher class may
    preempt the least-urgent running request to take its slot — the
    preempted request is requeued and (for StateSlot families) restored
    from its host snapshot at re-admission."""

    name = "priority"
    preempt_for_admission = True

    def sort_key(self, req, arrival: int):
        return (-req.priority, arrival)

    def decode_key(self, req, arrival: int, last_tick: int):
        return (-req.priority, last_tick, arrival)


POLICIES = {"fifo": FifoPolicy, "priority": PriorityPolicy}


def make_policy(policy) -> SchedulerPolicy:
    """'fifo' | 'priority' | a SchedulerPolicy instance."""
    if isinstance(policy, SchedulerPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown scheduler policy {policy!r}; have {list(POLICIES)}")
