"""Per-layer CacheSpec registry: the declarative table that drives paged
serving for **every** model family.

PR 3's paged engine hard-coded the dense/moe scan families: one pooled K/V
array per layer, one shared page table per slot, and ``if kind in (...)``
chains in ``lm.init_paged_cache`` / ``lm.prefill_chunk`` that raised for
anything with recurrent or encoder state. This module replaces those chains
with a spec table: each layer *declares* its decode-state components and
their lifecycle, and the cache plumbing (models/lm.py) plus the scheduler
(serving/scheduler.py) are driven by the table instead of by family names.

Component kinds:

  PagedAttn        growable page-table K/V. Rows live in the shared page
                   pool ((n_pages * page_size, Hkv, D) per layer, no batch
                   dim); a request holds ceil(len/page_size) pages.
  WindowPagedAttn  PagedAttn with a sliding-window attention mask: only the
                   last ``window`` positions are ever attendable, so pages
                   that slide fully out of the window are *recycled* —
                   freed back to the pool and their table entries pointed
                   at the trash page (reads of recycled rows are garbage
                   but masked, exactly like the dense cache's dead rows).
                   A request holds at most ceil(window/page_size)+1 pages.
  StateSlot        fixed-size recurrent state (mamba conv/ssm, mLSTM C/n/m,
                   sLSTM c/n/h/m) carried per *slot* across prefill chunks
                   and decode steps. Not pooled — the state of a request is
                   O(1) in its length. Preemption is recompute: the state
                   is reset at (re-)admission and rebuilt exactly by the
                   masked chunked prefill (blocks.mamba_prefill_chunk etc.),
                   so the greedy continuation is preserved.
  CrossAttnStatic  whisper-style encoder K/V, written once at admission
                   (lm.encode_cross_kv) and read-only afterwards.

The registry is pure config -> spec: jax arrays are only built by the
explicit ``state_slot_init``/``fresh_state_tree``/``reset_slot_state``
helpers both engines share. ``layer_kind``/``uses_scan`` are
canonical here (models/lm.py re-exports them) so the spec table and the
model assembly can never disagree about what a layer is.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PageLayout

# policies whose caches cannot rebuild exact prefix attention (h2o keeps its
# own budgeted structure; pcaattn stores lossy d-dim keys) — they serve
# through the dense engine only
UNPAGEABLE_POLICIES = ("h2o", "pcaattn")


# ------------------------------------------------------------ layer kinds

def is_slstm(cfg: ModelConfig, i: int) -> bool:
    return bool(cfg.slstm_every) and (i % cfg.slstm_every
                                      == cfg.slstm_every - 1)


def layer_kind(cfg: ModelConfig, i: int) -> str:
    """dense|moe|hybrid|mlstm|slstm|dec — what block layer ``i`` is."""
    if cfg.family == "ssm":
        return "slstm" if is_slstm(cfg, i) else "mlstm"
    if cfg.family == "moe":
        return "moe"
    if cfg.family == "hybrid":
        return "hybrid"
    if cfg.is_encoder_decoder:
        return "dec"
    return "dense"


def uses_scan(cfg: ModelConfig) -> bool:
    return cfg.family != "ssm"          # xlstm layers are heterogeneous


# ------------------------------------------------------------- components

@dataclasses.dataclass(frozen=True)
class PagedAttn:
    """Growable page-table K/V in the shared pool.

    ``shareable``: a full page's K/V depends only on the token prefix (and
    the fixed params/policy), so identical prompt prefixes may alias the
    same physical pages — this is the component prefix caching rides on.

    ``layout`` is the single source of truth for the component's physical
    pages: storage dtype, key basis (native vs PCA-latent) and latent rank
    (see configs.base.PageLayout). Page allocation (lm.init_paged_cache),
    the store path (blocks.attn_prefill_chunk / attn_decode) and every
    read path (XLA views + Pallas kernels) all derive from it."""
    n_kv_heads: int
    head_dim: int
    layout: PageLayout = dataclasses.field(default_factory=PageLayout)
    shareable = True

    @property
    def k_width(self) -> int:
        return self.layout.k_width(self.head_dim)


@dataclasses.dataclass(frozen=True)
class WindowPagedAttn:
    """Paged K/V whose attendable suffix is bounded: pages that slide out
    of the window are recycled (bounded page budget per request).

    Not shareable: recycling frees a slot's pages mid-stream and points
    table entries at the trash page, so a physical page's lifetime is tied
    to one request's window position — aliasing it from another request
    would read recycled/garbage rows as live context."""
    n_kv_heads: int
    head_dim: int
    window: int
    layout: PageLayout = dataclasses.field(default_factory=PageLayout)
    shareable = False

    @property
    def k_width(self) -> int:
        return self.layout.k_width(self.head_dim)


@dataclasses.dataclass(frozen=True)
class StateSlot:
    """Fixed-size per-slot recurrent state; ``state`` names the blocks
    cache builder (mamba|mlstm|slstm) that defines its pytree.

    Not shareable: the recurrent state summarizes the *entire* prefix in
    O(1) space, so a request cannot skip prefill over cached pages — the
    skipped tokens would be missing from its state. Families with any
    StateSlot bypass prefix caching entirely."""
    state: str
    shareable = False


@dataclasses.dataclass(frozen=True)
class CrossAttnStatic:
    """Encoder K/V written once at admission, read-only afterwards.

    Not shareable: the decoder's self-attention K/V depends on the
    request's encoder output (frames) through cross-attention, so equal
    token prefixes do *not* imply equal cached K/V across requests.

    ``layout``: storage dtype is honored (quantized cross K/V carry one
    scale per slot — written once at admission, so no RMW is needed), but
    the basis is forced native: PCA calibration covers self-attention
    keys only, and cross K/V are not paged."""
    enc_seq: int
    n_kv_heads: int
    head_dim: int
    layout: PageLayout = dataclasses.field(default_factory=PageLayout)
    shareable = False


Component = Union[PagedAttn, WindowPagedAttn, StateSlot, CrossAttnStatic]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer's decode-state declaration: named components, in the cache
    dict's key order ('attn' -> pooled K/V, 'ssm' -> StateSlot pytree,
    'cross' -> cross_k/cross_v arrays)."""
    kind: str
    components: Tuple[Tuple[str, Component], ...]

    def component(self, name: str):
        return dict(self.components).get(name)

    @property
    def attn(self):
        c = self.component("attn")
        return c if isinstance(c, (PagedAttn, WindowPagedAttn)) else None

    @property
    def state(self):
        c = self.component("ssm")
        return c if isinstance(c, StateSlot) else None

    @property
    def cross(self):
        c = self.component("cross")
        return c if isinstance(c, CrossAttnStatic) else None


# --------------------------------------------------------------- registry

def layer_specs(cfg: ModelConfig) -> Tuple[LayerSpec, ...]:
    """The spec table: one LayerSpec per decoder layer. Every paged
    component carries ``cfg.page_layout`` (cross-attention with the basis
    forced native); StateSlot stays full-precision native.

    ``cfg.page_ranks`` (Loki §4.2) overrides the latent-K rank layer by
    layer: each attn component carries its own layout with that layer's
    rank, so the table — and everything derived from it — is the single
    source of per-layer widths."""
    hd = cfg.resolved_head_dim
    lay = cfg.page_layout
    if lay.rank > hd:
        raise ValueError(f"page_layout rank {lay.rank} > head_dim {hd}")
    ranks = cfg.page_ranks
    if ranks is not None:
        if len(ranks) != cfg.n_layers:
            raise ValueError(f"page_ranks needs {cfg.n_layers} entries, "
                             f"got {len(ranks)}")
        if any(r > hd for r in ranks):
            raise ValueError(f"page_ranks {ranks} exceed head_dim {hd}")
    cross_lay = dataclasses.replace(lay, basis="native", rank=0)

    def attn_for(i: int) -> Component:
        li = lay if ranks is None else dataclasses.replace(
            lay, basis="pca", rank=ranks[i])
        w = cfg.layer_window(i)
        if w:
            return WindowPagedAttn(cfg.n_kv_heads, hd, w, li)
        return PagedAttn(cfg.n_kv_heads, hd, li)

    def one(i: int) -> LayerSpec:
        kind = layer_kind(cfg, i)
        comps = []
        if kind in ("dense", "moe", "hybrid", "dec"):
            comps.append(("attn", attn_for(i)))
        if kind == "hybrid":
            comps.append(("ssm", StateSlot("mamba")))
        if kind == "mlstm":
            comps.append(("ssm", StateSlot("mlstm")))
        if kind == "slstm":
            comps.append(("ssm", StateSlot("slstm")))
        if kind == "dec" and cfg.is_encoder_decoder:
            comps.append(("cross", CrossAttnStatic(cfg.enc_seq,
                                                   cfg.n_kv_heads, hd,
                                                   cross_lay)))
        return LayerSpec(kind, tuple(comps))

    return tuple(one(i) for i in range(cfg.n_layers))


def has_paged_attn(cfg: ModelConfig) -> bool:
    return any(s.attn is not None for s in layer_specs(cfg))


def max_k_width(cfg: ModelConfig) -> int:
    """Stored K width of the (stacked) pools: scan families stack every
    layer's pool in one array, so the allocation width is the max per-layer
    ``k_width``; narrower layers zero-mask their tail dims at write time."""
    widths = [s.attn.k_width for s in layer_specs(cfg) if s.attn is not None]
    return max(widths) if widths else cfg.resolved_head_dim


def layer_k_widths(cfg: ModelConfig) -> Tuple[int, ...]:
    """Per-layer stored K widths (a layer with no attn reports 0)."""
    return tuple(s.attn.k_width if s.attn is not None else 0
                 for s in layer_specs(cfg))


def latent_score_width(cfg: ModelConfig) -> int:
    """Width of the always-resident latent-K sidecar in a tiered pool
    (DESIGN.md §13): the leading-d slice Loki's approximate score pass
    reads, mirroring ``loki.loki_decode``'s d = min(max(d_f·D, 8), kd)
    clamped to the stored K width. The sidecar rows are bitwise copies of
    the leading columns of the stored (PCA-rotated) keys, so scoring from
    the sidecar is exactly the single-tier score computation."""
    d = max(int(cfg.loki.d_f * cfg.resolved_head_dim), 8)
    return min(d, max_k_width(cfg))


def has_state_slots(cfg: ModelConfig) -> bool:
    return any(s.state is not None for s in layer_specs(cfg))


def pageable(cfg: ModelConfig) -> Tuple[bool, str]:
    """Can this config serve from the paged engine? (ok, reason)."""
    if has_paged_attn(cfg) and cfg.attn_policy() in UNPAGEABLE_POLICIES:
        return False, (f"policy {cfg.attn_policy()!r} cannot rebuild exact "
                       "prefix attention from its cache; use the dense "
                       "engine")
    return True, ""


def prefix_shareable(cfg: ModelConfig) -> Tuple[bool, str]:
    """Can prompt-prefix pages be shared across this config's requests?
    (ok, reason). The engine consults this, so hybrid/SSM/encdec/SWA
    families transparently bypass sharing instead of erroring."""
    if not has_paged_attn(cfg):
        return False, "no paged-attention layers to share"
    for s in layer_specs(cfg):
        for name, comp in s.components:
            if not comp.shareable:
                return False, (f"{type(comp).__name__} ({name}) pins pages "
                               "to one request")
    return True, ""


def assert_pageable(cfg: ModelConfig) -> None:
    ok, reason = pageable(cfg)
    if not ok:
        raise ValueError(f"{cfg.arch}: {reason} (paged serving)")


def servable_archs() -> Tuple[str, ...]:
    """Archs whose (default-policy) config the paged engine serves — the
    allowed set launch/serve.py derives instead of hard-coding families."""
    from repro.configs import ARCHS, get_smoke_config
    return tuple(a for a in ARCHS if pageable(get_smoke_config(a))[0])


# ---------------------------------------------------------------- budgets

def window_page_budget(window: int, page_size: int) -> int:
    """Max live pages a window layer needs: the window spans at most
    ceil(window/page_size) pages plus the page being written."""
    return -(-window // page_size) + 1


def recycle_window(cfg: ModelConfig) -> int:
    """The window the engine may recycle pages against, or 0.

    One page table is shared by every layer of a slot, so recycling a page
    is only sound if *every* attention layer's mask has moved past it —
    i.e. all attn layers are windowed; the effective recycle window is the
    widest per-layer window."""
    windows = []
    for s in layer_specs(cfg):
        if isinstance(s.attn, WindowPagedAttn):
            windows.append(s.attn.window)
        elif s.attn is not None:
            return 0                      # a full-attention layer pins pages
    return max(windows) if windows else 0


def group_windows(cfg: ModelConfig) -> Tuple[int, ...]:
    """Window of each page-table group, one entry per group.

    Layers with *equal* attention windows share one page table: their
    masks move past a page at the same position, so recycling the page is
    sound for every layer reading that table. Distinct windows therefore
    get distinct tables (per-layer page-table groups) — a full-attention
    layer never recycles, while a window layer's group recycles at its own
    window instead of pinning pages forever.

    Group 0 is the full-attention group when one exists, else the widest
    window group (so the primary table's recycle semantics match the
    single-table engine: ``recycle_window(cfg) == group_windows(cfg)[0]``
    ... with 0 meaning "never recycle"). Remaining groups are ordered by
    descending window."""
    windows = {s.attn.window if isinstance(s.attn, WindowPagedAttn) else 0
               for s in layer_specs(cfg) if s.attn is not None}
    if not windows:
        return ()
    return tuple(sorted(windows, key=lambda w: (w != 0, -w)))


def layer_group_ids(cfg: ModelConfig) -> Tuple[int, ...]:
    """Page-table group id of each layer (-1 = layer has no paged attn)."""
    gid = {w: i for i, w in enumerate(group_windows(cfg))}
    out = []
    for s in layer_specs(cfg):
        if s.attn is None:
            out.append(-1)
        elif isinstance(s.attn, WindowPagedAttn):
            out.append(gid[s.attn.window])
        else:
            out.append(gid[0])
    return tuple(out)


def n_table_groups(cfg: ModelConfig) -> int:
    return max(len(group_windows(cfg)), 1)


def group_page_budget(cfg: ModelConfig, gid: int, smax: int,
                      page_size: int) -> int:
    """Max pages one request can hold in group ``gid``'s table."""
    max_pages = -(-smax // page_size)
    w = group_windows(cfg)[gid]
    if w:
        return min(max_pages, window_page_budget(w, page_size))
    return max_pages


def request_page_budget(cfg: ModelConfig, smax: int, page_size: int) -> int:
    """Max pages one request can hold at once under the spec table —
    summed over its page-table groups (a mixed SWA/full model holds
    group 0's full-prefix pages plus each window group's bounded set)."""
    if not has_paged_attn(cfg):
        return 0
    return sum(group_page_budget(cfg, g, smax, page_size)
               for g in range(len(group_windows(cfg))))


# ------------------------------------------------------------- state init

def state_slot_init(cfg: ModelConfig, comp: StateSlot, batch: int,
                    dtype) -> Dict[str, Any]:
    """Fresh state pytree for ``batch`` slots of a StateSlot component."""
    from repro.models import blocks as B
    if comp.state == "mamba":
        return B.init_mamba_cache(cfg, batch, dtype)
    if comp.state == "mlstm":
        return B.init_mlstm_cache(cfg, batch)
    if comp.state == "slstm":
        return B.init_slstm_cache(cfg, batch)
    raise ValueError(f"unknown StateSlot kind {comp.state!r}")


def fresh_state_tree(cfg: ModelConfig, dtype, *, include_cross: bool = True):
    """Batch-1 init values for every StateSlot (and optionally
    CrossAttnStatic) leaf, shaped to DUS straight into one slot of a decode
    cache — shared by both engines' slot-reset paths. None if the model has
    no such components (attention-only families need no reset: rows past a
    slot's position are unreachable)."""
    specs = layer_specs(cfg)

    def one(spec: LayerSpec) -> Dict[str, Any]:
        c: Dict[str, Any] = {}
        if spec.state is not None:
            c["ssm"] = state_slot_init(cfg, spec.state, 1, dtype)
        if include_cross and spec.cross is not None:
            x = spec.cross
            c["cross_k"] = jnp.zeros(
                (1, x.enc_seq, x.n_kv_heads, x.head_dim), dtype)
            c["cross_v"] = jnp.zeros_like(c["cross_k"])
        return c

    if uses_scan(cfg):
        layer = one(specs[0])
        if not layer:
            return None
        return jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (cfg.n_layers,) + a.shape).copy(), layer)
    layers = [one(s) for s in specs]
    return layers if any(layers) else None


def snapshot_slot_state(layers, fresh, slot: int, scan: bool):
    """Extract one slot's state leaves from a cache's ``layers`` tree,
    shaped like ``fresh_state_tree`` output (batch-1 leaves) so a later
    ``reset_slot_state(layers, snapshot, slot, scan)`` restores it
    verbatim. Used by snapshot-on-preemption: the (tiny) recurrent state
    goes to host instead of being recomputed from the folded prompt."""
    def take(full, axis):
        return jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=axis)

    if scan:
        sub = {k: layers[k] for k in fresh}
        return jax.tree.map(lambda full, _: take(full, 1), sub, fresh)
    out = []
    for lc, fr in zip(layers, fresh):
        out.append(jax.tree.map(lambda full, _: take(full, 0),
                                {k: lc[k] for k in fr}, fr))
    return out


def reset_slot_state(layers, fresh, slot, scan: bool):
    """Overwrite one slot's state leaves in a cache's ``layers`` tree with
    ``fresh`` init values (from ``fresh_state_tree``); other leaves are
    shared by reference. ``slot`` may be a traced scalar."""
    def dus(full, one, axis):
        return jax.lax.dynamic_update_slice_in_dim(
            full, one.astype(full.dtype), slot, axis=axis)

    if scan:
        sub = {k: layers[k] for k in fresh}
        sub = jax.tree.map(lambda f, o: dus(f, o, 1), sub, fresh)
        return {**layers, **sub}
    out = []
    for lc, fr in zip(layers, fresh):
        sub = {k: lc[k] for k in fr}
        sub = jax.tree.map(lambda f, o: dus(f, o, 0), sub, fr)
        out.append({**lc, **sub})
    return out


# ------------------------------------------------------------ spec table

def _fmt_layout(comp: Component) -> str:
    lay = getattr(comp, "layout", None)
    if lay is None or lay == PageLayout():
        return ""
    return f", layout={lay.describe()}"


def _fmt_component(name: str, comp: Component, smax: int,
                   page_size: int) -> str:
    if isinstance(comp, WindowPagedAttn):
        return (f"{name}=WindowPagedAttn(window={comp.window}, "
                f"<= {window_page_budget(comp.window, page_size)} pages"
                f"{_fmt_layout(comp)})")
    if isinstance(comp, PagedAttn):
        return (f"{name}=PagedAttn(<= {-(-smax // page_size)} pages"
                f"{_fmt_layout(comp)})")
    if isinstance(comp, StateSlot):
        return f"{name}=StateSlot({comp.state})"
    if isinstance(comp, CrossAttnStatic):
        return (f"{name}=CrossAttnStatic(enc_seq={comp.enc_seq}, "
                f"written at admission{_fmt_layout(comp)})")
    return f"{name}={comp!r}"


def format_spec_table(cfg: ModelConfig, smax: int, page_size: int) -> str:
    """Human-readable per-layer spec table (printed by serve.py --dryrun).
    Consecutive identical layers are folded into one row."""
    specs = layer_specs(cfg)
    rows = []
    start = 0
    for i in range(1, len(specs) + 1):
        if i == len(specs) or specs[i] != specs[start]:
            s = specs[start]
            comps = " ".join(_fmt_component(n, c, smax, page_size)
                             for n, c in s.components) or "(stateless)"
            span = (f"{start}" if i - 1 == start else f"{start}-{i - 1}")
            rows.append(f"  layer {span:>7}  {s.kind:<7} {comps}")
            start = i
    budget = request_page_budget(cfg, smax, page_size)
    ok, why = prefix_shareable(cfg)
    share = "prefix_shareable" if ok else f"prefix_unshareable ({why})"
    lay = cfg.page_layout
    bpr = lay.bytes_per_page_row(cfg.resolved_head_dim, cfg.n_kv_heads)
    head = (f"CacheSpec[{cfg.arch}] smax={smax} page_size={page_size} "
            f"budget={budget} pages/request"
            + (f" recycle_window={recycle_window(cfg)}"
               if recycle_window(cfg) else "")
            + f" layout={lay.describe()}"
            + (f" ranks=per-layer(max r={max_k_width(cfg)})"
               if cfg.page_ranks is not None else "")
            + f" ({bpr * page_size} B/page/layer) {share}")
    return "\n".join([head] + rows)
