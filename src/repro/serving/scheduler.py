"""Paged serving engine: admission + continuous batching over a page pool.

Replaces the dense engine's ``(n_slots, Smax, ...)`` preallocation with the
shared page pool of serving/paged_cache.py and a scheduler that interleaves

  * **chunked prefill** — each tick advances at most one waiting prompt by
    ``prefill_chunk`` tokens, so a long prompt neither monopolizes a tick
    nor gets truncated to the cache length, and
  * **batched decode** — one ``lm.decode_step`` over every live slot, with
    per-slot positions and page tables keeping ragged batches exact.

What a slot *holds* is declared by the per-layer CacheSpec table
(serving/cache_spec.py), so every family in configs/ serves here:

  PagedAttn        pages allocated on demand (ceil(len/page_size) held),
                   freed the moment the request finishes.
  WindowPagedAttn  (mixtral SWA) pages that slide fully out of the
                   attention window are *recycled*: freed back to the pool
                   and their table entries pointed at the trash page, so a
                   window layer holds at most ceil(window/page_size)+1
                   pages instead of ceil(smax/page_size). Recycling runs
                   before growth each tick, so the bound holds at every
                   instant of the decode phase.
  StateSlot        (hymba mamba, xlstm m/s-LSTM) per-slot recurrent state,
                   reset at admission and carried across prefill chunks;
                   the batched decode masks state updates of non-live
                   slots (mid-prefill or idle) via ``live``.
  CrossAttnStatic  (whisper) encoder K/V computed once at admission from
                   ``Request.frames`` and written into the slot.

Under memory pressure the scheduler *preempts* the latest-arriving request
(vLLM's recompute policy — an older request is never evicted for a younger
one): its pages are freed and it is requeued at the front with its
generated tokens folded into the prompt. StateSlot layers are handled by
recompute — state is reset at re-admission and rebuilt exactly by the
masked chunked prefill — so greedy decoding reproduces the identical
continuation. ``n_pages - 1 >= `` the per-request page bound is enforced
at construction, so a lone request can always run to its length cap and
preemption cannot livelock.

Decode numerics are the dense engine's: the jnp policies read the gathered
logical view (bit-compatible with a dense cache of the same logical
length), the ``loki_block`` Pallas path indexes the pool directly through
the page table (DESIGN.md §7, §8).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serving import cache_spec as CS
from repro.serving.engine import Request, context_cap, sample_next
from repro.serving.paged_cache import PagePool

PAGED_POLICIES = ("full", "exact_topk", "loki", "loki_block")


def _dus(full, one, slot, axis):
    return jax.lax.dynamic_update_slice_in_dim(
        full, one.astype(full.dtype), slot, axis=axis)


class PagedServingEngine:
    """Continuous-batching engine over a paged KV-cache (all families).

    n_slots        decode batch width (concurrent *running* requests)
    smax           logical context cap per request (rounded up to pages)
    page_size      tokens per page; defaults to ``cfg.loki.block_size`` so
                   pages coincide with the fused kernel's DMA blocks
    n_pages        physical pool size incl. the reserved trash page;
                   defaults to fitting every slot at its spec-table page
                   bound (pass less to exercise pressure / preemption)
    prefill_chunk  prompt tokens processed per tick (fixed-size, padded)
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 smax: int = 512, page_size: Optional[int] = None,
                 n_pages: Optional[int] = None, prefill_chunk: int = 32,
                 eos_id: Optional[int] = None, greedy: bool = True,
                 backend: Optional[str] = None):
        if backend is not None:
            cfg = cfg.replace(
                loki=dataclasses.replace(cfg.loki, backend=backend))
        CS.assert_pageable(cfg)
        self.specs = CS.layer_specs(cfg)
        self.has_pages = CS.has_paged_attn(cfg)
        self.has_state = CS.has_state_slots(cfg)
        self.is_encdec = cfg.is_encoder_decoder
        if self.has_pages and cfg.attn_policy() not in PAGED_POLICIES:
            raise ValueError(
                f"policy {cfg.attn_policy()!r} cannot serve from a paged "
                f"cache (supported: {PAGED_POLICIES}); use ServingEngine")
        self.params, self.cfg = params, cfg
        self.page_size = page_size or cfg.loki.block_size
        self.max_pages = -(-smax // self.page_size)
        self.smax = self.max_pages * self.page_size      # logical cap
        self.n_slots = n_slots
        self.prefill_chunk = prefill_chunk
        self.eos_id, self.greedy = eos_id, greedy

        # page accounting from the spec table: ``req_budget`` is the
        # decode-phase bound per request (= ceil(window/ps)+1 for SWA
        # models, else max_pages); ``_req_pages_hard`` additionally covers
        # a mid-prefill chunk, whose pages can't be recycled until the
        # chunk's earliest query has moved past them
        self.window = CS.recycle_window(cfg)
        self.req_budget = CS.request_page_budget(cfg, self.smax,
                                                 self.page_size)
        if self.window:
            self._req_pages_hard = min(
                self.max_pages,
                CS.window_page_budget(self.window + self.prefill_chunk - 1,
                                      self.page_size))
        else:
            self._req_pages_hard = self.req_budget
        if n_pages is None:
            n_pages = 1 + max(n_slots * self._req_pages_hard, 1)
        if self.has_pages and n_pages - 1 < self._req_pages_hard:
            raise ValueError(
                f"pool of {n_pages} pages cannot hold one full request "
                f"({self._req_pages_hard} pages); raise n_pages or lower "
                "smax")

        self.pool = PagePool(n_pages, self.page_size)
        self.cache = lm.init_paged_cache(cfg, n_pages, self.page_size,
                                         jnp.float32, n_slots=n_slots)
        self._fresh_state = CS.fresh_state_tree(cfg, jnp.float32)
        self.page_table = jnp.zeros((n_slots, self.max_pages), jnp.int32)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.last_tok = jnp.zeros((n_slots,), jnp.int32)
        self.live = np.zeros((n_slots,), bool)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        # logical page index -> physical page id, or None once recycled
        # (window slide); ``len`` is the logical coverage, the number of
        # non-None entries is what the slot actually holds
        self.slot_pages: List[List[Optional[int]]] = [
            [] for _ in range(n_slots)]
        # slots mid-prefill: slot -> index of the next prompt token to feed
        self._prefill_at: Dict[int, int] = {}
        # admission order, oldest first — preemption victims come from the
        # tail so head-of-line requests always finish
        self._admit_order: List[int] = []
        self._queue: Deque[Request] = collections.deque()
        # generated tokens already folded back into req.prompt by earlier
        # preemptions (keyed by object id; a second preemption must only
        # fold the tokens generated since the last one)
        self._folded: Dict[int, int] = {}
        # original submission order (survives preemption/re-admission):
        # preemption only ever evicts later arrivals, so head-of-line
        # requests always finish
        self._arrival: Dict[int, int] = {}
        self._arrival_seq = 0
        self.ticks = 0
        self.n_preempted = 0
        self.n_recycled_pages = 0
        self.peak_slot_pages = 0       # max pages any slot held at once

        ps = self.page_size
        self._decode = jax.jit(
            lambda p, c, t, pl, pt, lv: lm.decode_step(
                p, cfg, c, t, pl, page_table=pt, page_size=ps, live=lv))
        self._chunk = jax.jit(
            lambda p, c, toks, start, nv, row, sl: lm.prefill_chunk(
                p, cfg, c, toks, start, nv, row, ps, slot=sl))
        if self.is_encdec:
            self._encode_cross = jax.jit(
                lambda p, fr: lm.encode_cross_kv(p, cfg, fr))

    # --------------------------------------------------- per-slot state

    def _reset_slot_state(self, slot: int) -> None:
        """(Re-)admission: zero the slot's recurrent state so a previous
        occupant cannot leak into this request — preemption recovery is
        recompute, and recompute must start from the fresh state."""
        if self._fresh_state is None:
            return
        self.cache = {"layers": CS.reset_slot_state(
            self.cache["layers"], self._fresh_state, slot,
            lm.uses_scan(self.cfg))}

    def _install_cross(self, slot: int, frames: np.ndarray) -> None:
        """CrossAttnStatic lifecycle: run the encoder once at admission and
        write this request's cross K/V into its slot."""
        ck, cv = self._encode_cross(self.params,
                                    jnp.asarray(frames)[None])
        layers = self.cache["layers"]
        self.cache = {"layers": {
            **layers,
            "cross_k": _dus(layers["cross_k"], ck, slot, 1),
            "cross_v": _dus(layers["cross_v"], cv, slot, 1)}}

    # ------------------------------------------------------------ admin

    def submit(self, req: Request) -> None:
        if self.is_encdec and req.frames is None:
            raise ValueError("encoder-decoder serving needs Request.frames "
                             "(enc_seq, d_model)")
        req.t_submit = time.time()
        self._arrival[id(req)] = self._arrival_seq
        self._arrival_seq += 1
        self._queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if not self._queue:
                return
            if self.slot_req[slot] is not None:
                continue
            req = self._queue.popleft()
            toks = req.prompt.astype(np.int32)
            if not req.out:
                cap = context_cap(self.smax, req.max_new)
                if len(toks) > cap:
                    toks = toks[-cap:]
            # else: re-admission after a mid-decode preemption. Everything
            # in the folded prompt was legitimately cached at preemption
            # (pos_after < smax-1, so len <= smax-1): re-truncating here
            # would drop context the unpreempted run kept and make greedy
            # output depend on preemption timing.
            req.prompt = toks
            self.slot_req[slot] = req
            self.slot_pages[slot] = []
            self._admit_order.append(slot)
            self.pos = self.pos.at[slot].set(0)
            self._reset_slot_state(slot)
            if self.is_encdec:
                self._install_cross(slot, req.frames)
            if len(toks) > 1:
                self._prefill_at[slot] = 0
            else:
                self._ready(slot)

    def _ready(self, slot: int) -> None:
        """Prefill finished: the slot joins the decode batch."""
        toks = self.slot_req[slot].prompt
        self._prefill_at.pop(slot, None)
        self.pos = self.pos.at[slot].set(len(toks) - 1)
        self.last_tok = self.last_tok.at[slot].set(int(toks[-1]))
        self.live[slot] = True

    def _release(self, slot: int, *, done: bool) -> None:
        req = self.slot_req[slot]
        if done:
            req.done = True
            req.t_done = time.time()
            self._folded.pop(id(req), None)
            self._arrival.pop(id(req), None)
        # recycled (None) entries were freed the moment they slid out of
        # the window — freeing them again here would double-free (PagePool
        # raises); only the pages the slot still holds go back
        self.pool.free([p for p in self.slot_pages[slot] if p is not None])
        self.slot_pages[slot] = []
        # retarget the freed slot at the trash page so the batched decode
        # step's unconditional write cannot touch reallocated pages
        self.page_table = self.page_table.at[slot].set(0)
        self.pos = self.pos.at[slot].set(0)
        self.live[slot] = False
        self.slot_req[slot] = None
        self._prefill_at.pop(slot, None)
        self._admit_order.remove(slot)

    def _preempt(self, slot: int) -> None:
        """Recompute-preemption: fold generated tokens into the prompt and
        requeue at the front; greedy decoding reproduces the rest (the
        slot's StateSlot components are reset at re-admission and rebuilt
        by the masked chunked prefill)."""
        req = self.slot_req[slot]
        folded = self._folded.get(id(req), 0)
        fresh = req.out[folded:]
        if fresh:
            req.prompt = np.concatenate(
                [req.prompt, np.asarray(fresh, np.int32)])
            self._folded[id(req)] = len(req.out)
        self._release(slot, done=False)
        self._queue.appendleft(req)
        self.n_preempted += 1

    def _make_room(self, need: int, protect: int) -> bool:
        """Free pages by preempting requests that *arrived after* the
        protected slot's request, newest arrival first — an older request
        is never evicted for a younger one, so head-of-line requests
        always finish even though re-admission rejoins the slot list.
        Only slots actually holding pages are victims (a just-admitted
        slot with none would be churned for nothing). True iff ``need``
        pages are now available."""
        while self.pool.free_pages < need:
            mine = self._arrival[id(self.slot_req[protect])]
            victims = [s for s in self._admit_order
                       if s != protect
                       and any(p is not None for p in self.slot_pages[s])
                       and self._arrival[id(self.slot_req[s])] > mine]
            if not victims:
                return False
            self._preempt(max(
                victims, key=lambda s: self._arrival[id(self.slot_req[s])]))
        return True

    def _grow_to(self, slot: int, n_tokens: int) -> bool:
        """Ensure the slot's table covers logical positions [0, n_tokens)."""
        if not self.has_pages:
            return True                    # StateSlot-only model (xlstm)
        need = PagePool.pages_for(n_tokens, self.page_size) \
            - len(self.slot_pages[slot])
        if need <= 0:
            return True
        if not self._make_room(need, protect=slot):
            return False
        pages = self.pool.alloc(need)
        base = len(self.slot_pages[slot])
        self.page_table = self.page_table.at[
            slot, base:base + need].set(jnp.asarray(pages, jnp.int32))
        self.slot_pages[slot].extend(pages)
        self.peak_slot_pages = max(
            self.peak_slot_pages,
            sum(p is not None for p in self.slot_pages[slot]))
        return True

    def _recycle_window(self, slot: int, next_q: int) -> None:
        """WindowPagedAttn lifecycle: pages every future query's window has
        slid past are dead — free them and point their table entries at the
        trash page (reads of recycled rows are masked by the sliding-window
        mask exactly like the dense cache's dead rows). ``next_q`` is the
        earliest position any future query of this slot can have; it
        attends kv >= next_q - window + 1."""
        if not self.window:
            return
        first_live = max(0, next_q - self.window + 1) // self.page_size
        pages = self.slot_pages[slot]
        freed = [p for p in pages[:first_live] if p is not None]
        if not freed:
            return
        pages[:first_live] = [None] * min(first_live, len(pages))
        self.pool.free(freed)
        self.n_recycled_pages += len(freed)
        self.page_table = self.page_table.at[slot, :first_live].set(0)
        live = sum(p is not None for p in pages)
        if live > self._req_pages_hard:
            raise RuntimeError(
                f"slot {slot} holds {live} pages after recycling, above "
                f"the spec-table bound {self._req_pages_hard}")

    # ------------------------------------------------------------- tick

    def _prefill_step(self) -> bool:
        """Advance the oldest mid-prefill request by one fixed-size chunk."""
        slot = next((s for s in self._admit_order
                     if s in self._prefill_at), None)
        if slot is None:
            return False
        req = self.slot_req[slot]
        toks = req.prompt
        n_pre = len(toks) - 1              # last token goes through decode
        start = self._prefill_at[slot]
        c = self.prefill_chunk
        n_valid = min(c, n_pre - start)
        # recycle before growing: the chunk's earliest query is at
        # ``start``, so pages its window has passed free up first and the
        # per-request bound holds at every instant
        self._recycle_window(slot, start)
        if not self._grow_to(slot, start + n_valid):
            return False                   # pool contended; retry next tick
        chunk = np.zeros((1, c), np.int32)
        chunk[0, :n_valid] = toks[start:start + n_valid]
        _, self.cache = self._chunk(
            self.params, self.cache, jnp.asarray(chunk),
            jnp.int32(start), jnp.int32(n_valid), self.page_table[slot],
            jnp.int32(slot))
        self._prefill_at[slot] = start + n_valid
        if start + n_valid >= n_pre:
            self._ready(slot)
        return True

    def _decode_tick(self, rng: Optional[jax.Array]) -> bool:
        if not self.live.any():
            return False
        pos_np = np.asarray(self.pos)
        # every live slot writes its new token this step: make sure the
        # target page exists (preempting youngest-first under pressure),
        # recycling window-dead pages first so SWA slots stay within their
        # spec-table page bound
        for slot in np.flatnonzero(self.live):
            slot = int(slot)
            if not self.live[slot]:
                continue                   # preempted by an earlier grow
            self._recycle_window(slot, int(pos_np[slot]))
            if not self._grow_to(slot, int(pos_np[slot]) + 1):
                # this slot's request is the newest arrival under memory
                # pressure: vLLM's recompute policy preempts the requester
                # itself rather than evicting an older request
                self._preempt(slot)
        if not self.live.any():
            return False
        # the batched step writes a token for *every* slot; non-live slots
        # (idle, or mid-prefill with pages already mapped) must land in the
        # trash page, not at position 0 of their freshly prefilled pages —
        # and their StateSlot components must not advance (``live`` mask)
        live_dev = jnp.asarray(self.live)
        pt = self.page_table * live_dev.astype(jnp.int32)[:, None]
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_tok, self.pos, pt,
            live_dev if self.has_state else None)
        self.pos = self.pos + live_dev.astype(jnp.int32)
        nxt_np = np.asarray(sample_next(logits, greedy=self.greedy,
                                        rng=rng, ticks=self.ticks))
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None or not self.live[slot]:
                continue
            tok = int(nxt_np[slot])
            req.out.append(tok)
            finished = (len(req.out) >= req.max_new
                        or (self.eos_id is not None and tok == self.eos_id)
                        or int(pos_np[slot]) + 1 >= self.smax - 1)
            if finished:
                self._release(slot, done=True)
            else:
                self.last_tok = self.last_tok.at[slot].set(tok)
        return True

    def tick(self, rng: Optional[jax.Array] = None) -> None:
        self._admit()
        self._prefill_step()
        self._decode_tick(rng)
        self.ticks += 1

    def run_until_done(self, max_ticks: int = 10_000,
                       rng: Optional[jax.Array] = None) -> None:
        for _ in range(max_ticks):
            if not self._queue and not self._admit_order:
                return
            sub = None
            if rng is not None:
                rng, sub = jax.random.split(rng)
            self.tick(sub)
