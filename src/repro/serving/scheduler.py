"""Paged serving engine: policy-driven scheduling over a refcounted pool.

Replaces the dense engine's ``(n_slots, Smax, ...)`` preallocation with the
shared page pool of serving/paged_cache.py and a tick split into three
**policy-driven phases** (serving/policy.py):

  admission  waiting requests take free slots in ``SchedulerPolicy`` order
             (FIFO or priority classes); under the priority policy a
             strictly-more-urgent waiter may preempt the least-urgent
             running request for its slot
  prefill    mid-prefill slots advance by fixed-size chunks until the
             per-tick **prefill token budget** is spent — several small
             chunks, or several waiting prompts, share one tick
  decode     one batched ``lm.decode_step`` over the selected live slots
             (at most the **decode token budget**; selection round-robins
             within a policy class so a tight budget never starves a
             stream), with per-slot positions and page tables keeping
             ragged batches exact

What a slot *holds* is declared by the per-layer CacheSpec table
(serving/cache_spec.py) — PagedAttn / WindowPagedAttn (recycled) /
StateSlot / CrossAttnStatic — so every family in configs/ serves here
(DESIGN.md §8).

**Prefix caching** (DESIGN.md §9): for configs whose components are all
``shareable`` (state-free, full-attention families), full prompt pages are
registered in the pool's content-hash index as prefill writes them. A
later request whose prompt starts with the same tokens *acquires* those
pages (refcount++) and starts its query stream at the first uncached
token — chunks fully covered by cached pages are never computed. Cached
pages hold storage-basis keys, so Loki scoring over them is exact (Lemma
4.1). When the match ends mid-page the tail page is shared read-only and
**copy-on-write** duplicates it the moment this request must write its
own rows. Unreferenced cached pages form an LRU that ``alloc`` reclaims
*before* the scheduler ever preempts a live request.

Under memory pressure the scheduler *preempts* the least-urgent request
by the policy's order (vLLM's recompute policy — under FIFO an older
request is never evicted for a younger one): its references are released
— never force-freed, shared pages survive for their other readers — and
it is requeued with its generated tokens folded into the prompt.
StateSlot layers are handled by recompute, except pure-state families
(no pages to rebuild), whose tiny recurrent state is **snapshotted to
host** at preemption and restored at re-admission so the folded prompt is
not re-run. ``n_pages - 1 >=`` the per-request page bound is enforced at
construction, so a lone request can always run to its length cap and
preemption cannot livelock.

Decode numerics are the dense engine's: the jnp policies read the gathered
logical view (bit-compatible with a dense cache of the same logical
length), the ``loki_block`` Pallas path indexes the pool directly through
the page table (DESIGN.md §7, §8).

**Request lifecycle + fault tolerance** (DESIGN.md §11): every request
walks the serving/lifecycle.py status machine (QUEUED -> PREFILL ->
DECODE -> DONE | CANCELLED | TIMED_OUT | FAILED | SHED), with per-request
deadlines on the engine's injected clock, a ``cancel(rid)`` that frees
refcounted pages / COW tails / state snapshots mid-generation without
disturbing shared-prefix readers, and a degradation ladder under faults
(serving/faults.py): NaN-poisoned slots are quarantined and FAILed
individually instead of poisoning the batch; a fused-Pallas decode
failure disables the backend (core/dispatch.py) and re-runs the tick on
the XLA path; sustained pool pressure sheds the least-urgent request
(terminal SHED + retry-after hint) once it has churned through
``shed_after`` preemptions, instead of livelocking on recompute churn.
An optional per-tick invariant auditor (``audit=True``) cross-checks the
pool's refcounts, the slots' page lists and the device page table after
every tick, turning silent corruption into a loud ``AuditError``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import dispatch
from repro.models import lm
from repro.serving import cache_spec as CS
from repro.serving import faults as FI
from repro.serving import lifecycle as LC
from repro.serving import paged_cache as PC
from repro.serving.engine import (Request, context_cap, oversized_reason,
                                  sample_next)
from repro.serving.lifecycle import Status
from repro.serving.paged_cache import PagePool
from repro.serving.policy import SchedulerPolicy, TickBudget, make_policy

PAGED_POLICIES = ("full", "exact_topk", "loki", "loki_block")

# miss-repair bound for the tiered decode: run 1 discovers the first
# off-device winners, run 2 can still shift deeper layers' selections
# (their run-1 scores attended trash rows), run 3 is fully resident in
# every observed trace — 4 leaves one run of slack before declaring
# promotion/selection ping-pong
_TIERED_MAX_RUNS = 4


def _dus(full, one, slot, axis):
    return jax.lax.dynamic_update_slice_in_dim(
        full, one.astype(full.dtype), slot, axis=axis)


class PagedServingEngine:
    """Continuous-batching engine over a paged KV-cache (all families).

    n_slots        decode batch width (concurrent *running* requests)
    smax           logical context cap per request (rounded up to pages)
    page_size      tokens per page; defaults to ``cfg.loki.block_size`` so
                   pages coincide with the fused kernel's DMA blocks
    n_pages        physical pool size incl. the reserved trash page;
                   defaults to fitting every slot at its spec-table page
                   bound (pass less to exercise pressure / preemption)
    prefill_chunk  prompt tokens processed per chunk (fixed-size, padded)
    policy         'fifo' | 'priority' | a SchedulerPolicy instance
    prefill_budget prompt tokens computed per tick (default: one chunk)
    decode_budget  live slots decoded per tick (default: all of them)
    prefix_cache   share identical prompt-prefix pages across requests
                   (auto-bypassed for configs with unshareable components)
    admission      'strict' (default) FAILs requests whose prompt +
                   max_new can never fit smax at submit(); 'lenient'
                   keeps the legacy truncate/cap degraded modes
    clock          zero-arg wall clock (default time.time) stamping
                   request times and driving deadline expiry — inject
                   lifecycle.ManualClock for deterministic tests
    shed_after     preemptions a request survives before the scheduler
                   sheds it (terminal SHED + retry-after hint) instead of
                   requeueing — anti-churn under sustained pool pressure;
                   None (default) never sheds
    faults         serving/faults.py FaultPlan consulted by the pool,
                   this scheduler and the decode dispatch; None = off
    audit          run the serving/faults.py invariant auditor after
                   every tick (raises AuditError on violation)
    nan_guard      quarantine slots whose decode logits go non-finite
                   (FAIL that request alone, keep the batch serving)
    device_pages   tiered KV pool (DESIGN.md §13): only this many pages
                   (incl. the trash frame) keep full-D K/V rows in HBM;
                   the rest live in host buffers, always scoreable
                   through the resident latent-K sidecar, and are
                   promoted back on demand when Loki's selection attends
                   them. Requires a Loki policy over a non-quantized
                   layout. None (default) = single-tier, all-resident.
    max_inflight   outstanding async host->HBM fetches the tiered pool's
                   fetch queue may hold (default 2: double-buffered)
    packed         gather-packed decode (DESIGN.md §14): compact the
                   tick's live slots into a dense batch padded to a
                   power-of-two bucket, so decode FLOPs scale with
                   occupancy instead of ``n_slots``. Bucket programs jit
                   lazily; under a sealed TraceGuard an unwarmed bucket
                   falls back to the full-width masked program instead of
                   recompiling in the hot path. False = always masked
                   full-width (the A/B benchmarking baseline).
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 smax: int = 512, page_size: Optional[int] = None,
                 n_pages: Optional[int] = None, prefill_chunk: int = 32,
                 eos_id: Optional[int] = None, greedy: bool = True,
                 backend: Optional[str] = None,
                 policy="fifo", prefill_budget: Optional[int] = None,
                 decode_budget: Optional[int] = None,
                 prefix_cache: bool = True, admission: str = "strict",
                 clock=None, shed_after: Optional[int] = None,
                 faults: Optional[FI.FaultPlan] = None,
                 audit: bool = False, nan_guard: bool = True,
                 trace_guard=None, donate: bool = True,
                 device_pages: Optional[int] = None,
                 max_inflight: int = 2, packed: bool = True):
        if backend is not None:
            cfg = cfg.replace(
                loki=dataclasses.replace(cfg.loki, backend=backend))
        CS.assert_pageable(cfg)
        self.specs = CS.layer_specs(cfg)
        self.has_pages = CS.has_paged_attn(cfg)
        self.has_state = CS.has_state_slots(cfg)
        self.is_encdec = cfg.is_encoder_decoder
        if self.has_pages and cfg.attn_policy() not in PAGED_POLICIES:
            raise ValueError(
                f"policy {cfg.attn_policy()!r} cannot serve from a paged "
                f"cache (supported: {PAGED_POLICIES}); use ServingEngine")
        self.params, self.cfg = params, cfg
        self.page_size = page_size or cfg.loki.block_size
        self.max_pages = -(-smax // self.page_size)
        self.smax = self.max_pages * self.page_size      # logical cap
        self.n_slots = n_slots
        self.prefill_chunk = prefill_chunk
        self.eos_id, self.greedy = eos_id, greedy
        self.policy: SchedulerPolicy = make_policy(policy)
        self.budget = TickBudget(
            prefill_tokens=prefill_budget or prefill_chunk,
            decode_tokens=decode_budget or n_slots)
        shareable, why = CS.prefix_shareable(cfg)
        self.prefix_caching = bool(prefix_cache and shareable)
        self.prefix_cache_reason = (
            "" if not prefix_cache else why)     # bypass reason, if any

        # page accounting from the spec table: ``req_budget`` is the
        # decode-phase bound per request (summed over its page-table
        # groups); ``_group_pages_hard`` additionally covers a mid-prefill
        # chunk, whose pages can't be recycled until the chunk's earliest
        # query has moved past them. Layers whose windows differ keep
        # separate page tables (DESIGN.md §14): group 0 owns the primary
        # table and every existing mechanism (prefix cache, COW,
        # snapshots); groups 1.. are aux window groups that grow and
        # recycle in lockstep with it but at their own window
        self.window = CS.recycle_window(cfg)
        self.group_windows = CS.group_windows(cfg)
        self.n_groups = max(len(self.group_windows), 1)
        self.req_budget = CS.request_page_budget(cfg, self.smax,
                                                 self.page_size)

        def hard(w: int) -> int:
            if w:
                return min(self.max_pages, CS.window_page_budget(
                    w + self.prefill_chunk - 1, self.page_size))
            return self.max_pages
        if self.group_windows:
            self._group_pages_hard = [hard(w) for w in self.group_windows]
        else:
            self._group_pages_hard = [hard(self.window) if self.window
                                      else self.req_budget]
        self._req_pages_hard = sum(self._group_pages_hard)
        if n_pages is None:
            n_pages = 1 + max(n_slots * self._req_pages_hard, 1)
        if self.has_pages and n_pages - 1 < self._req_pages_hard:
            raise ValueError(
                f"pool of {n_pages} pages cannot hold one full request "
                f"({self._req_pages_hard} pages); raise n_pages or lower "
                "smax")

        self.tiered = device_pages is not None
        if self.tiered:
            pol = cfg.attn_policy()
            if pol not in ("loki", "loki_block"):
                raise ValueError(
                    "tiered KV pool needs a Loki policy (its latent "
                    f"sidecar drives the score pass), not {pol!r}")
            if cfg.page_layout.quantized:
                raise ValueError(
                    "tiered KV pool requires a non-quantized page layout: "
                    "quantized row writes re-derive per-page scales, so "
                    "the miss-repair replay would not be bit-idempotent")
            if not (self.has_pages and lm.uses_scan(cfg)):
                raise ValueError("tiered KV pool needs paged attention "
                                 "layers in a scan family")
            if self.n_groups > 1:
                raise ValueError(
                    "tiered KV pool does not compose with per-layer "
                    "page-table groups (cfg.window_layers): the frame "
                    "table and pin ledger are single-table")
            if device_pages - 1 < self._req_pages_hard:
                raise ValueError(
                    f"device pool of {device_pages} frames cannot hold "
                    f"one full request ({self._req_pages_hard} pages); "
                    "raise device_pages or lower smax")

        if admission not in ("strict", "lenient"):
            raise ValueError(f"admission={admission!r}; "
                             "use 'strict' or 'lenient'")
        self.admission = admission
        self._clock = clock or time.time
        self.shed_after = shed_after
        self._faults = faults
        self.audit = audit
        self.nan_guard = nan_guard
        self.lifecycle_counts: Dict[str, int] = {}
        self.n_stalled = 0
        self.stalled_rids: List[int] = []
        self.n_quarantined = 0
        self.n_shed = 0
        self.n_backend_fallbacks = 0

        self.pool = PagePool(n_pages, self.page_size,
                             device_pages=device_pages,
                             max_inflight=max_inflight)
        if faults is not None:
            self.pool.set_faults(faults)
        self.cache = lm.init_paged_cache(cfg, n_pages, self.page_size,
                                         jnp.float32, n_slots=n_slots,
                                         device_pages=device_pages)
        self._fresh_state = CS.fresh_state_tree(cfg, jnp.float32)
        # page table / positions / last tokens live on the HOST: every
        # per-slot update between ticks is a cheap in-place numpy write,
        # and the arrays cross to the device once per jitted call instead
        # of forcing a device round-trip per bookkeeping touch
        self.page_table = np.zeros((n_slots, self.max_pages), np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self.last_tok = np.zeros((n_slots,), np.int32)
        self.live = np.zeros((n_slots,), bool)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        # logical page index -> physical page id, or None once recycled
        # (window slide); ``len`` is the logical coverage, the number of
        # non-None entries is what the slot actually holds
        self.slot_pages: List[List[Optional[int]]] = [
            [] for _ in range(n_slots)]
        # aux page-table groups 1..n-1 mirror the primary table's shape;
        # their pages are never prefix-shared (any multi-group config has
        # a WindowPagedAttn component, which bypasses prefix caching), so
        # every aux page is sole-owned and COW/registration never apply
        self.aux_tables: List[np.ndarray] = [
            np.zeros((n_slots, self.max_pages), np.int32)
            for _ in range(self.n_groups - 1)]
        self.aux_pages: List[List[List[Optional[int]]]] = [
            [[] for _ in range(n_slots)] for _ in range(self.n_groups - 1)]
        # slot -> logical index of a shared tail page this request must
        # copy-on-write before its first write lands in it (full-page
        # prefix hits need no COW: the slot never writes below its first
        # uncached token, so only the partial tail can collide)
        self._cow_pending: Dict[int, int] = {}
        # prefix-cache registration cursor per slot: next full prompt page
        # to publish, and the chain hash of everything before it
        self._reg_next: Dict[int, int] = {}
        self._reg_parent: Dict[int, bytes] = {}
        # slots mid-prefill: slot -> index of the next prompt token to feed
        self._prefill_at: Dict[int, int] = {}
        # admission order, oldest first — used for phase iteration; the
        # *policy* key decides urgency and preemption victims
        self._admit_order: List[int] = []
        self._queue: Deque[Request] = collections.deque()
        # generated tokens already folded back into req.prompt by earlier
        # preemptions (keyed by object id; a second preemption must only
        # fold the tokens generated since the last one)
        self._folded: Dict[int, int] = {}
        # original submission order (survives preemption/re-admission) —
        # the tie-break inside a policy class, so FIFO's "an older request
        # is never evicted for a younger one" guarantee holds per class
        self._arrival: Dict[int, int] = {}
        self._arrival_seq = 0
        # host snapshots of preempted StateSlot state: id(req) ->
        # (tokens consumed, batch-1 state tree). Pure-state families
        # restore unconditionally; hybrids (state + paged K/V, e.g. hymba)
        # additionally park their own K/V pages as private pool entries
        # (``_page_snap``) and restore only when the *whole* retained set
        # survived the interim — recompute stays the fallback
        self._state_snap: Dict[int, Tuple[int, Any]] = {}
        self._page_snap: Dict[
            int, Tuple[List[Optional[int]], List[bytes]]] = {}
        self._snap_eligible = self.has_state
        self._last_decoded = np.zeros((n_slots,), np.int64)
        self.ticks = 0
        self.n_preempted = 0
        self.n_recycled_pages = 0
        self.peak_slot_pages = 0       # max pages any slot held at once
        self.n_prefill_computed_tokens = 0
        self.n_cow_copies = 0
        self.n_state_restores = 0
        # tiered-pool engine state (DESIGN.md §13): host byte buffers for
        # demoted pages, the per-slot pinned write-target, a last-use tick
        # per page driving the cold-resident demotion order, and the
        # bounded async fetch queue
        self._host_kv: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._pinned_tail: Dict[int, int] = {}
        self._page_last_use: Dict[int, int] = {}
        self.n_prefetch_hits = 0
        self.n_prefetch_misses = 0
        self.n_sync_fetches = 0
        self.n_decode_reruns = 0
        self._fetch = (PC.FetchQueue(self.pool, self._promote_copy,
                                     faults=faults)
                       if self.tiered else None)
        self._trace_guard = trace_guard
        self._donate = donate       # False only for A/B benchmarking
        # gather-packed decode: tiered decode already packs its work by
        # re-running only missing slots, and its winner-mask bookkeeping
        # is slot-indexed — keep it on the full-width masked program
        self.packed = bool(packed) and not self.tiered
        self.n_packed_ticks = 0
        self.n_masked_ticks = 0
        self.n_packed_rows_saved = 0   # (n_slots - bucket) summed
        self.n_packed_fallbacks = 0    # sealed-guard unwarmed buckets

        self._build_programs()

    def _build_programs(self) -> None:
        """(Re-)jit the engine's compiled closures. Called once at
        construction and again by the backend-fallback path: after
        ``dispatch.disable_backend('pallas')`` a fresh jit retraces, and
        the retrace resolves to the XLA path."""
        cfg, ps = self.cfg, self.page_size
        guard = self._trace_guard
        if guard is not None:
            guard.rebuild()     # legitimate retrace window re-opens
        wrap = guard.wrap if guard is not None else (lambda _n, f: f)
        # the cache argument is donated on every cache-updating program:
        # the caller always replaces ``self.cache`` with the result, so
        # the old buffer is dead on return and XLA may update in place
        # (CPU silently ignores donation; the kernel-fallback re-run in
        # ``_run_decode`` is safe because the injected failure raises
        # before dispatch ever consumes the buffer)
        self._decode = jax.jit(
            wrap("decode_step",
                 lambda p, c, t, pl, pt, lv: lm.decode_step(
                     p, cfg, c, t, pl, page_table=pt, page_size=ps,
                     live=lv)),
            donate_argnums=(1,) if self._donate else ())
        # per-bucket packed decode programs jit lazily (_packed_program);
        # a rebuild invalidates them all so the retrace resolves to the
        # surviving backend exactly like the programs above
        self._decode_packed: Dict[int, Any] = {}
        self._chunk = jax.jit(
            wrap("prefill_chunk",
                 lambda p, c, toks, start, nv, row, sl: lm.prefill_chunk(
                     p, cfg, c, toks, start, nv, row, ps, slot=sl)),
            donate_argnums=(1,) if self._donate else ())
        self._copy_page = jax.jit(
            wrap("copy_cache_page",
                 lambda c, s, d: lm.copy_cache_page(cfg, c, s, d, ps)),
            donate_argnums=(0,) if self._donate else ())
        if self.tiered:
            self._decode_t = jax.jit(
                wrap("decode_step_tiered",
                     lambda p, c, t, pl, pt, ft, lv: lm.decode_step(
                         p, cfg, c, t, pl, page_table=pt, page_size=ps,
                         live=lv, frame_table=ft)),
                donate_argnums=(1,) if self._donate else ())
            self._chunk_t = jax.jit(
                wrap("prefill_chunk_tiered",
                     lambda p, c, toks, start, nv, row, fr, sl:
                     lm.prefill_chunk(p, cfg, c, toks, start, nv, row,
                                      ps, slot=sl, frame_row=fr)),
                donate_argnums=(1,) if self._donate else ())
            self._copy_page_t = jax.jit(
                wrap("copy_cache_page_tiered",
                     lambda c, s, d, sf, df: lm.copy_cache_page(
                         cfg, c, s, d, ps, src_frame=sf, dst_frame=df)),
                donate_argnums=(0,) if self._donate else ())
            self._promote_write = jax.jit(
                wrap("promote_page_rows",
                     lambda c, k, v, f: lm.promote_page_rows(
                         cfg, c, k, v, f, ps)),
                donate_argnums=(0,) if self._donate else ())
            if self._fresh_state is not None:
                # batched rewind for the miss-repair re-run: one masked
                # restore over every stale slot at once (tiered requires a
                # scan family, so the slot axis of every state leaf is 1)
                def rewind(sub, snap, stale):
                    def mask_one(cur, sv):
                        m = stale.reshape((1, -1) + (1,) * (cur.ndim - 2))
                        return jnp.where(m, sv, cur)
                    return jax.tree.map(mask_one, sub, snap)
                self._rewind = jax.jit(
                    wrap("tiered_rewind", rewind),
                    donate_argnums=(0,) if self._donate else ())
        if self.is_encdec:
            self._encode_cross = jax.jit(
                lambda p, fr: lm.encode_cross_kv(p, cfg, fr))

    def _packed_program(self, bucket: int):
        """The packed decode program for one bucket width, jitted on
        first use — or None when the trace guard is sealed and this
        bucket was never warmed, in which case the caller runs the
        full-width masked program instead of recompiling mid-hot-path."""
        prog = self._decode_packed.get(bucket)
        if prog is not None:
            return prog
        guard = self._trace_guard
        name = f"decode_step_packed[b{bucket}]"
        if guard is not None and guard.sealed \
                and not guard.traces.get(name):
            return None
        cfg, ps = self.cfg, self.page_size
        wrap = guard.wrap if guard is not None else (lambda _n, f: f)
        prog = jax.jit(
            wrap(name,
                 lambda p, c, t, pl, pt, lv, si: lm.decode_step(
                     p, cfg, c, t, pl, page_table=pt, page_size=ps,
                     live=lv, slot_idx=si)),
            donate_argnums=(1,) if self._donate else ())
        self._decode_packed[bucket] = prog
        return prog

    # --------------------------------------------------- per-slot state

    def _group_tables(self) -> List[np.ndarray]:
        """Every group's host page table, primary (group 0) first."""
        return [self.page_table] + self.aux_tables

    def _group_pages(self, g: int) -> List[List[Optional[int]]]:
        """Group ``g``'s per-slot logical page lists."""
        return self.slot_pages if g == 0 else self.aux_pages[g - 1]

    def _key(self, req: Request):
        """The policy's urgency key (smaller = more urgent)."""
        return self.policy.sort_key(req, self._arrival[id(req)])

    def _reset_slot_state(self, slot: int) -> None:
        """(Re-)admission: zero the slot's recurrent state so a previous
        occupant cannot leak into this request — preemption recovery is
        recompute, and recompute must start from the fresh state."""
        if self._fresh_state is None:
            return
        self.cache = {"layers": CS.reset_slot_state(
            self.cache["layers"], self._fresh_state, slot,
            lm.uses_scan(self.cfg))}

    def _drop_page_snap(self, psnap) -> None:
        """Discard a retained-page set: reclaim whatever private entries
        still exist and return their pages to the free list."""
        if psnap is None:
            return
        pages = self.pool.reclaim_private(psnap[1])
        if pages:
            self.pool.release(pages)
            if self.tiered:
                self._prune_host()

    def _try_restore_state(self, slot: int, req: Request,
                           n_pre: int) -> Optional[int]:
        """Snapshot-on-preemption restore: write the host snapshot back
        into the slot and return the number of prompt tokens it already
        folded in, or None when recompute must run. Pure-state families
        need only the snapshot; hybrids also reclaim their retained K/V
        pages — all-or-nothing, since a state snapshot over a partial K/V
        prefix would attend garbage."""
        snap = self._state_snap.get(id(req))
        psnap = self._page_snap.pop(id(req), None)
        if snap is None or not self._snap_eligible:
            self._drop_page_snap(psnap)
            return None
        consumed, tree = snap
        if not 1 <= consumed <= n_pre:
            self._drop_page_snap(psnap)
            return None
        if self.has_pages:
            if psnap is None:
                return None
            pages_list, keys = psnap
            if self.pool.reclaim_private(keys) is None:
                # pool pressure evicted part of the retained set while we
                # were queued: the snapshot is unusable, recompute instead
                return None
            self.slot_pages[slot] = list(pages_list)
            row = np.zeros((self.max_pages,), np.int32)
            for i, pg in enumerate(pages_list):
                if pg is not None:
                    row[i] = pg
            self.page_table[slot] = row
            self.peak_slot_pages = max(
                self.peak_slot_pages,
                sum(p is not None for p in pages_list))
        self.cache = {"layers": CS.reset_slot_state(
            self.cache["layers"], jax.tree.map(jnp.asarray, tree), slot,
            lm.uses_scan(self.cfg))}
        self.n_state_restores += 1
        return consumed

    def _install_cross(self, slot: int, frames: np.ndarray) -> None:
        """CrossAttnStatic lifecycle: run the encoder once at admission and
        write this request's cross K/V into its slot."""
        ck, cv = self._encode_cross(self.params,
                                    jnp.asarray(frames)[None])
        layers = self.cache["layers"]
        upd = {}
        if "cross_k_scale" in layers:
            # quantized CrossAttnStatic: one scale per (layer, slot),
            # written once here — the slot is never rewritten, so no RMW
            qmax = self.cfg.page_layout.qmax

            def quantize(x, dst):
                amax = jnp.max(jnp.abs(x),
                               axis=tuple(range(1, x.ndim)))      # (L,)
                s = jnp.maximum(amax, PC.QUANT_EPS) / qmax
                codes = PC.quantize_rows(
                    x, s.reshape((-1,) + (1,) * (x.ndim - 1)),
                    dst.dtype, qmax)
                return codes, s

            ck, ks = quantize(ck, layers["cross_k"])
            cv, vs = quantize(cv, layers["cross_v"])
            upd["cross_k_scale"] = _dus(layers["cross_k_scale"],
                                        ks[:, None], slot, 1)
            upd["cross_v_scale"] = _dus(layers["cross_v_scale"],
                                        vs[:, None], slot, 1)
        self.cache = {"layers": {
            **layers,
            "cross_k": _dus(layers["cross_k"], ck, slot, 1),
            "cross_v": _dus(layers["cross_v"], cv, slot, 1), **upd}}

    # -------------------------------------------------------- lifecycle

    def _terminal(self, req: Request, status: Status, detail: str = "",
                  retry_after: float = 0.0) -> None:
        """Move a request to a terminal status and drop every piece of
        engine state keyed to it — fold bookkeeping, arrival order, host
        state snapshots and privately-retained pages — so a terminated
        request leaks nothing no matter how it ended."""
        # lifecycle: live -> terminal
        LC.transition(req, status, detail)
        req.t_done = self._clock()
        req.retry_after = retry_after
        self.lifecycle_counts[str(status)] = \
            self.lifecycle_counts.get(str(status), 0) + 1
        self._folded.pop(id(req), None)
        self._arrival.pop(id(req), None)
        self._state_snap.pop(id(req), None)
        self._drop_page_snap(self._page_snap.pop(id(req), None))

    def _retry_after_hint(self) -> float:
        """SHED hint: ticks to drain the current backlog at the decode
        budget — roughly when resubmitting stops being hopeless."""
        live = [r for r in self.slot_req if r is not None]
        rem = sum(max(r.max_new - len(r.out), 1)
                  for r in list(self._queue) + live)
        return float(-(-rem // max(self.budget.decode_tokens, 1)))

    def cancel(self, rid: int, detail: str = "client cancel") -> bool:
        """Terminate a request by id — queued, mid-prefill, or
        mid-decode. A running request's references are released exactly
        like a finished one's: shared prefix pages survive for their
        other readers, sole-owned pages (incl. a COW'd tail) return to
        the pool, and any preemption snapshot is dropped. Returns False
        when no live request carries this rid."""
        for i, req in enumerate(self._queue):
            if req.rid == rid:
                del self._queue[i]
                self._terminal(req, Status.CANCELLED, detail)
                return True
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is not None and req.rid == rid:
                self._terminal(req, Status.CANCELLED, detail)
                self._release_slot(slot)
                return True
        return False

    def _expire_deadlines(self) -> None:
        """Tick phase 0: expire breached deadlines, queued or running."""
        now = self._clock()
        for req in list(self._queue):
            why = LC.breach(req.deadline, now, req.t_submit, bool(req.out))
            if why:
                self._queue.remove(req)
                self._terminal(req, Status.TIMED_OUT, why)
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None:
                continue
            why = LC.breach(req.deadline, now, req.t_submit, bool(req.out))
            if why:
                self._terminal(req, Status.TIMED_OUT, why)
                self._release_slot(slot)

    # ------------------------------------------------------------ admin

    def submit(self, req: Request) -> None:
        if self.is_encdec and req.frames is None:
            raise ValueError("encoder-decoder serving needs Request.frames "
                             "(enc_seq, d_model)")
        req.t_submit = self._clock()
        if self.admission == "strict":
            why = oversized_reason(len(req.prompt), req.max_new, self.smax)
            if why:
                self._terminal(req, Status.FAILED, f"oversized: {why}")
                return
        self._arrival[id(req)] = self._arrival_seq
        self._arrival_seq += 1
        self._queue.append(req)

    def _pop_next(self) -> Request:
        """Most urgent waiting request by the policy key. Re-admissions
        keep their original arrival, so under FIFO a preempted request
        resumes ahead of everything that arrived after it."""
        qi = min(range(len(self._queue)),
                 key=lambda i: self._key(self._queue[i]))
        req = self._queue[qi]
        del self._queue[qi]
        return req

    def _admit_into(self, slot: int, req: Request) -> None:
        # lifecycle: QUEUED -> PREFILL
        LC.transition(req, Status.PREFILL)
        toks = req.prompt.astype(np.int32)
        if not req.out:
            cap = context_cap(self.smax, req.max_new)
            if len(toks) > cap:
                toks = toks[-cap:]
        # else: re-admission after a mid-decode preemption. Everything
        # in the folded prompt was legitimately cached at preemption
        # (pos_after < smax-1, so len <= smax-1): re-truncating here
        # would drop context the unpreempted run kept and make greedy
        # output depend on preemption timing.
        req.prompt = toks
        self.slot_req[slot] = req
        self.slot_pages[slot] = []
        for g in range(1, self.n_groups):
            self.aux_pages[g - 1][slot] = []
            self.aux_tables[g - 1][slot] = 0
        self._cow_pending.pop(slot, None)
        self._admit_order.append(slot)
        self.pos[slot] = 0
        n_pre = len(toks) - 1
        restored = self._try_restore_state(slot, req, n_pre)
        if restored is None:
            self._reset_slot_state(slot)
        if self.is_encdec:
            self._install_cross(slot, req.frames)
        start = 0
        self._reg_next[slot] = 0
        self._reg_parent[slot] = PC.ROOT_KEY
        if restored is not None:
            start = restored
        elif self.prefix_caching and n_pre > 0:
            pages, cov, tail, parent = self.pool.match_prefix(toks, n_pre)
            if pages:
                self.page_table[slot, :len(pages)] = pages
                self.slot_pages[slot] = list(pages)
                if tail:
                    # shared partial tail: read-only until the first write
                    # into it forces a copy (COW)
                    self._cow_pending[slot] = len(pages) - 1
                n_full = len(pages) - (1 if tail else 0)
                self._reg_next[slot] = n_full
                self._reg_parent[slot] = parent
                self.peak_slot_pages = max(self.peak_slot_pages,
                                           len(pages))
                start = cov
        if n_pre > start:
            self._prefill_at[slot] = start
        else:
            self._ready(slot)

    def _ready(self, slot: int) -> None:
        """Prefill finished: the slot joins the decode batch."""
        req = self.slot_req[slot]
        # lifecycle: PREFILL -> DECODE
        LC.transition(req, Status.DECODE)
        toks = req.prompt
        self._prefill_at.pop(slot, None)
        self.pos[slot] = len(toks) - 1
        self.last_tok[slot] = int(toks[-1])
        self.live[slot] = True
        if self.tiered and any(p is not None
                               for p in self.slot_pages[slot]):
            # pin the decode write-target now if a frame allows it; the
            # decode phase re-ensures residency before every batched step,
            # so failing here only costs a sync fetch later
            tail = [p for p in self.slot_pages[slot] if p is not None][-1]
            if self._ensure_resident([tail]):
                self._repin_tail(slot)

    def _release_slot(self, slot: int) -> None:
        """Return a slot to the pool — pure page/slot bookkeeping, no
        request-status side effects (callers pair this with ``_terminal``
        or a requeue, which own the status transition)."""
        if self.tiered:
            old = self._pinned_tail.pop(slot, None)
            if old is not None:
                self.pool.unpin(old)
        # recycled (None) entries were released the moment they slid out
        # of the window; everything else drops one reference — a shared
        # page another request (or the prefix index) still needs survives,
        # a sole-owned one returns to the free list / LRU
        self.pool.release(
            [p for p in self.slot_pages[slot] if p is not None])
        for g in range(1, self.n_groups):
            self.pool.release(
                [p for p in self.aux_pages[g - 1][slot] if p is not None])
            self.aux_pages[g - 1][slot] = []
            self.aux_tables[g - 1][slot] = 0
        if self.tiered:
            self._prune_host()
        self.slot_pages[slot] = []
        self._cow_pending.pop(slot, None)
        self._reg_next.pop(slot, None)
        self._reg_parent.pop(slot, None)
        # retarget the freed slot at the trash page so the batched decode
        # step's unconditional write cannot touch reallocated pages
        self.page_table[slot] = 0
        self.pos[slot] = 0
        self.live[slot] = False
        self.slot_req[slot] = None
        self._prefill_at.pop(slot, None)
        self._admit_order.remove(slot)

    def _retain_slot_pages(self, slot: int, req: Request) -> None:
        """Hybrid preemption (StateSlot + paged K/V, e.g. hymba): park the
        slot's own K/V pages as *private* pool entries so re-admission can
        apply the state snapshot instead of recomputing the folded prompt.
        Private entries are unreachable from prefix matching; once the
        slot releases its references they sit unreferenced, so under
        pressure the pool evicts them like any cached page and the restore
        falls back to recompute (``_try_restore_state`` is all-or-nothing:
        a partial K/V prefix is useless to the snapshot)."""
        keys, ok = [], True
        for p in self.slot_pages[slot]:
            if p is None:
                continue
            try:
                keys.append(self.pool.register_private(p))
            except ValueError:
                ok = False      # page already published (shared): the
                break           # retained set cannot be made whole
        if ok and keys:
            self._page_snap[id(req)] = (list(self.slot_pages[slot]), keys)
        elif keys:
            self._drop_page_snap(([], keys))

    def _preempt(self, slot: int) -> None:
        """Recompute-preemption: fold generated tokens into the prompt and
        requeue; greedy decoding reproduces the rest. A preempted request
        *releases* its references — shared pages are never freed out from
        under their other readers. State-carrying families additionally
        snapshot the slot's recurrent state to host so re-admission can
        skip re-running the folded prompt; hybrids park their K/V pages
        beside the snapshot (pure-paged families keep recompute).

        With ``shed_after`` set, a request that has already churned
        through that many preemptions is **shed** instead of requeued:
        terminal SHED with a retry-after hint, its pages released. Under
        sustained pressure this converts recompute livelock into an
        explicit, client-visible admission-control signal."""
        req = self.slot_req[slot]
        req.n_preempts += 1
        if (self.shed_after is not None
                and req.n_preempts >= self.shed_after):
            self.n_preempted += 1
            self.n_shed += 1
            self._terminal(
                req, Status.SHED,
                f"pool pressure: preempted {req.n_preempts}x",
                retry_after=self._retry_after_hint())
            self._release_slot(slot)
            return
        consumed = self._prefill_at.get(slot)
        folded = self._folded.get(id(req), 0)
        fresh = req.out[folded:]
        if fresh:
            req.prompt = np.concatenate(
                [req.prompt, np.asarray(fresh, np.int32)])
            self._folded[id(req)] = len(req.out)
        if consumed is None:
            # live mid-decode: the state has folded in every token of the
            # (just-folded) prompt except the last, which re-admission
            # feeds through the first decode step
            consumed = len(req.prompt) - 1 if self.live[slot] else 0
        if self._snap_eligible and consumed >= 1:
            snap = CS.snapshot_slot_state(
                self.cache["layers"], self._fresh_state, slot,
                lm.uses_scan(self.cfg))
            # host-sync: preemption snapshot copy-out — rare, off the
            # steady-state decode path by construction
            self._state_snap[id(req)] = (consumed, jax.device_get(snap))
            if self.has_pages and self.n_groups == 1:
                # multi-group hybrids recompute: retention parks only the
                # primary table's pages, and a restore over missing aux
                # pages would attend garbage (_try_restore_state is
                # all-or-nothing, so no psnap -> recompute)
                self._retain_slot_pages(slot, req)
        # lifecycle: PREFILL|DECODE -> QUEUED
        LC.transition(req, Status.QUEUED, "preempted")
        self._release_slot(slot)
        self._queue.appendleft(req)
        self.n_preempted += 1

    def _make_room(self, need: int, protect: int) -> bool:
        """Free pages by preempting strictly-less-urgent requests (largest
        policy key first) — under FIFO that is exactly "newest arrival
        first; an older request is never evicted for a younger one", so
        head-of-line requests always finish. Unreferenced cached pages do
        NOT require preemption: they count as available and ``alloc``
        reclaims them LRU-first, so eviction always precedes preemption.
        Only slots actually holding pages are victims (a just-admitted
        slot with none would be churned for nothing). True iff ``need``
        pages are now available."""
        while self.pool.available_pages < need:
            mine = self._key(self.slot_req[protect])
            candidates = [s for s in self._admit_order
                          if s != protect
                          and any(p is not None for p in self.slot_pages[s])
                          and self._key(self.slot_req[s]) > mine]
            if not candidates:
                return False
            # under sharing, releasing a page only reclaims it when this
            # slot is its last holder: prefer victims whose preemption
            # actually gains pages; fall back to shared-only holders only
            # when nothing gainful exists (their release drops refcounts,
            # which is what turns a co-holder into a gainful victim next
            # iteration — so the loop still makes progress)
            gainful = [s for s in candidates
                       if any(p is not None and self.pool.refcount(p) == 1
                              for p in self.slot_pages[s])]
            # victim order: the policy's shed key — least urgent first,
            # ties toward the most-churned request, which is also the one
            # shed_after retires when pressure is sustained
            self._preempt(max(
                gainful or candidates,
                key=lambda s: self.policy.shed_key(
                    self.slot_req[s],
                    self._arrival[id(self.slot_req[s])],
                    self.slot_req[s].n_preempts)))
        return True

    def _grow_to(self, slot: int, n_tokens: int) -> bool:
        """Ensure every group's table covers logical positions
        [0, n_tokens). Groups grow in lockstep — each group's layers write
        the same token row, so logical coverage is identical across
        tables; only recycling (per-group window) makes them diverge."""
        if not self.has_pages:
            return True                    # StateSlot-only model (xlstm)
        want = PagePool.pages_for(n_tokens, self.page_size)
        needs = [max(want - len(self._group_pages(g)[slot]), 0)
                 for g in range(self.n_groups)]
        total = sum(needs)
        if total <= 0:
            return True
        if not self._make_room(total, protect=slot):
            return False
        # tiered: fresh pages are born RESIDENT, so claim frames first —
        # by demotion, never by preempting (demote-before-preempt: the
        # _make_room above handles *logical* page shortage, which frames
        # cannot fix; frame shortage is always demotion's job)
        if self.tiered and not self._demote_for_frames(
                total, protect=frozenset(
                    p for p in self.slot_pages[slot] if p is not None)):
            return False
        for g, (need, table) in enumerate(zip(needs,
                                              self._group_tables())):
            if not need:
                continue
            pages = self.pool.alloc(need)
            if pages is None:
                # injected alloc_fail: contended this tick. Groups grown
                # so far keep their (consistent) pages; the retry only
                # re-requests what is still missing
                return False
            plist = self._group_pages(g)[slot]
            base = len(plist)
            table[slot, base:base + need] = pages
            plist.extend(pages)
        self.peak_slot_pages = max(
            self.peak_slot_pages,
            sum(p is not None for p in self.slot_pages[slot]))
        return True

    def _resolve_cow(self, slot: int) -> bool:
        """Copy-on-write of a shared tail page, run lazily right before
        this slot's first write could land in it. If the slot is the
        page's only reader it takes ownership in place — the index entry
        is dropped (this write is about to overwrite the cached content)
        and no copy is paid; only a page another request still reads is
        actually copied, the table entry repointed, and the original left
        serving its other readers. False when the pool cannot produce the
        copy's page (caller retries or preempts)."""
        idx = self._cow_pending.get(slot)
        if idx is None:
            return True
        old = self.slot_pages[slot][idx]
        if self.pool.refcount(old) == 1:
            self.pool.deregister(old)
            self._cow_pending.pop(slot)
            return True
        if not self._make_room(1, protect=slot):
            return False
        if self.pool.refcount(old) == 1:
            # _make_room preempted the co-holder: sole reader after all —
            # take ownership instead of paying the copy at peak pressure
            self.pool.deregister(old)
            self._cow_pending.pop(slot)
            return True
        if self.tiered:
            # the copy reads the source frame and writes a fresh one:
            # both ends must be on device before the kernel runs (promote
            # the source first — its promotion may consume a free frame,
            # the destination's frame is claimed after)
            prot = frozenset(
                p for p in self.slot_pages[slot] if p is not None)
            if not (self._ensure_resident([old], prot)
                    and self._demote_for_frames(1, prot | {old})):
                return False
        got = self.pool.alloc(1)
        if got is None:
            return False        # injected alloc_fail: contended this tick
        new = got[0]
        if self.tiered:
            self.cache = self._copy_page_t(
                self.cache, old, new,
                jnp.int32(self.pool.frame_of(old)),
                jnp.int32(self.pool.frame_of(new)))
        else:
            self.cache = self._copy_page(self.cache, old, new)
        self.page_table[slot, idx] = new
        self.slot_pages[slot][idx] = new
        if self.tiered:
            # the old page may have been this slot's pinned tail: move
            # the pin to the copy BEFORE dropping the reference
            self._repin_tail(slot)
        self.pool.release([old])
        if self.tiered:
            self._prune_host()
        self._cow_pending.pop(slot)
        self.n_cow_copies += 1
        return True

    def _register_ready_pages(self, slot: int) -> None:
        """Publish full prompt pages the prefill has completely written.
        Only pages fully covered by *prefilled* prompt tokens register —
        the page receiving decode writes never does, so registered pages
        are immutable and safe to alias."""
        if not self.prefix_caching:
            return
        req = self.slot_req[slot]
        toks = req.prompt
        written = self._prefill_at.get(slot, len(toks) - 1)
        ps = self.page_size
        i = self._reg_next[slot]
        while (i + 1) * ps <= written:
            self._reg_parent[slot] = self.pool.register(
                self.slot_pages[slot][i], self._reg_parent[slot],
                toks[i * ps:(i + 1) * ps])
            i += 1
        self._reg_next[slot] = i

    def _recycle_window(self, slot: int, next_q: int) -> None:
        """WindowPagedAttn lifecycle: pages every future query's window has
        slid past are dead — free them and point their table entries at the
        trash page (reads of recycled rows are masked by the sliding-window
        mask exactly like the dense cache's dead rows). ``next_q`` is the
        earliest position any future query of this slot can have; it
        attends kv >= next_q - window + 1."""
        windows = self.group_windows or ((self.window,)
                                         if self.window else ())
        for g, w in enumerate(windows):
            if not w:
                continue         # full-attention group: pages pin forever
            first_live = max(0, next_q - w + 1) // self.page_size
            pages = self._group_pages(g)[slot]
            freed = [p for p in pages[:first_live] if p is not None]
            if not freed:
                continue
            pages[:first_live] = [None] * min(first_live, len(pages))
            self.pool.release(freed)
            if self.tiered:
                self._prune_host()
            self.n_recycled_pages += len(freed)
            self._group_tables()[g][slot, :first_live] = 0
            live = sum(p is not None for p in pages)
            if live > self._group_pages_hard[g]:
                raise RuntimeError(
                    f"slot {slot} group {g} holds {live} pages after "
                    "recycling, above the spec-table bound "
                    f"{self._group_pages_hard[g]}")

    # ------------------------------------------- tiered KV pool (§13)

    def _frame_table(self, pt: np.ndarray) -> np.ndarray:
        """Resolve a logical page table to device frames. RESIDENT pages
        map to their frame; HOST pages (and staging frames still in
        flight) map to the trash frame 0 — rows read through a trash
        entry are finite garbage that the selection's validity mask turns
        into an exactly-zero attention contribution, and the winner mask
        is what reports the page for promotion."""
        lut = np.zeros((self.pool.n_pages,), np.int32)
        for p, f in self.pool.frame_map().items():
            lut[p] = f
        for p in self.pool.inflight_page_ids():
            lut[p] = 0
        return lut[pt]

    def _prune_host(self) -> None:
        """Drop host byte buffers no off-device page needs anymore: only
        HOST / IN_FLIGHT pages can ever be promoted from host bytes."""
        keep = set(self.pool.host_page_ids()) \
            | set(self.pool.inflight_page_ids())
        if len(self._host_kv) != len(keep):
            self._host_kv = {p: v for p, v in self._host_kv.items()
                             if p in keep}

    def _promote_copy(self, page: int, frame: int) -> None:
        """FetchQueue copy_fn: host bytes -> the claimed staging frame.
        ``jnp.asarray`` starts the host->device transfer and the jitted
        row update is dispatched asynchronously, so the copy overlaps
        whatever the host enqueues next (the repair run's score pass)."""
        k_np, v_np = self._host_kv[page]
        self.cache = self._promote_write(
            self.cache, jnp.asarray(k_np), jnp.asarray(v_np),
            jnp.int32(frame))

    def _demote_page(self, page: int) -> None:
        """Copy-then-demote: pull the page's full-D rows out of its frame
        into host memory, then surrender the frame. The latent sidecar
        row stays on device, so the page keeps scoring in the approximate
        pass; only exact attention needs it back."""
        frame = self.pool.frame_of(page)
        attn = self.cache["layers"]["attn"]
        sl = slice(frame * self.page_size, (frame + 1) * self.page_size)
        # host-sync: demotion copy-out — runs under frame pressure, never
        # on the steady-state all-resident decode path
        k_np, v_np = jax.device_get((attn["k"][:, sl], attn["v"][:, sl]))
        self._host_kv[page] = (k_np, v_np)
        self.pool.demote(page)

    def _demote_for_frames(self, need: int, protect=frozenset()) -> bool:
        """Free device frames by demoting victims in the policy's
        ``demote_key`` order — cached-but-unreferenced pages first (their
        frames serve nobody; their bytes keep prefix value on host), then
        cold residents by last-use tick. Demotion always precedes
        preemption or shedding: losing a frame costs one prefetch, losing
        a slot costs a re-prefill. Pinned tails and ``protect`` pages are
        never victims. True iff ``need`` frames are now free."""
        if not self.tiered:
            return True
        if self.pool.free_frames >= need:
            return True
        lru_pos = {p: i for i, p in enumerate(self.pool.lru_page_ids())}
        cands = [p for p in self.pool.resident_page_ids()
                 if p not in protect and not self.pool.is_pinned(p)]
        cands.sort(key=lambda p: self.policy.demote_key(
            p, p in lru_pos, lru_pos.get(p, 0),
            self._page_last_use.get(p, -1)))
        for p in cands:
            if self.pool.free_frames >= need:
                break
            self._demote_page(p)
        return self.pool.free_frames >= need

    def _promote_sync(self, page: int, protect=frozenset()) -> bool:
        """Synchronous promote, counted — the miss-repair fallback and
        the path for reads with no trash-masking to hide behind (prefill
        prefix gathers, COW sources, decode write targets). Claims a
        frame (demoting a victim if none is free), copies, completes.
        False when no frame could be claimed this tick (injected
        hbm_oom_on_promote, or every frame pinned/protected): the caller
        defers its slot to the next tick — bit-safe under greedy
        decoding, since nothing of that stream advanced."""
        state = self.pool.tier_of(page)
        if state == PC.IN_FLIGHT:
            self._fetch.drain()
            self._prune_host()
            state = self.pool.tier_of(page)
        if state == PC.RESIDENT:
            return True
        frame = self.pool.promote_begin(page)
        if frame is None:
            self._demote_for_frames(1, protect | {page})
            frame = self.pool.promote_begin(page)
        if frame is None:
            return False
        self._promote_copy(page, frame)
        self.pool.promote_complete(page)
        self.n_sync_fetches += 1
        self._prune_host()
        return True

    def _ensure_resident(self, pages, protect=frozenset()) -> bool:
        """Promote every off-device page in ``pages`` synchronously."""
        if not self.tiered:
            return True
        todo = [p for p in pages if p is not None]
        prot = frozenset(protect) | set(todo)
        return all(self._promote_sync(p, prot) for p in todo)

    def _repin_tail(self, slot: int) -> None:
        """Pin the slot's current write-target (tail) page, unpinning the
        previous one once the tail moves. The batched decode writes K/V
        rows through the frame table; a pinned tail cannot be demoted, so
        a write is never silently diverted to the trash frame."""
        live = [p for p in self.slot_pages[slot] if p is not None]
        tail = live[-1] if live else None
        old = self._pinned_tail.get(slot)
        if old == tail:
            return
        if old is not None:
            self.pool.unpin(old)
            self._pinned_tail.pop(slot, None)
        if tail is not None:
            self.pool.pin(tail)
            self._pinned_tail[slot] = tail

    def _frame_starved(self, slot: int) -> bool:
        """True when this slot's decode-prep growth failed for *frames*
        rather than logical pages: the pool could satisfy the growth (and
        a pending COW copy) out of free or cached pages, so only the
        device tier is short. Frame shortage is demotion's and deferral's
        job; it must never preempt (DESIGN.md §13)."""
        need = PagePool.pages_for(int(self.pos[slot]) + 1, self.page_size) \
            - len(self.slot_pages[slot])
        if slot in self._cow_pending:
            need += 1
        return self.pool.available_pages >= max(need, 0)

    def _unpin_tails(self, keep) -> None:
        """Drop the best-effort tail pins of every slot not in ``keep``.
        Safe at any point after the pinned slot's last write landed: a
        demotion copies the frame's rows to the host first, so unpinning
        never loses data — it only lets the demotion policy consider
        those frames again. Unpinned slots re-ensure and re-pin in their
        own prep (or defer if they cannot)."""
        for t in [t for t in self._pinned_tail if t not in keep]:
            self.pool.unpin(self._pinned_tail.pop(t))

    def _winner_pages(self, pt: np.ndarray, win: np.ndarray,
                      sel: np.ndarray):
        """slot -> set of logical pages this run's selection attended."""
        out: Dict[int, set] = {}
        for s in np.flatnonzero(sel):
            out[int(s)] = {int(p) for p in pt[s][win[s]] if p != 0}
        return out

    def _repair_misses(self, miss: Dict[int, List[int]],
                       winners: Dict[int, set],
                       todo: np.ndarray) -> None:
        """Promote the missed pages of as many slots as the device pool
        allows, most urgent first; slots whose misses cannot all fit
        *defer* (dropped from ``todo``; their streams re-run identically
        next tick). Frames are granted incrementally: each repaired
        slot's full winner set joins the protected set, so a later slot
        can never demote an earlier one's pages and re-runs make strict
        progress. When even the head-of-line slot cannot fit, every
        other stream defers and unpins so it can claim the whole pool —
        the ctor guarantees one request always fits on device."""
        order = sorted(miss, key=lambda s: self.policy.decode_key(
            self.slot_req[s], self._arrival[id(self.slot_req[s])],
            int(self._last_decoded[s])))

        def claim(pages, trial):
            for p in pages:
                if self.pool.tier_of(p) != PC.HOST:
                    continue    # already in flight / just promoted
                if not self._fetch.request(p):
                    self._demote_for_frames(1, frozenset(trial))
                    if not self._fetch.request(p):
                        return False
            return True

        protect = set(self._pinned_tail.values())
        head_took_all = False
        for i, s in enumerate(order):
            if head_took_all:
                todo[s] = False
                continue
            trial = protect | winners[s]
            if claim(miss[s], trial):
                protect = trial
                continue
            if i == 0:
                # head-of-line starvation: everything else defers, its
                # pins lift (a deferred stream commits nothing this tick;
                # next tick's prep re-promotes and re-pins its tail)
                self._unpin_tails(keep={s})
                head_took_all = True
                trial = winners[s] | {self._pinned_tail.get(s)} - {None}
                if claim(miss[s], trial):
                    continue
            todo[s] = False                 # defer this stream

    def _decode_tiered(self, sel: np.ndarray, rng):
        """Two-phase tiered decode (DESIGN.md §13): one optimistic jitted
        run whose score pass reads only the always-resident latent
        sidecar, then exact attention through the frame table. Slots
        whose every attended (winner) page was resident **commit** their
        token immediately — their run was exact. Slots that attended an
        off-device page saw trash-frame garbage: their misses are
        promoted through the bounded fetch queue and only *they* re-run.
        Replay is exact because a slot's K/V row write depends only on
        its input token and position (never on what attention read), the
        recurrent state of re-run slots is restored from a pre-run device
        snapshot, and positions only advance after the phase. A slot
        whose misses cannot be promoted this tick is deferred whole.

        Returns (nxt, finite, committed) over the full slot axis, with
        ``committed`` <= the ``sel`` passed in."""
        todo = sel.copy()
        done = np.zeros_like(sel)
        nxt_out = np.zeros((self.n_slots,), np.int64)
        fin_out = np.ones((self.n_slots,), bool) if self.nan_guard \
            else None
        # one pre-phase snapshot of the recurrent-state leaves: every
        # re-run restores its slots to this, so each stream's state
        # advances exactly once no matter how many runs it took
        snap = None
        if self._fresh_state is not None:
            layers = self.cache["layers"]
            snap = {k: jax.tree.map(jnp.copy, layers[k])
                    for k in self._fresh_state}
        for attempt in range(_TIERED_MAX_RUNS):
            ran = todo.copy()
            sel_dev = jnp.asarray(todo)
            pt = self.page_table * todo.astype(np.int32)[:, None]
            ft = self._frame_table(pt)
            logits, win, self.cache = self._run_decode_t(pt, ft, sel_dev)
            if self._faults is not None:
                bad = [s for s in np.flatnonzero(todo)
                       if self._faults.hit("nan_logits", int(s))]
                if bad:
                    logits = logits.at[
                        jnp.asarray(bad, jnp.int32)].set(jnp.nan)
            finite_dev = jnp.isfinite(logits).all(axis=-1) \
                if self.nan_guard else None
            nxt = sample_next(logits, greedy=self.greedy, rng=rng,
                              ticks=self.ticks)
            # host-sync: the ONE batched device->host sync of the common
            # (all-hit) tiered tick — sampled tokens, the nan-guard mask
            # and the winner mask cross together
            nxt_np, finite, win_np = jax.device_get(
                (nxt, finite_dev, win))
            winners = self._winner_pages(pt, np.asarray(win_np), todo)
            miss = {s: [p for p in sorted(pages)
                        if self.pool.tier_of(p) != PC.RESIDENT]
                    for s, pages in winners.items()}
            miss = {s: ps_ for s, ps_ in miss.items() if ps_}
            if attempt == 0:
                uniq = set().union(*winners.values()) if winners else set()
                n_miss = sum(self.pool.tier_of(p) != PC.RESIDENT
                             for p in uniq)
                self.n_prefetch_misses += n_miss
                self.n_prefetch_hits += len(uniq) - n_miss
            # commit every fully-resident slot: its token is exact, its
            # K/V row write is input-only (valid even beside garbage
            # reads), and its advanced state must NOT be restored
            for s in winners:
                if s in miss:
                    continue
                done[s] = True
                todo[s] = False
                nxt_out[s] = nxt_np[s]
                if fin_out is not None:
                    fin_out[s] = bool(finite[s])
                for p in winners[s]:
                    self._page_last_use[p] = self.ticks
            if todo.any():
                self.n_decode_reruns += 1
                self._repair_misses(miss, winners, todo)
                self._fetch.drain()
                self._prune_host()
            # restore every slot that ran this attempt without
            # committing — both the re-running and the just-deferred:
            # their recurrent state advanced on garbage attention inputs
            # and must rewind to the snapshot (committed slots keep
            # theirs, so each stream's state advances exactly once)
            stale = ran & ~done
            if snap is not None and stale.any():
                # one jitted masked restore over every stale slot at once
                # (was a per-slot snapshot/reset Python loop: a chain of
                # eagerly-dispatched slice updates per re-run)
                layers = self.cache["layers"]
                sub = {k: layers[k] for k in snap}
                sub = self._rewind(sub, snap, jnp.asarray(stale))
                self.cache = {"layers": {**layers, **sub}}
            if not todo.any():
                return nxt_out, fin_out, done
        raise RuntimeError(
            f"tiered decode did not converge in {_TIERED_MAX_RUNS} runs "
            "(promotion/selection ping-pong; raise device_pages)")

    def _run_decode_t(self, pt, ft, sel_dev):
        """Tiered twin of ``_run_decode``: same kernel-failure
        degradation ladder around the frame-table decode program."""
        lv = sel_dev if self.has_state else None
        on_pallas = dispatch.resolve_backend(
            self.cfg.loki.backend) == "pallas"
        try:
            if (on_pallas and self._faults is not None
                    and self._faults.hit("kernel_fail")):
                raise FI.FaultInjected("injected fused-kernel abort")
            return self._decode_t(self.params, self.cache, self.last_tok,
                                  self.pos, pt, jnp.asarray(ft), lv)
        except Exception as e:
            if not on_pallas:
                raise
            dispatch.disable_backend("pallas", f"decode step failed: {e}")
            self._build_programs()
            self.n_backend_fallbacks += 1
            return self._decode_t(self.params, self.cache, self.last_tok,
                                  self.pos, pt, jnp.asarray(ft), lv)

    # ------------------------------------------------------------ phases

    def _admission_phase(self) -> None:
        """Fill free slots in policy order; then, if the policy allows it,
        let a strictly-more-urgent waiter preempt the least-urgent running
        request for its slot (the running key multiset strictly decreases
        at every swap, so this terminates and the most urgent request
        always makes progress)."""
        while self._queue:
            free = [s for s in range(self.n_slots)
                    if self.slot_req[s] is None]
            if not free:
                break
            self._admit_into(free[0], self._pop_next())
        if not self.policy.preempt_for_admission:
            return
        while self._queue:
            qi = min(range(len(self._queue)),
                     key=lambda i: self._key(self._queue[i]))
            cand = self._queue[qi]
            worse = [s for s in self._admit_order
                     if self._key(self.slot_req[s]) > self._key(cand)]
            if not worse:
                return
            del self._queue[qi]
            self._preempt(max(worse,
                              key=lambda s: self._key(self.slot_req[s])))
            slot = next(s for s in range(self.n_slots)
                        if self.slot_req[s] is None)
            self._admit_into(slot, cand)

    def _prefill_phase(self) -> None:
        """Advance mid-prefill slots, most urgent first, spending at most
        ``budget.prefill_tokens`` real prompt tokens across any number of
        chunks and slots this tick."""
        budget = self.budget.prefill_tokens
        slots = sorted([s for s in self._admit_order
                        if s in self._prefill_at],
                       key=lambda s: self._key(self.slot_req[s]))
        for slot in slots:
            while budget > 0 and slot in self._prefill_at:
                n = self._prefill_slot_chunk(slot)
                if n < 0:
                    break              # this slot is pool-contended; a
                budget -= max(n, 1)    # later slot may still fit (e.g. a
            if budget <= 0:            # chunk into pages it already holds)
                return

    def _prefill_slot_chunk(self, slot: int) -> int:
        """One fixed-size chunk of one slot's prompt. Returns the number
        of real tokens computed, or -1 when the pool is contended."""
        req = self.slot_req[slot]
        toks = req.prompt
        n_pre = len(toks) - 1              # last token goes through decode
        start = self._prefill_at[slot]
        c = self.prefill_chunk
        n_valid = min(c, n_pre - start)
        # recycle before growing: the chunk's earliest query is at
        # ``start``, so pages its window has passed free up first and the
        # per-request bound holds at every instant
        self._recycle_window(slot, start)
        # a shared tail page must be copied before this chunk's first
        # write lands in it (start == the first uncached token)
        if not self._resolve_cow(slot):
            return -1
        if not self._grow_to(slot, start + n_valid):
            return -1
        chunk = np.zeros((1, c), np.int32)
        chunk[0, :n_valid] = toks[start:start + n_valid]
        if self.tiered:
            # prefill reads the *whole* prefix exactly (no trash-masking
            # selection to hide behind) and writes the chunk's pages:
            # everything this slot holds must be resident, synchronously
            held = [p for p in self.slot_pages[slot] if p is not None]
            if not self._ensure_resident(held):
                return -1        # frame-starved this tick: retry later
            self._repin_tail(slot)
            fr = self._frame_table(self.page_table[slot])
            _, self.cache = self._chunk_t(
                self.params, self.cache, jnp.asarray(chunk),
                jnp.int32(start), jnp.int32(n_valid),
                self.page_table[slot], jnp.asarray(fr), jnp.int32(slot))
        else:
            row = self.page_table[slot] if self.n_groups == 1 \
                else np.stack([t[slot] for t in self._group_tables()])
            _, self.cache = self._chunk(
                self.params, self.cache, jnp.asarray(chunk),
                jnp.int32(start), jnp.int32(n_valid),
                row, jnp.int32(slot))
        self._prefill_at[slot] = start + n_valid
        self.n_prefill_computed_tokens += n_valid
        self._register_ready_pages(slot)
        if start + n_valid >= n_pre:
            self._ready(slot)
        return n_valid

    def _decode_phase(self, rng: Optional[jax.Array]) -> bool:
        if not self.live.any():
            return False
        # decode-budget selection: when more slots are live than the
        # budget covers, the policy's decode key picks this tick's batch
        # (strict priority classes, round-robin inside a class)
        chosen = [int(s) for s in np.flatnonzero(self.live)]
        if len(chosen) > self.budget.decode_tokens:
            chosen.sort(key=lambda s: self.policy.decode_key(
                self.slot_req[s], self._arrival[id(self.slot_req[s])],
                int(self._last_decoded[s])))
            chosen = chosen[: self.budget.decode_tokens]
        sel = np.zeros((self.n_slots,), bool)
        sel[chosen] = True
        # every selected slot writes its new token this step: make sure
        # the target page exists and is privately writable (COW first),
        # recycling window-dead pages so SWA slots stay within their
        # spec-table page bound
        prepped: set = set()
        for slot in chosen:
            if not self.live[slot]:
                continue                   # preempted by an earlier grow
            self._recycle_window(slot, int(self.pos[slot]))
            if not (self._resolve_cow(slot)
                    and self._grow_to(slot, int(self.pos[slot]) + 1)):
                if self.tiered and self._frame_starved(slot):
                    # demote-before-preempt (§13): the pool has logical
                    # capacity and only device frames are short — a frame
                    # shortage never costs a slot its pages. Pins are
                    # best-effort and re-taken each tick, so drop the
                    # tails pinned by slots that have not completed this
                    # tick's prep (they re-ensure in their own iteration
                    # or defer) and retry; if frames are still short,
                    # defer the slot one tick instead of preempting.
                    self._unpin_tails(keep=prepped | {slot})
                    if not (self._resolve_cow(slot) and self._grow_to(
                            slot, int(self.pos[slot]) + 1)):
                        sel[slot] = False
                        continue
                else:
                    # this slot's request is the least urgent under memory
                    # pressure: vLLM's recompute policy preempts the
                    # requester itself rather than evicting a more urgent
                    # request
                    self._preempt(slot)
                    continue
            if self.tiered:
                # this step writes a K/V row into the tail page: promote
                # it if demoted, pin it so no repair-loop demotion diverts
                # the write to the trash frame. Frame-starved -> defer the
                # slot one tick (bit-safe: nothing of its stream advances)
                held = [p for p in self.slot_pages[slot] if p is not None]
                if held:
                    if not self._ensure_resident([held[-1]],
                                                 frozenset(held)):
                        sel[slot] = False
                        continue
                    self._repin_tail(slot)
                    self._page_last_use[held[-1]] = self.ticks
                prepped.add(slot)
        sel &= self.live
        if not sel.any():
            return False
        # the batched step writes a token for *every* slot; unselected
        # slots (idle, mid-prefill, live-but-over-budget) must land in the
        # trash page, not at their current position — and their StateSlot
        # components must not advance (``live`` mask)
        if self.tiered:
            nxt_np, finite, sel = self._decode_tiered(sel, rng)
            if not sel.any():
                return False    # every stream deferred to the next tick
        else:
            order = self._packed_order(sel)
            if order is not None:
                # gather-packed step: the batch is the live slots plus
                # distinct idle pad rows up to the bucket width — pad
                # rows write to the trash page (zeroed table rows) and
                # their state is live-masked, so only result unpacking
                # differs from the masked path below
                prog, sidx, plive = order
                n_live = int(plive.sum())
                self.n_packed_ticks += 1
                self.n_packed_rows_saved += self.n_slots - len(sidx)
                keep = plive.astype(np.int32)
                if self.n_groups > 1:
                    pt = np.stack([t[sidx] for t in self._group_tables()],
                                  axis=1) * keep[:, None, None]
                else:
                    pt = self.page_table[sidx] * keep[:, None]
                logits, self.cache = self._run_decode_packed(
                    prog, len(sidx), sidx, pt, plive)
                if self._faults is not None:
                    bad = [i for i in range(n_live)
                           if self._faults.hit("nan_logits",
                                               int(sidx[i]))]
                    if bad:
                        logits = logits.at[
                            jnp.asarray(bad, jnp.int32)].set(jnp.nan)
                finite_dev = jnp.isfinite(logits).all(axis=-1) \
                    if self.nan_guard else None
                nxt = sample_next(logits, greedy=self.greedy, rng=rng,
                                  ticks=self.ticks)
                # host-sync: the ONE batched device->host sync of the
                # packed decode tick
                nxt_p, fin_p = jax.device_get((nxt, finite_dev))
                nxt_np = np.zeros((self.n_slots,), nxt_p.dtype)
                nxt_np[sidx[:n_live]] = nxt_p[:n_live]
                finite = None
                if fin_p is not None:
                    finite = np.ones((self.n_slots,), bool)
                    finite[sidx[:n_live]] = fin_p[:n_live]
            else:
                self.n_masked_ticks += 1
                sel_dev = jnp.asarray(sel)
                keep = sel.astype(np.int32)
                if self.n_groups > 1:
                    pt = np.stack(self._group_tables(),
                                  axis=1) * keep[:, None, None]
                else:
                    pt = self.page_table * keep[:, None]
                logits, self.cache = self._run_decode(pt, sel_dev)
                if self._faults is not None:
                    bad = [s for s in np.flatnonzero(sel)
                           if self._faults.hit("nan_logits", int(s))]
                    if bad:
                        logits = logits.at[
                            jnp.asarray(bad, jnp.int32)].set(jnp.nan)
                finite_dev = jnp.isfinite(logits).all(axis=-1) \
                    if self.nan_guard else None
                nxt = sample_next(logits, greedy=self.greedy, rng=rng,
                                  ticks=self.ticks)
                # host-sync: the ONE batched device->host sync of the
                # decode tick — sampled tokens (and the nan-guard mask)
                # must reach Python to drive per-request lifecycle;
                # everything else stays host-side
                nxt_np, finite = jax.device_get((nxt, finite_dev))
        self.pos += sel.astype(np.int32)
        self._last_decoded[sel] = self.ticks
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None or not sel[slot]:
                continue
            if finite is not None and not finite[slot]:
                # numerically-failed slot: quarantine this request alone
                # (its pages go back to the pool; the rest of the batch
                # saw its own rows only and keeps serving untouched)
                self.n_quarantined += 1
                self._terminal(req, Status.FAILED,
                               "non-finite logits (slot quarantined)")
                self._release_slot(slot)
                continue
            tok = int(nxt_np[slot])
            req.out.append(tok)
            if len(req.out) == 1:
                req.t_first = self._clock()
            finished = (len(req.out) >= req.max_new
                        or (self.eos_id is not None and tok == self.eos_id)
                        or int(self.pos[slot]) >= self.smax - 1)
            if finished:
                self._terminal(req, Status.DONE)
                self._release_slot(slot)
            else:
                self.last_tok[slot] = tok
        return True

    def _run_decode(self, pt, sel_dev):
        """One batched decode step through the degradation ladder: when
        the fused-Pallas path raises (for real, or via the ``kernel_fail``
        injection site), disable the backend process-wide, re-jit so the
        retrace resolves to XLA, and re-run the *same* step — the tick
        completes on the reference path and every later step stays there.
        Failures on the XLA floor propagate: there is nothing left to
        fall back to."""
        lv = sel_dev if self.has_state else None
        on_pallas = dispatch.resolve_backend(
            self.cfg.loki.backend) == "pallas"
        try:
            if (on_pallas and self._faults is not None
                    and self._faults.hit("kernel_fail")):
                raise FI.FaultInjected("injected fused-kernel abort")
            return self._decode(self.params, self.cache, self.last_tok,
                                self.pos, pt, lv)
        except Exception as e:
            if not on_pallas:
                raise
            dispatch.disable_backend("pallas", f"decode step failed: {e}")
            self._build_programs()
            self.n_backend_fallbacks += 1
            return self._decode(self.params, self.cache, self.last_tok,
                                self.pos, pt, lv)

    def _packed_order(self, sel: np.ndarray):
        """Plan this tick's gather-packed batch: (program, slot order,
        packed live mask), or None when the tick should run masked
        full-width — packing disabled, the bucket would not be narrower
        than ``n_slots``, or the trace guard is sealed and this bucket
        was never warmed."""
        if not self.packed:
            return None
        live_idx = np.flatnonzero(sel)
        n_live = int(live_idx.size)
        # bucketed padding keeps the set of program shapes small and
        # stable (log2(n_slots) buckets), so a warmed engine never
        # retraces as occupancy wanders
        bucket = 1 << max(n_live - 1, 0).bit_length()
        if bucket >= self.n_slots:
            return None
        prog = self._packed_program(bucket)
        if prog is None:
            self.n_packed_fallbacks += 1
            return None
        # pad with DISTINCT non-selected slot ids: the packed cache
        # scatter requires unique rows, and uniqueness is what lets pad
        # rows reuse the live-masking/trash-page machinery untouched
        pad = np.setdiff1d(np.arange(self.n_slots, dtype=np.int64),
                           live_idx)[:bucket - n_live]
        sidx = np.concatenate([live_idx, pad]).astype(np.int32)
        plive = np.zeros((bucket,), bool)
        plive[:n_live] = True
        return prog, sidx, plive

    def _run_decode_packed(self, prog, bucket: int, sidx: np.ndarray,
                           pt: np.ndarray, plive: np.ndarray):
        """Packed twin of ``_run_decode``: same degradation ladder, with
        token/position rows gathered to the packed order on the host."""
        lv = jnp.asarray(plive) if self.has_state else None
        tok, pos = self.last_tok[sidx], self.pos[sidx]
        on_pallas = dispatch.resolve_backend(
            self.cfg.loki.backend) == "pallas"
        try:
            if (on_pallas and self._faults is not None
                    and self._faults.hit("kernel_fail")):
                raise FI.FaultInjected("injected fused-kernel abort")
            return prog(self.params, self.cache, tok, pos, pt, lv,
                        jnp.asarray(sidx))
        except Exception as e:
            if not on_pallas:
                raise
            dispatch.disable_backend("pallas", f"decode step failed: {e}")
            self._build_programs()
            self.n_backend_fallbacks += 1
            prog = self._packed_program(bucket)
            return prog(self.params, self.cache, tok, pos, pt, lv,
                        jnp.asarray(sidx))

    def _inject_corruption(self) -> None:
        """``slot_corrupt`` site: silently repoint one live slot's tail
        page entry at a page some *other* slot holds — the kind of
        bookkeeping bug that would alias two requests' caches. Nothing
        fails here by design; the per-tick auditor is what must catch
        it (invariant B/E)."""
        if self._faults is None:
            return
        for slot in range(self.n_slots):
            pages = self.slot_pages[slot]
            tail = [i for i, p in enumerate(pages) if p is not None]
            if (self.slot_req[slot] is None or not tail
                    or not self._faults.hit("slot_corrupt", slot)):
                continue
            mine = {p for p in pages if p is not None}
            foreign = sorted(
                {p for s in range(self.n_slots) if s != slot
                 for p in self.slot_pages[s]
                 if p is not None and p not in mine})
            pages[tail[-1]] = foreign[0] if foreign else 0

    # ------------------------------------------------------------- tick

    def tick(self, rng: Optional[jax.Array] = None) -> None:
        if self._faults is not None:
            self._faults.advance(self.ticks)
        self._expire_deadlines()
        self._admission_phase()
        self._prefill_phase()
        self._decode_phase(rng)
        self._inject_corruption()
        self.ticks += 1
        if self.audit:
            FI.audit_engine(self)

    @property
    def n_prefix_hit_tokens(self) -> int:
        """Prompt tokens served from cached pages (every match goes
        through pool.match_prefix, so the pool's counter is the truth)."""
        return self.pool.n_hit_tokens

    def prefix_hit_rate(self) -> float:
        """Fraction of prefill-eligible prompt tokens served from cached
        pages instead of being computed."""
        total = self.n_prefix_hit_tokens + self.n_prefill_computed_tokens
        return self.n_prefix_hit_tokens / total if total else 0.0

    def run_until_done(self, max_ticks: int = 10_000,
                       rng: Optional[jax.Array] = None) -> None:
        for _ in range(max_ticks):
            if not self._queue and not self._admit_order:
                return
            sub = None
            if rng is not None:
                rng, sub = jax.random.split(rng)
            self.tick(sub)
        self._report_stall(max_ticks)

    def _report_stall(self, max_ticks: int) -> None:
        """Drain exhausted its tick budget with requests still live: a
        stall is an *answer*, not a silent return. Every remaining
        request is marked TIMED_OUT (its pages released, pool back to
        baseline) and recorded in ``stalled_rids`` / ``stats()`` so
        harnesses and operators see exactly who starved."""
        detail = f"stalled: drain hit max_ticks={max_ticks}"
        for req in list(self._queue):
            self._queue.remove(req)
            self._terminal(req, Status.TIMED_OUT, detail)
            self.n_stalled += 1
            self.stalled_rids.append(req.rid)
        for slot in range(self.n_slots):
            req = self.slot_req[slot]
            if req is None:
                continue
            self._terminal(req, Status.TIMED_OUT, detail)
            self._release_slot(slot)
            self.n_stalled += 1
            self.stalled_rids.append(req.rid)

    # ------------------------------------------- Engine protocol surface

    def drain(self, max_ticks: int = 10_000,
              rng: Optional[jax.Array] = None) -> None:
        """Engine protocol: run ticks until no request is queued or live."""
        self.run_until_done(max_ticks, rng)

    def stats(self) -> Dict[str, Any]:
        """Engine protocol: one flat dict of serving counters, keyed the
        same across engine kinds so harnesses never branch on the type."""
        out = {
            "engine": "paged",
            "ticks": self.ticks,
            "layout": self.cfg.page_layout.describe(),
            "n_preempted": self.n_preempted,
            "n_recycled_pages": self.n_recycled_pages,
            "n_cow_copies": self.n_cow_copies,
            "n_state_restores": self.n_state_restores,
            "peak_slot_pages": self.peak_slot_pages,
            "n_prefill_computed_tokens": self.n_prefill_computed_tokens,
            "prefix_hit_rate": self.prefix_hit_rate(),
            "lifecycle": dict(self.lifecycle_counts),
            "n_stalled": self.n_stalled,
            "stalled_rids": list(self.stalled_rids),
            "n_shed": self.n_shed,
            "n_quarantined": self.n_quarantined,
            "n_backend_fallbacks": self.n_backend_fallbacks,
            "packed": {
                "enabled": self.packed,
                "n_packed_ticks": self.n_packed_ticks,
                "n_masked_ticks": self.n_masked_ticks,
                "n_rows_saved": self.n_packed_rows_saved,
                "n_sealed_fallbacks": self.n_packed_fallbacks,
            },
        }
        if self.n_groups > 1:
            out["table_groups"] = {
                "n_groups": self.n_groups,
                "group_windows": list(self.group_windows),
                "group_pages_hard": list(self._group_pages_hard),
            }
        if self.tiered:
            looked = self.n_prefetch_hits + self.n_prefetch_misses
            out["tiered"] = {
                "device_pages": self.pool.device_pages,
                "n_demoted": self.pool.n_demoted,
                "n_promoted": self.pool.n_promoted,
                "n_prefetch_hits": self.n_prefetch_hits,
                "n_prefetch_misses": self.n_prefetch_misses,
                "prefetch_hit_rate": (self.n_prefetch_hits / looked
                                      if looked else 1.0),
                "n_sync_fetches": self.n_sync_fetches,
                "n_fetches_issued": self._fetch.n_issued,
                "n_sync_fallbacks": self._fetch.n_sync_fallback,
                "n_decode_reruns": self.n_decode_reruns,
            }
        if self._faults is not None:
            out["faults"] = dict(self._faults.counts)
        return out
