"""Request lifecycle: status machine, deadlines, and injectable clocks.

Until PR 7 a request either finished or silently vanished: ``Request.done``
was the only observable outcome, there was no way to cancel a running
request, no deadline could expire it, and a stalled drain returned without
a trace. This module makes the lifecycle explicit:

    QUEUED --> PREFILL --> DECODE --> DONE
      |            |          |
      |            +--<-------+        (preemption requeues: --> QUEUED)
      |            |          |
      +------------+----------+-----> CANCELLED   client cancel(rid)
                                      TIMED_OUT   deadline / stalled drain
                                      FAILED      submit reject, NaN slot
                                      SHED        load shed under pressure

Every transition goes through :func:`transition`, which validates the edge
against ``ALLOWED`` — an illegal move (resurrecting a terminal request,
skipping admission) raises :class:`LifecycleError` instead of silently
corrupting scheduler bookkeeping. Terminal statuses are sticky; the only
backward edge is preemption (PREFILL/DECODE -> QUEUED).

Deadlines are wall-clock budgets measured on the **engine's injected
clock** (``clock=`` constructor argument, default ``time.time``), so tests
drive them deterministically with :class:`ManualClock` instead of
sleeping. ``Deadline.ttft`` bounds submit -> first generated token,
``Deadline.total`` bounds submit -> terminal; either may be None
(unbounded). Expiry is checked at the top of every engine tick —
a breached request is released (all pages / snapshots freed) and marked
TIMED_OUT with the breached budget in ``Request.detail``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Optional


class LifecycleError(RuntimeError):
    """An illegal status transition (engine bookkeeping bug, or a caller
    trying to resurrect a terminal request)."""


class Status(enum.Enum):
    """Where a request is in its life. Values are the wire/stats names."""
    QUEUED = "queued"          # submitted, waiting for a slot
    PREFILL = "prefill"        # holds a slot, prompt being absorbed
    DECODE = "decode"          # in the batched decode set
    DONE = "done"              # finished normally (max_new / eos / cap)
    CANCELLED = "cancelled"    # client cancel(rid)
    TIMED_OUT = "timed_out"    # deadline breached, or drain stalled
    FAILED = "failed"          # rejected at submit, or quarantined (NaN)
    SHED = "shed"              # load-shed under sustained pool pressure

    def __str__(self) -> str:           # stats()/logs read naturally
        return self.value


#: statuses a request can never leave
TERMINAL = frozenset(
    {Status.DONE, Status.CANCELLED, Status.TIMED_OUT, Status.FAILED,
     Status.SHED})

#: legal edges; anything else raises LifecycleError. Terminal statuses
#: (FAILED etc.) are reachable from any live status: a request can be
#: rejected while queued, quarantined while decoding, shed while requeued.
_LIVE = frozenset({Status.QUEUED, Status.PREFILL, Status.DECODE})
ALLOWED = {
    Status.QUEUED: frozenset({Status.PREFILL}) | TERMINAL,
    Status.PREFILL: frozenset({Status.DECODE, Status.QUEUED}) | TERMINAL,
    Status.DECODE: frozenset({Status.QUEUED}) | TERMINAL,
    Status.DONE: frozenset(),
    Status.CANCELLED: frozenset(),
    Status.TIMED_OUT: frozenset(),
    Status.FAILED: frozenset(),
    Status.SHED: frozenset(),
}


def transition(req, to: Status, detail: str = "") -> None:
    """Move ``req`` to ``to``, validating the edge. ``detail`` explains
    terminal statuses ("ttft deadline", "non-finite logits", ...); it is
    kept on the request for stats and error reporting. ``req.done`` stays
    the legacy "finished normally" flag: True only for DONE."""
    cur = req.status
    if to not in ALLOWED[cur]:
        raise LifecycleError(
            f"illegal lifecycle transition {cur} -> {to} for request "
            f"{req.rid}" + (f" ({detail})" if detail else ""))
    req.status = to
    if detail:
        req.detail = detail
    if to is Status.DONE:
        req.done = True


def is_terminal(req) -> bool:
    return req.status in TERMINAL


def summarize(requests: Iterable) -> dict:
    """status-name -> count over a request collection (stats helper)."""
    out: dict = {}
    for r in requests:
        out[str(r.status)] = out.get(str(r.status), 0) + 1
    return out


# ------------------------------------------------------------- deadlines

@dataclasses.dataclass(frozen=True)
class Deadline:
    """Per-request wall budgets in seconds of the engine's clock.

    ttft   submit -> first generated token (queue wait + prefill). A
           request still waiting past it is hopeless for the client even
           if it would eventually run, so it times out in place.
    total  submit -> terminal. Bounds the whole request including decode.
    """
    ttft: Optional[float] = None
    total: Optional[float] = None


def breach(deadline: Optional[Deadline], now: float, t_submit: float,
           has_first_token: bool) -> Optional[str]:
    """Which budget ``now`` violates, or None. ``ttft`` stops mattering
    once the first token has been produced."""
    if deadline is None:
        return None
    waited = now - t_submit
    if deadline.total is not None and waited > deadline.total:
        return "total deadline"
    if (deadline.ttft is not None and not has_first_token
            and waited > deadline.ttft):
        return "ttft deadline"
    return None


# ---------------------------------------------------------------- clocks

class ManualClock:
    """Deterministic clock for tests: time only moves when advanced.

    Engines call their clock as a zero-arg function, so this is a drop-in
    for ``time.time`` — construct one, pass it as ``clock=``, and
    ``advance()`` it between ticks to drive deadline expiry exactly."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t
