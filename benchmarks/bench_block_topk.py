"""(Ours, DESIGN.md §3) Block- vs token-granular top-k selection fidelity.

The TPU adaptation selects top-k at 128-token *block* granularity (per-block
score maxima) instead of the paper's per-token top-k. This benchmark measures
what that costs: Jaccard overlap with exact-token top-k and attention-mass
recall (fraction of the true softmax mass covered by the selection), on real
captured (q, K) from the bench model.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.bench_jaccard import captured_qk


def mass_recall(exact, sel_mask):
    """exact (…,S) raw scores; sel_mask (…,S) bool. softmax-mass covered."""
    e = np.exp(exact - exact.max(-1, keepdims=True))
    p = e / e.sum(-1, keepdims=True)
    return float((p * sel_mask).sum(-1).mean())


def run() -> list:
    qs, ks, cfg = captured_qk()
    calib = common.calibration("synthA")
    proj = calib.projections("pre")
    l_, b, s, n_kv, dim = ks.shape
    h = qs.shape[3]
    g = h // n_kv
    q = qs[:, :, -1].reshape(l_, b, n_kv, g, dim)
    k_hat = np.einsum("lbshd,lhde->lbshe", ks, proj)
    q_hat = np.einsum("lbhgd,lhde->lbhge", q, proj)
    exact = np.einsum("lbhgd,lbshd->lbhgs", q, ks)
    d = max(int(0.25 * dim), 8)
    approx = np.einsum("lbhgd,lbshd->lbhgs", q_hat[..., :d],
                       np.ascontiguousarray(k_hat[..., :d]))
    k_f = 0.25
    k_tok = int(k_f * s)
    top_tok = np.argsort(-approx, -1)[..., :k_tok]
    tok_mask = np.zeros_like(approx, bool)
    np.put_along_axis(tok_mask, top_tok, True, -1)
    exact_top = np.argsort(-exact, -1)[..., :k_tok]

    rows = []
    for bs in (8, 16, 32):
        nb = s // bs
        blk = approx[..., : nb * bs].reshape(*approx.shape[:-1], nb, bs)
        bmax = blk.max(-1)
        kb = max(int(k_f * nb), 1)
        top_blk = np.argsort(-bmax, -1)[..., :kb]
        blk_mask = np.zeros_like(bmax, bool)
        np.put_along_axis(blk_mask, top_blk, True, -1)
        sel_mask = np.repeat(blk_mask, bs, axis=-1)
        if sel_mask.shape[-1] < s:
            sel_mask = np.concatenate(
                [sel_mask, np.zeros((*sel_mask.shape[:-1],
                                     s - sel_mask.shape[-1]), bool)], -1)
        # jaccard vs exact-token selection
        jac = []
        fe = exact_top.reshape(-1, k_tok)
        fm = sel_mask.reshape(-1, s)
        for i in range(fe.shape[0]):
            a = set(fe[i])
            b_ = set(np.nonzero(fm[i])[0])
            jac.append(len(a & b_) / len(a | b_))
        rows.append({
            "bench": "block_topk", "block_size": bs, "k_f": k_f,
            "jaccard_vs_exact": float(np.mean(jac)),
            "mass_recall_block": mass_recall(exact, sel_mask),
            "mass_recall_token": mass_recall(exact, tok_mask),
        })
    return common.emit(rows, "block_topk")


if __name__ == "__main__":
    run()
