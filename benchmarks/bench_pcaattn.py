"""Paper Appendix E (Table 5): PCAAttn ablation — the negative control.

PCAAttn computes softmax attention *directly* from truncated d-dim PCA scores
(no top-k re-ranking, K cache stored truncated). The paper shows it fails
badly (ppl 38 -> 933 vs ~5 full). We reproduce the qualitative result: Loki
at the same d_f stays near full attention while PCAAttn degrades by an order
of magnitude more.
"""
from __future__ import annotations

import math

from benchmarks import common


def run(prompt_len: int = 32, seq_len: int = 96) -> list:
    params_plain, cfg = common.trained_params()
    params_loki = common.loki_params("post")   # PCAAttn uses post-rotary (paper)
    toks = common.eval_tokens(n_seqs=8, seq_len=seq_len, seed_step=9000)
    rows = [{
        "bench": "pcaattn", "policy": "full", "d_f": 1.0,
        "ppl": math.exp(common.decode_nll(params_plain, cfg, toks,
                                          prompt_len)),
    }]
    for d_f in (0.5, 0.25, 0.125):
        loki_cfg = common.policy_cfg("loki", k_f=0.25, d_f=d_f,
                                     transform="post")
        rows.append({
            "bench": "pcaattn", "policy": "loki", "d_f": d_f,
            "ppl": math.exp(common.decode_nll(params_loki, loki_cfg, toks,
                                              prompt_len)),
        })
        pa_cfg = common.policy_cfg("pcaattn", d_f=d_f, transform="post")
        rows.append({
            "bench": "pcaattn", "policy": "pcaattn", "d_f": d_f,
            "ppl": math.exp(common.decode_nll(params_loki, pa_cfg, toks,
                                              prompt_len)),
        })
    return common.emit(rows, "pcaattn")


if __name__ == "__main__":
    run()
