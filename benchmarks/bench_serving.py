"""Serving benchmark: paged engine (page-pool cache + chunked-prefill
scheduler) vs the dense slot engine, at request counts **above** the dense
engine's ``n_slots``.

The dense engine preallocates ``n_slots × smax`` cache rows whether or not
they are used, and admits at most ``n_slots`` requests at a time; the paged
engine holds the same decode batch width but shares one page pool across
requests, admitting as soon as pages free up and absorbing long prompts in
fixed-size chunks. The benchmark drives identical request streams through
both and reports:

  * tokens/s (generated tokens over the wall-clock drain time)
  * per-request latency p50/p99 (submit -> done)
  * ticks, preemptions, and the cache footprint of each engine

The container is CPU-only, so absolute numbers are only meaningful
relative to each other; the structural effects (no truncation, queue >
n_slots drains, footprint ∝ live tokens) are platform-independent.

    PYTHONPATH=src python benchmarks/bench_serving.py --smoke
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke --family all

``--family`` sweeps one tiny config per architecture family (dense, moe,
hybrid, ssm, encdec, vlm) through the paged engine vs the dense engine —
the CacheSpec registry's coverage claim as throughput rows (per-family
``families`` section in the JSON, incl. window-recycled pages for SWA).

``--workload shared-prefix`` drives N requests over one long shared
system prompt with the prefix cache on vs off: prefix hit rate, prefill
tokens computed/saved, TTFT p50/p99 and tok/s (greedy outputs are
asserted identical — caching is exact, the win is skipped prefill):

    PYTHONPATH=src python benchmarks/bench_serving.py --smoke \\
        --workload shared-prefix

``--workload layout`` drives one identical stream through the paged
engine under each PageLayout (DESIGN.md §10) — native fp16 vs latent-rank
fp16 vs quantized int8 latent — and reports bytes/page/layer, total pool
bytes and tok/s per layout (the int8 latent layout must at least halve
the fp16 page footprint; asserted):

    PYTHONPATH=src python benchmarks/bench_serving.py --smoke \\
        --workload layout

``--workload chaos`` is the robustness acceptance run (DESIGN.md §11):
the same stream twice through the paged engine, fault-free and under a
seeded FaultPlan with the per-tick invariant auditor on. Requests that
finish DONE under faults must be bit-identical to the fault-free run,
every request must end in a correct terminal status, and the pool must
drain back to its baseline accounting — all asserted, then reported as
lifecycle/fault counters:

    PYTHONPATH=src python benchmarks/bench_serving.py --smoke \\
        --workload chaos

``--profile device`` scales the standard workload to device-sized pools
(larger smax / pool / stream) and adds an estimated decode bytes-moved
upper bound per engine row — the number to watch on a real accelerator.

Results land in ``BENCH_serving.json`` at the repo root (the shared-prefix,
layout and chaos rows merge into the existing report).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks import common  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serving import cache_spec as CS  # noqa: E402
from repro.serving import faults as FI  # noqa: E402
from repro.serving import lifecycle as LC  # noqa: E402
from repro.serving.engine import Request, ServingEngine  # noqa: E402
from repro.serving.scheduler import PagedServingEngine  # noqa: E402

# one tiny representative per family (the CacheSpec registry serves all)
FAMILY_ARCHS = {
    "dense": "qwen2.5-3b",
    "moe": "mixtral-8x22b",
    "hybrid": "hymba-1.5b",
    "ssm": "xlstm-125m",
    "encdec": "whisper-small",
    "vlm": "llava-next-mistral-7b",
}


def _frames_for(cfg, i):
    if not cfg.is_encoder_decoder:
        return None
    return np.asarray(jax.random.normal(jax.random.PRNGKey(900 + i),
                                        (cfg.enc_seq, cfg.d_model)),
                      np.float32)


def _requests(data, n, max_new, base_len=16, stride=6, vocab=512, cfg=None):
    reqs = []
    for i in range(n):
        toks = data.batch_at(4000 + i)["tokens"][0, : base_len + stride * (i % 5)]
        reqs.append(Request(rid=i, prompt=np.asarray(toks, np.int32) % vocab,
                            max_new=max_new,
                            frames=_frames_for(cfg, i) if cfg else None))
    return reqs


def _drain(eng, reqs):
    """Drive a stream through any engine via the Engine protocol (submit /
    drain / stats) — no branching on the engine kind."""
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    eng.drain(max_ticks=20_000)
    dt = time.time() - t0
    assert all(r.done for r in reqs), "engine failed to drain the queue"
    toks = sum(len(r.out) for r in reqs)
    lats = sorted(r.t_done - r.t_submit for r in reqs)
    ttfts = sorted(r.t_first - r.t_submit for r in reqs if r.t_first)
    p = lambda xs, q: xs[min(int(q * len(xs)), len(xs) - 1)]
    return {
        "requests": len(reqs),
        "generated_tokens": toks,
        "wall_s": round(dt, 3),
        "tok_per_s": round(toks / max(dt, 1e-9), 2),
        "latency_p50_s": round(p(lats, 0.50), 3),
        "latency_p99_s": round(p(lats, 0.99), 3),
        "ttft_p50_s": round(p(ttfts, 0.50), 3) if ttfts else None,
        "ttft_p99_s": round(p(ttfts, 0.99), 3) if ttfts else None,
        "ticks": eng.stats()["ticks"],
    }


def _cache_bytes(cfg, rows):
    hd = cfg.resolved_head_dim
    return 2 * cfg.n_layers * rows * cfg.n_kv_heads * hd * 4  # f32 K+V


def _decode_read_bytes(cfg, n_toks, rows_per_tok, rows_full=None):
    """Estimated decode-phase HBM reads (``--profile device``), split by
    pass. ``rows_per_tok`` is the live-page row count a decode token
    actually streams (held pages * page_size — recycled pages left the
    table); ``rows_full`` is the un-recycled smax rectangle the legacy
    estimate charged, kept as the ``full_scan_equiv`` yardstick.

    ``full``/``exact_topk`` decode now streams K/V page-by-page through
    the scalar-prefetched table, so both are charged live-page reads per
    *generated* (packed-slot) token — never ``ticks * n_slots`` rows; a
    masked tick's idle rows all read the single trash page, which stays
    HBM-resident. ``exact_topk`` splits like Loki: its exact score pass
    reads every live K row once, then only the top-k winners' V rows are
    gathered. Loki policies additionally shrink the score read to the
    leading-d latent slice of K (the resident sidecar in a tiered
    pool)."""
    widths = [w for w in CS.layer_k_widths(cfg) if w]
    per_row = cfg.n_kv_heads * 4                        # f32 per K dim
    full_scan = n_toks * (rows_full or rows_per_tok) * per_row \
        * sum(2 * w for w in widths)                    # K+V, smax rect
    live_scan = n_toks * rows_per_tok * per_row \
        * sum(2 * w for w in widths)                    # K+V, live pages
    pol = cfg.attn_policy()
    if pol == "full":
        return {"est_decode_read_bytes_ub": live_scan,
                "est_decode_read_bytes": {
                    "live_page_scan": live_scan,
                    "full_scan_equiv": full_scan}}
    k_rows = max(cfg.loki.min_k, int(cfg.loki.k_f * rows_per_tok))
    if pol == "exact_topk":
        score = n_toks * rows_per_tok * per_row * sum(widths)
        attend = n_toks * min(k_rows, rows_per_tok) * per_row \
            * sum(widths)                               # winners' V rows
        return {"est_decode_read_bytes_ub": score + attend,
                "est_decode_read_bytes": {
                    "score_pass": score,
                    "attend_pass_ub": attend,
                    "full_scan_equiv": full_scan}}
    d = CS.latent_score_width(cfg)
    score_w = sum(min(d, w) for w in widths)            # K slice only
    attend = n_toks * min(k_rows, rows_per_tok) \
        * per_row * sum(2 * w for w in widths)
    return {"est_decode_read_bytes": {
        "score_pass": n_toks * rows_per_tok * per_row * score_w,
        "attend_pass_ub": attend,
        "full_scan_equiv": full_scan,
        "score_reduction_vs_full_k":
            round(sum(widths) / max(score_w, 1), 2),
    }}


def family_sweep(families, *, n_slots, smax, page_size, chunk, max_new,
                 n_req):
    """One tiny config per family through paged + dense; per-family rows."""
    rows = {}
    for fam in families:
        arch = FAMILY_ARCHS[fam]
        cfg = get_smoke_config(arch)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        data = common.SyntheticLM(common.BENCH_DATA)

        dense = ServingEngine(params, cfg, n_slots=n_slots, smax=smax)
        r_dense = _drain(dense, _requests(data, n_req, max_new,
                                          vocab=cfg.vocab, cfg=cfg))
        paged = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                                   page_size=page_size, prefill_chunk=chunk)
        r_paged = _drain(paged, _requests(data, n_req, max_new,
                                          vocab=cfg.vocab, cfg=cfg))
        rows[fam] = {
            "arch": arch,
            "paged_tok_per_s": r_paged["tok_per_s"],
            "dense_tok_per_s": r_dense["tok_per_s"],
            "ticks": r_paged["ticks"],
            "pool_pages": paged.pool.n_pages,
            "page_budget_per_request": paged.req_budget,
            "peak_slot_pages": paged.peak_slot_pages,
            "recycled_pages": paged.n_recycled_pages,
            "recycle_window": paged.window,
            "preempted": paged.n_preempted,
        }
        print(f"[family {fam}] {arch}: paged {r_paged['tok_per_s']} tok/s "
              f"(dense {r_dense['tok_per_s']}), "
              f"budget {paged.req_budget} pages/req, "
              f"recycled {paged.n_recycled_pages}")
    return rows


def shared_prefix_workload(params, cfg, data, *, n_slots, smax, page_size,
                           chunk, max_new, n_req):
    """N requests over one long shared system prompt + short unique tails
    — the prefix-caching acceptance workload. Drives the identical stream
    through the paged engine with the cache on and off and reports the
    prefix hit rate, prefill tokens computed, TTFT p50/p99 and tok/s.
    Greedy outputs must agree token for token (exactness is asserted, not
    just measured)."""
    sys_len = max(2 * page_size + page_size // 2, smax // 2)
    sys_prompt = np.asarray(data.batch_at(7000)["tokens"][0], np.int32)
    sys_prompt = np.tile(sys_prompt, -(-sys_len // len(sys_prompt)))
    sys_prompt = sys_prompt[:sys_len]

    def reqs():
        out = []
        for i in range(n_req):
            tail = np.asarray(
                data.batch_at(7100 + i)["tokens"][0, : 4 + i % 5], np.int32)
            out.append(Request(rid=i,
                               prompt=np.concatenate([sys_prompt, tail]),
                               max_new=max_new))
        return out

    rows = {}
    outs = {}
    for mode in ("off", "on"):
        eng = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                                 page_size=page_size, prefill_chunk=chunk,
                                 prefix_cache=mode == "on")
        rs = reqs()
        row = _drain(eng, rs)
        row["prefill_tokens_computed"] = eng.n_prefill_computed_tokens
        row["prefix_hit_tokens"] = eng.n_prefix_hit_tokens
        row["prefix_hit_rate"] = round(eng.prefix_hit_rate(), 3)
        row["cow_copies"] = eng.n_cow_copies
        row["evicted_pages"] = eng.pool.n_evicted
        rows[f"cache_{mode}"] = row
        outs[mode] = [r.out for r in rs]
    assert outs["on"] == outs["off"], \
        "prefix caching changed greedy outputs"
    on, off = rows["cache_on"], rows["cache_off"]
    assert on["prefix_hit_tokens"] > 0, "shared prefix never hit the cache"
    assert on["prefill_tokens_computed"] < off["prefill_tokens_computed"]
    rows["prefill_tokens_saved"] = (off["prefill_tokens_computed"]
                                    - on["prefill_tokens_computed"])
    print(f"[shared-prefix] hit rate {on['prefix_hit_rate']}, "
          f"prefill {on['prefill_tokens_computed']} vs "
          f"{off['prefill_tokens_computed']} tokens, "
          f"ttft p50 {on['ttft_p50_s']}s vs {off['ttft_p50_s']}s")
    return rows


def layout_workload(data, *, n_slots, smax, page_size, chunk, max_new,
                    n_req, specs=None):
    """One identical stream per PageLayout through the paged engine.

    The model is the PCA-calibrated bench LM under loki_block — the policy
    whose decode kernels read latent keys straight off the pages. Rows:
    bytes/page/layer (K+V rows at the layout's storage width and dtype),
    total pool bytes (pages × layers, plus the f32 scale sidecars for
    quantized layouts) and tok/s. The int8 latent layout must cut
    bytes/page at least 2× vs fp16 — asserted, not just reported."""
    params, base = common.trained_params()
    params = common.loki_params()          # pca-basis layouts need the
    base = common.policy_cfg(              # projections in the params
        "loki_block", k_f=0.5, d_f=0.5, block_size=8, local_window=4,
        min_k=4)
    hd = base.resolved_head_dim
    specs = specs or ["fp16", f"fp16:pca:r={hd // 2}",
                      f"int8:pca:r={hd // 2}"]
    rows = {}
    for spec in specs:
        cfg = base.with_layout(spec)
        lay = cfg.page_layout
        eng = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                                 page_size=page_size, prefill_chunk=chunk)
        # warm drain: compile the chunked-prefill + decode programs for
        # this layout so tok/s compares steady-state pages, not XLA
        _drain(eng, _requests(data, 1, 2, vocab=cfg.vocab))
        row = _drain(eng, _requests(data, n_req, max_new, vocab=cfg.vocab))
        bpp = lay.bytes_per_page_row(hd, cfg.n_kv_heads) * page_size
        pool_bytes = bpp * cfg.n_layers * eng.pool.n_pages
        if lay.quantized:                  # (n_pages,) f32 K + V scales
            pool_bytes += 2 * 4 * cfg.n_layers * eng.pool.n_pages
        row.update({
            "layout": lay.describe(),
            "bytes_per_page_layer": bpp,
            "pool_bytes": pool_bytes,
            "pool_pages": eng.pool.n_pages,
        })
        rows[lay.describe()] = row
        print(f"[layout {lay.describe()}] {bpp} B/page/layer, "
              f"{row['tok_per_s']} tok/s, {row['ticks']} ticks")
    fp16 = next((r for k, r in rows.items() if k.startswith("fp16:native")),
                None)
    int8 = next((r for k, r in rows.items() if k.startswith("int8")), None)
    if fp16 and int8:
        ratio = fp16["bytes_per_page_layer"] / int8["bytes_per_page_layer"]
        rows["int8_page_reduction_vs_fp16"] = round(ratio, 2)
        assert ratio >= 2.0, \
            f"int8 latent pages only {ratio:.2f}x smaller than fp16"
    return rows


DEFAULT_CHAOS = ("seed=3,nan_logits=0.04,alloc_fail=0.05,"
                 "pool_exhaustion=0.03,kernel_fail=0.02")


def donation_workload(params, cfg, data, *, n_slots, smax, page_size,
                      chunk, max_new, n_req):
    """Buffer donation A/B: the identical stream through the paged engine
    with ``donate_argnums`` disabled vs enabled on every cache-updating
    jitted program (decode_step / prefill_chunk / copy_cache_page).
    Donation lets XLA update the cache in place instead of materialising
    a second copy — on CPU it is a silent no-op, so the two rows bounding
    each other is itself the assertion; on a device the 'after' row is
    the one to watch alongside the halved peak cache footprint."""
    rows = {}
    for key, don in (("donate_off", False), ("donate_on", True)):
        eng = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                                 page_size=page_size, prefill_chunk=chunk,
                                 donate=don)
        rows[key] = _drain(eng, _requests(data, n_req, max_new,
                                          vocab=cfg.vocab))
    rows["steady_state_tok_per_s"] = {
        "before": rows["donate_off"]["tok_per_s"],
        "after": rows["donate_on"]["tok_per_s"],
    }
    print(f"[donation] tok/s before={rows['donate_off']['tok_per_s']} "
          f"after={rows['donate_on']['tok_per_s']}")
    return rows


def tiered_workload(data, *, n_slots, smax, page_size, chunk, max_new,
                    n_req):
    """Tiered KV pool acceptance (DESIGN.md §13): the identical stream
    through the single-tier paged engine and through a tiered pool whose
    device tier holds at most **half** the single-tier pages (full-D K/V
    pages spill to pinned host buffers; the rank-d latent sidecar stays
    resident and keeps the Loki score pass exact). Greedy outputs must
    agree token for token — asserted, not measured. Reports demotion /
    promotion traffic, the Loki-guided fetch queue's prefetch hit rate,
    steady tok/s at the shrunken device pool, and the per-token score
    bytes served from the resident tier vs a full-D score scan (~D/d)."""
    params, _ = common.trained_params()
    cfg = common.policy_cfg("loki_block", k_f=0.5, d_f=0.5, block_size=8,
                            local_window=4, min_k=4)

    single = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                                page_size=page_size, prefill_chunk=chunk)
    _drain(single, _requests(data, 1, 2, vocab=cfg.vocab))        # warm
    base = _requests(data, n_req, max_new, vocab=cfg.vocab)
    r_single = _drain(single, base)

    total = single.pool.n_pages
    # half the single-tier pool, floored at the ctor's one-full-request
    # bound (prefill reads the whole prefix exactly, so one request must
    # always fit on device)
    device_pages = max(total // 2, single._req_pages_hard + 1)
    tiered = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                                page_size=page_size, prefill_chunk=chunk,
                                device_pages=device_pages, audit=True)
    _drain(tiered, _requests(data, 1, 2, vocab=cfg.vocab))        # warm
    rs = _requests(data, n_req, max_new, vocab=cfg.vocab)
    r_tiered = _drain(tiered, rs)

    assert [r.out for r in rs] == [r.out for r in base], \
        "tiered pool changed greedy outputs"
    st = tiered.stats()["tiered"]
    assert st["n_demoted"] > 0, \
        "half-sized device pool never demoted a page"
    assert st["prefetch_hit_rate"] > 0, \
        "Loki-guided prefetch never hit"

    widths = [w for w in CS.layer_k_widths(cfg) if w]
    d = CS.latent_score_width(cfg)
    score_w = sum(min(d, w) for w in widths)
    rows_scanned = tiered.peak_slot_pages * page_size
    per_tok = cfg.n_kv_heads * 4                        # f32 per K dim
    rows = {
        "single_tier_tok_per_s": r_single["tok_per_s"],
        "tiered_tok_per_s": r_tiered["tok_per_s"],
        "device_pages": device_pages,
        "total_pages": total,
        "resident_score_bytes_per_token": rows_scanned * per_tok * score_w,
        "full_d_score_bytes_per_token":
            rows_scanned * per_tok * sum(widths),
        "score_byte_reduction": round(sum(widths) / max(score_w, 1), 2),
        "prefetch_hit_rate": round(st["prefetch_hit_rate"], 3),
        "n_demoted": st["n_demoted"],
        "n_promoted": st["n_promoted"],
        "n_sync_fetches": st["n_sync_fetches"],
        "n_decode_reruns": st["n_decode_reruns"],
        "preempted": tiered.n_preempted,
        "outputs_bit_identical": True,
        "ticks": r_tiered["ticks"],
    }
    print(f"[tiered] {device_pages}/{total} device pages: "
          f"{r_tiered['tok_per_s']} tok/s (single-tier "
          f"{r_single['tok_per_s']}), hit rate "
          f"{st['prefetch_hit_rate']}, score bytes "
          f"{rows['score_byte_reduction']}x down, bit-identical")
    return rows


def packed_workload(data, *, n_slots, smax, page_size, chunk, max_new):
    """Gather-packed decode acceptance (DESIGN.md §14): the identical
    exact_topk stream at **25% occupancy** (n_slots//4 concurrent
    requests in an n_slots-wide engine) through the masked full-width
    engine (``packed=False``) and the gather-packed one. Greedy outputs
    must agree token for token — asserted, not measured. Reports tok/s
    for both (packed decode runs a power-of-two bucket of live rows per
    tick instead of all n_slots), plus the exact-policy decode read-bytes
    estimate before (legacy smax * batch rectangle) and after (live-page
    rows per generated token)."""
    params, _ = common.trained_params()
    cfg = common.policy_cfg("exact_topk")
    occ = max(n_slots // 4, 1)

    rows = {}
    engines = {}
    for mode, packed in (("masked", False), ("packed", True)):
        eng = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                                 page_size=page_size, prefill_chunk=chunk,
                                 packed=packed)
        # warm-up with the identical stream shape: the timed run must
        # visit only buckets (live-count powers of two) the warm-up
        # already compiled, or the compile lands inside the clock
        _drain(eng, _requests(data, occ, max_new, vocab=cfg.vocab))
        reqs = _requests(data, occ, max_new, vocab=cfg.vocab)
        r = _drain(eng, reqs)
        st = eng.stats()["packed"]
        rows[mode] = {
            "tok_per_s": r["tok_per_s"],
            "generated_tokens": r["generated_tokens"],
            "ticks": r["ticks"],
            "n_packed_ticks": st["n_packed_ticks"],
            "n_masked_ticks": st["n_masked_ticks"],
            "rows_saved": st["n_rows_saved"],
        }
        engines[mode] = eng
        rows[mode + "_out"] = [list(map(int, q.out)) for q in reqs]

    assert rows["masked_out"] == rows["packed_out"], \
        "gather-packed decode changed greedy outputs"
    out_m, out_p = rows.pop("masked_out"), rows.pop("packed_out")
    eng = engines["packed"]
    est = _decode_read_bytes(cfg, rows["packed"]["generated_tokens"],
                             eng.peak_slot_pages * page_size,
                             rows_full=smax)
    rows["decode_read_bytes_before"] = \
        est["est_decode_read_bytes"]["full_scan_equiv"]
    rows["decode_read_bytes_after"] = est["est_decode_read_bytes_ub"]
    rows["decode_read_bytes_reduction"] = round(
        rows["decode_read_bytes_before"]
        / max(rows["decode_read_bytes_after"], 1), 2)
    rows["occupancy"] = round(occ / n_slots, 3)
    rows["outputs_bit_identical"] = True
    rows["speedup_packed_vs_masked"] = round(
        rows["packed"]["tok_per_s"]
        / max(rows["masked"]["tok_per_s"], 1e-9), 3)
    print(f"[packed] {occ}/{n_slots} slots live: packed "
          f"{rows['packed']['tok_per_s']} tok/s vs masked "
          f"{rows['masked']['tok_per_s']} "
          f"({rows['speedup_packed_vs_masked']}x), exact-policy decode "
          f"bytes {rows['decode_read_bytes_reduction']}x down, "
          "bit-identical")
    return rows


def chaos_workload(params, cfg, data, *, n_slots, smax, page_size, chunk,
                   max_new, n_req, spec=""):
    """Robustness acceptance: one stream, fault-free then under a seeded
    FaultPlan with the invariant auditor on every tick. Asserts the §11
    acceptance bar — DONE outputs bit-identical to the fault-free run,
    every request in a legal terminal status, pool accounting back to
    baseline after drain — and reports the lifecycle/fault counters."""
    def stream():
        return _requests(data, n_req, max_new, vocab=cfg.vocab)

    def pool_at_baseline(eng):
        # after a full drain nothing may hold a reference: every page is
        # either free or an unreferenced cached (LRU) page
        free = len(eng.pool.free_page_ids())
        lru = len(eng.pool.lru_page_ids())
        return free + lru == eng.pool.n_pages - 1

    base_eng = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                                  page_size=page_size, prefill_chunk=chunk,
                                  audit=True)
    base = stream()
    r_base = _drain(base_eng, base)
    assert pool_at_baseline(base_eng), "fault-free run leaked pages"
    truth = {r.rid: r.out for r in base}

    spec = spec or DEFAULT_CHAOS
    plan = FI.FaultPlan.parse(spec)
    eng = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                             page_size=page_size, prefill_chunk=chunk,
                             faults=plan, audit=True, shed_after=8)
    rs = stream()
    for r in rs:
        eng.submit(r)
    t0 = time.time()
    eng.drain(max_ticks=20_000)
    dt = time.time() - t0

    not_terminal = [r.rid for r in rs if not LC.is_terminal(r)]
    assert not not_terminal, f"requests left live: {not_terminal}"
    mismatch = [r.rid for r in rs if r.done and r.out != truth[r.rid]]
    assert not mismatch, \
        f"DONE outputs diverged from the fault-free run: {mismatch}"
    assert pool_at_baseline(eng), \
        "chaos drain did not return the pool to baseline accounting"

    st = eng.stats()
    done = sum(r.done for r in rs)
    rows = {
        "fault_spec": plan.describe(),
        "requests": n_req,
        "wall_s": round(dt, 3),
        "ticks": st["ticks"],
        "lifecycle": LC.summarize(rs),
        "faults_injected": dict(plan.counts),
        "n_preempted": eng.n_preempted,
        "n_quarantined": eng.n_quarantined,
        "n_shed": eng.n_shed,
        "n_backend_fallbacks": eng.n_backend_fallbacks,
        "done_bit_identical": done,
        "fault_free_tok_per_s": r_base["tok_per_s"],
        "auditor": "green",       # every tick audited, none raised
    }
    print(f"[chaos] {plan.describe()}: {LC.summarize(rs)}, "
          f"faults {dict(plan.counts)}, auditor green, "
          f"{done} DONE bit-identical")
    return rows


def _write_merged(path, update):
    """Update the report in place: each invocation owns its sections
    (standard / families / shared_prefix) and must not erase the others'."""
    report = {}
    if os.path.exists(path):
        with open(path) as f:
            report = json.load(f)
    report.update(update)
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--n-slots", type=int, default=0)
    ap.add_argument("--smax", type=int, default=0)
    ap.add_argument("--page-size", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=0)
    ap.add_argument("--max-new", type=int, default=0)
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--family", default="",
                    help="comma list of families (or 'all') to sweep one "
                         "tiny config each through paged vs dense: "
                         + ",".join(FAMILY_ARCHS))
    ap.add_argument("--workload", default="standard",
                    choices=["standard", "shared-prefix", "layout",
                             "chaos", "donation", "tiered", "packed"],
                    help="shared-prefix: N requests over one long system "
                         "prompt, prefix cache on vs off (hit rate, TTFT, "
                         "tok/s). layout: the same stream under each "
                         "--layouts PageLayout (bytes/page, tok/s). chaos: "
                         "the same stream fault-free vs under a seeded "
                         "FaultPlan with the invariant auditor on "
                         "(DESIGN.md §11 acceptance). tiered: the same "
                         "stream single-tier vs a half-sized device pool "
                         "with host offload + Loki-guided prefetch "
                         "(DESIGN.md §13 acceptance). packed: the same "
                         "exact_topk stream at 25%% occupancy, masked "
                         "full-width vs gather-packed decode (DESIGN.md "
                         "§14 acceptance). All merge into the existing "
                         "JSON report")
    ap.add_argument("--faults", default="",
                    help="FaultPlan spec for --workload chaos "
                         f"(default: {DEFAULT_CHAOS})")
    ap.add_argument("--profile", default="",
                    choices=["", "device"],
                    help="device: device-sized pool (smax=512, 32-token "
                         "pages, longer stream) + estimated decode "
                         "bytes-moved per row — explicit size flags still "
                         "override")
    ap.add_argument("--layouts", default="",
                    help="comma list of PageLayout specs for --workload "
                         "layout (default: fp16, fp16:pca:r=D/2, "
                         "int8:pca:r=D/2)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    if args.profile == "device":
        n_slots = args.n_slots or 4
        smax = args.smax or 512
        page_size = args.page_size or 32
        chunk = args.prefill_chunk or 64
        max_new = args.max_new or 32
        n_req = args.requests or 4 * n_slots
    elif args.smoke:
        n_slots = args.n_slots or 2
        smax = args.smax or 64
        page_size = args.page_size or 16
        chunk = args.prefill_chunk or 8
        max_new = args.max_new or 6
        n_req = args.requests or 3 * n_slots
    else:
        n_slots = args.n_slots or 4
        smax = args.smax or 128
        page_size = args.page_size or 16
        chunk = args.prefill_chunk or 16
        max_new = args.max_new or 16
        n_req = args.requests or 4 * n_slots

    params, cfg = common.trained_params()
    data = common.SyntheticLM(common.BENCH_DATA)

    if args.workload == "layout":
        specs = ([s.strip() for s in args.layouts.split(",") if s.strip()]
                 or None)
        rows = layout_workload(
            data, n_slots=n_slots, smax=smax, page_size=page_size,
            chunk=chunk, max_new=max_new, n_req=n_req, specs=specs)
        _write_merged(args.out, {"layouts": rows})
        print(json.dumps({"layouts": rows}, indent=2))
        print(f"\nwrote {args.out}")
        return

    if args.workload == "shared-prefix":
        rows = shared_prefix_workload(
            params, cfg, data, n_slots=n_slots, smax=smax,
            page_size=page_size, chunk=chunk, max_new=max_new, n_req=n_req)
        _write_merged(args.out, {"shared_prefix": rows})
        print(json.dumps({"shared_prefix": rows}, indent=2))
        print(f"\nwrote {args.out}")
        return

    if args.workload == "donation":
        rows = donation_workload(
            params, cfg, data, n_slots=n_slots, smax=smax,
            page_size=page_size, chunk=chunk, max_new=max_new,
            n_req=n_req)
        _write_merged(args.out, {"donation": rows})
        print(json.dumps({"donation": rows}, indent=2))
        print(f"\nwrote {args.out}")
        return

    if args.workload == "tiered":
        rows = tiered_workload(
            data, n_slots=n_slots, smax=smax, page_size=page_size,
            chunk=chunk, max_new=max_new, n_req=n_req)
        _write_merged(args.out, {"tiered": rows})
        print(json.dumps({"tiered": rows}, indent=2))
        print(f"\nwrote {args.out}")
        return

    if args.workload == "packed":
        rows = packed_workload(
            data, n_slots=n_slots, smax=smax, page_size=page_size,
            chunk=chunk, max_new=max_new)
        _write_merged(args.out, {"packed": rows})
        print(json.dumps({"packed": rows}, indent=2))
        print(f"\nwrote {args.out}")
        return

    if args.workload == "chaos":
        rows = chaos_workload(
            params, cfg, data, n_slots=n_slots, smax=smax,
            page_size=page_size, chunk=chunk, max_new=max_new,
            n_req=n_req, spec=args.faults)
        _write_merged(args.out, {"chaos": rows})
        print(json.dumps({"chaos": rows}, indent=2))
        print(f"\nwrote {args.out}")
        return

    dense = ServingEngine(params, cfg, n_slots=n_slots, smax=smax)
    r_dense = _drain(dense, _requests(data, n_req, max_new))
    r_dense["cache_bytes"] = _cache_bytes(cfg, n_slots * smax)

    paged = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                               page_size=page_size, prefill_chunk=chunk)
    r_paged = _drain(paged, _requests(data, n_req, max_new))
    r_paged["cache_bytes"] = _cache_bytes(cfg, paged.pool.n_pages * page_size)
    r_paged["preempted"] = paged.n_preempted
    r_paged["peak_pages"] = paged.pool.n_pages - 1
    if args.profile == "device":
        # decode-phase HBM reads per engine row, split by pass for Loki
        # policies (the score scan touches only the latent K slice; only
        # the top-k winners are read at full width) — the numbers to
        # compare against kernel counters on real hardware
        for row, eng_ in ((r_dense, None), (r_paged, paged)):
            rows_per_tok = (smax if eng_ is None
                            else eng_.peak_slot_pages * page_size)
            row.update(_decode_read_bytes(
                cfg, row["generated_tokens"], rows_per_tok,
                rows_full=smax))

    # tight pool: the structural win — the same stream served from half the
    # pages (but always >= one full request), via continuous recycling
    tight_pages = 1 + max(paged.max_pages,
                          (n_slots * paged.max_pages) // 2)
    tight = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                               page_size=page_size, prefill_chunk=chunk,
                               n_pages=tight_pages)
    r_tight = _drain(tight, _requests(data, n_req, max_new))
    r_tight["cache_bytes"] = _cache_bytes(cfg, tight_pages * page_size)
    r_tight["preempted"] = tight.n_preempted
    r_tight["peak_pages"] = tight_pages - 1

    update = {
        "config": {"n_slots": n_slots, "smax": smax,
                   "page_size": page_size, "prefill_chunk": chunk,
                   "max_new": max_new, "requests": n_req,
                   "backend": jax.default_backend()},
        "dense": r_dense,
        "paged": r_paged,
        "paged_tight_pool": r_tight,
    }
    if args.family:
        fams = (list(FAMILY_ARCHS) if args.family == "all"
                else [f.strip() for f in args.family.split(",")])
        unknown = [f for f in fams if f not in FAMILY_ARCHS]
        if unknown:
            raise SystemExit(f"unknown families {unknown}; "
                             f"have {list(FAMILY_ARCHS)}")
        update["families"] = family_sweep(
            fams, n_slots=n_slots, smax=smax, page_size=page_size,
            chunk=chunk, max_new=max_new, n_req=n_req)
    print(json.dumps(_write_merged(args.out, update), indent=2))
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
