"""Paper Eq. (5): theoretical speedup vs measured compiled-FLOP ratio.

speedup = 2DS / (dS + 2Dk + 2D^2) ~= 1 / (d_f/2 + k_f)    (D << S)

We lower vanilla decode attention and Loki decode attention with XLA and
compare the actual HLO FLOP counts; the ratio should track Eq. 5 (FLOPs, not
bytes, is what the formula models).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.configs.base import LokiConfig
from repro.core.attention import decode_full
from repro.core.loki import loki_decode


def hlo_flops(fn, *args) -> float:
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, list):
        c = c[0]
    return float(c.get("flops", 0.0))


def run() -> list:
    rows = []
    b, h, dim, s = 1, 8, 128, 8192
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, dim), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, dim), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, dim), jnp.float32)
    proj = jnp.broadcast_to(jnp.eye(dim), (h, dim, dim))
    cur = jnp.full((b,), s, jnp.int32)

    f_full = hlo_flops(lambda q, k, v, c: decode_full(q, k, v, c),
                       q, k, v, cur)
    for k_f, d_f in [(0.25, 0.25), (0.125, 0.5), (0.125, 0.25),
                     (0.5, 0.5)]:
        cfg = LokiConfig(d_f=d_f, k_f=k_f, local_window=0, min_k=1)
        f_loki = hlo_flops(
            lambda q, k, v, c, p: loki_decode(q, k, v, c, p, cfg),
            q, k, v, cur, proj)
        d = max(int(d_f * dim), 8)
        kk = max(int(k_f * s), 1)
        exact = 2.0 * dim * s / (d * s + 2 * dim * kk + 2 * dim * dim)
        approx = 1.0 / (d_f / 2 + k_f)
        rows.append({
            "bench": "theory", "k_f": k_f, "d_f": d_f,
            "hlo_flops_full": f_full, "hlo_flops_loki": f_loki,
            "measured_flop_ratio": f_full / f_loki,
            "eq5_exact": exact, "eq5_approx": approx,
            # loki also pays the q-projection (2D^2 per head) + topk, so the
            # measured ratio should be <= eq5_exact but the same order
            "within_2x_of_eq5": bool(
                0.5 < (f_full / f_loki) / exact < 2.0),
        })
    return common.emit(rows, "theory")


if __name__ == "__main__":
    run()
