"""Paper Figure 6 (left): top-k agreement between Loki and exact top-k.

For every layer/head, captures real post-rotary (q, K) from the bench model,
computes exact-score top-k and approximate (d-dim PCA) top-k index sets, and
reports their Jaccard similarity across the (k_f, d_f) grid. The paper finds
~0.9 at (0.25, 0.25) for Llama2-7B.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.models import lm


def captured_qk():
    """(qs (L,B,S,H,D) post-rope, ks (L,B,S,Hkv,D) post-rope)."""
    params, cfg = common.trained_params()
    toks = jnp.asarray(common.eval_tokens(4, 96, seed_step=7000))
    _, _, (pre, post, qs) = lm.forward(params, toks, cfg, capture_keys=True)
    return np.asarray(qs), np.asarray(post), cfg


def jaccard_grid(qs, ks, proj, k_f: float, d_f: float) -> float:
    """Mean Jaccard over layers/heads/batch for the last-token query."""
    l_, b, s, h, dim = qs.shape
    n_kv = ks.shape[3]
    g = h // n_kv
    d = max(int(d_f * dim), 8)
    k = max(int(k_f * s), 1)
    q = qs[:, :, -1]                                    # (L,B,H,D)
    qg = q.reshape(l_, b, n_kv, g, dim)
    # exact scores in the original basis
    exact = np.einsum("lbhgd,lbshd->lbhgs", qg, ks)     # (L,B,Hkv,G,S)
    # approx scores in the PCA basis, truncated to d dims
    q_hat = np.einsum("lbhgd,lhde->lbhge", qg, proj)
    k_hat = np.einsum("lbshd,lhde->lbshe", ks, proj)
    approx = np.einsum("lbhgd,lbshd->lbhgs", q_hat[..., :d],
                       np.ascontiguousarray(k_hat[..., :d]))
    top_e = np.argsort(-exact, axis=-1)[..., :k]
    top_a = np.argsort(-approx, axis=-1)[..., :k]
    jac = []
    flat_e = top_e.reshape(-1, k)
    flat_a = top_a.reshape(-1, k)
    for i in range(flat_e.shape[0]):
        a, b_ = set(flat_e[i]), set(flat_a[i])
        jac.append(len(a & b_) / len(a | b_))
    return float(np.mean(jac))


def run() -> list:
    qs, ks, cfg = captured_qk()
    calib = common.calibration("synthA")
    proj = calib.projections("pre")                     # (L,Hkv,D,D)
    rows = []
    for k_f in (0.125, 0.25, 0.5):
        for d_f in (0.125, 0.25, 0.5, 1.0):
            j = jaccard_grid(qs, ks, proj, k_f, d_f)
            rows.append({"bench": "jaccard", "k_f": k_f, "d_f": d_f,
                         "jaccard": j})
    # paper's headline cell ~0.9; sanity floor checks monotonicity in d_f
    return common.emit(rows, "jaccard")


if __name__ == "__main__":
    run()
