"""Paper Figures 1/2 + Appendix A: Rank_l@90 dimensionality analysis.

Performs PCA on the bench model's captured keys (pre- and post-rotary) and
reports the per-layer rank at which 90% of variance is explained. The paper's
claims validated here:
  (1) rank << full head dimension,
  (2) rank is consistent across calibration datasets,
  (3) rotary embeddings raise key dimensionality (rank_post >= rank_pre,
      on average).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run() -> list:
    rows = []
    _, cfg = common.trained_params()
    d_full = cfg.resolved_head_dim
    per_ds = {}
    for ds in common.CALIB_DATASETS:
        calib = common.calibration(ds)
        r_pre = calib.rank_at(0.90, "pre")     # (L, Hkv)
        r_post = calib.rank_at(0.90, "post")
        per_ds[ds] = (r_pre.mean(1), r_post.mean(1))
        for layer in range(cfg.n_layers):
            rows.append({
                "bench": "rank_analysis", "dataset": ds, "layer": layer,
                "rank90_pre": float(r_pre[layer].mean()),
                "rank90_post": float(r_post[layer].mean()),
                "full_dim": d_full,
            })
    # claim checks
    pre_means = np.stack([v[0] for v in per_ds.values()])   # (DS, L)
    post_means = np.stack([v[1] for v in per_ds.values()])
    rows.append({
        "bench": "rank_analysis", "dataset": "ALL", "layer": -1,
        "rank90_pre": float(pre_means.mean()),
        "rank90_post": float(post_means.mean()),
        "full_dim": d_full,
        "low_rank_claim": bool(post_means.mean() < 0.9 * d_full),
        "cross_dataset_spread": float(
            np.abs(post_means - post_means.mean(0)).max()),
        "rope_raises_rank": bool(post_means.mean() >= pre_means.mean()),
    })
    return common.emit(rows, "rank_analysis")


if __name__ == "__main__":
    run()
