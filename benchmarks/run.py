"""Benchmark harness: one suite per paper table/figure (see DESIGN.md §7).

Usage:
    PYTHONPATH=src python -m benchmarks.run            # all suites
    PYTHONPATH=src python -m benchmarks.run rank jaccard

Prints CSV-ish rows and persists JSON under experiments/bench/.
"""
from __future__ import annotations

import sys
import time

SUITES = {
    "rank": ("benchmarks.bench_rank_analysis", "Fig 1/2 + App A: Rank@90"),
    "perplexity": ("benchmarks.bench_perplexity", "Table 2: ppl by policy"),
    "downstream": ("benchmarks.bench_downstream", "Fig 5/Tables 3-4: acc"),
    "jaccard": ("benchmarks.bench_jaccard", "Fig 6 left: top-k agreement"),
    "generalization": ("benchmarks.bench_generalization",
                       "Fig 6 mid: calib datasets"),
    "attention_time": ("benchmarks.bench_attention_time",
                       "Fig 6 right/Fig 7: attn time + bytes"),
    "kernels": ("benchmarks.bench_kernels", "App C: kernel sweep + bytes"),
    "pcaattn": ("benchmarks.bench_pcaattn", "App E/Table 5: PCAAttn"),
    "block_topk": ("benchmarks.bench_block_topk",
                   "ours: block vs token select"),
    "chunked": ("benchmarks.bench_chunked",
                "ours: chunk-local vs global selection"),
    "theory": ("benchmarks.bench_theory", "Eq 5: speedup vs HLO FLOPs"),
}


def main() -> None:
    import importlib
    names = sys.argv[1:] or list(SUITES)
    t_all = time.time()
    failures = []
    for name in names:
        mod_name, desc = SUITES[name]
        print(f"\n=== {name}: {desc} ===")
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            mod.run()
            print(f"--- {name} done in {time.time() - t0:.1f}s")
        except Exception as e:  # keep the sweep going; report at the end
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    print(f"\ntotal {time.time() - t_all:.1f}s")
    if failures:
        print(f"{len(failures)} suite failures: {failures}")
        sys.exit(1)
    print("all benchmark suites OK")


if __name__ == "__main__":
    main()
