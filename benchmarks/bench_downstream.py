"""Paper Figure 5 / Tables 3-4: downstream task performance across policies
and (k_f, d_f) settings.

Offline proxy: greedy next-token accuracy on held-out structured synthetic
data, through the decode path. The paper's trends validated:
  * accuracy degrades as k_f/d_f shrink,
  * k_f hurts more than d_f (k=0.125,d=0.5 < k=0.5,d=0.125),
  * loki >= h2o at matched budgets.
"""
from __future__ import annotations

from benchmarks import common

GRID = [(0.5, 0.5), (0.5, 0.125), (0.25, 0.25), (0.125, 0.5), (0.125, 0.125)]


def run(prompt_len: int = 32, seq_len: int = 96) -> list:
    params_plain, cfg = common.trained_params()
    params_loki = common.loki_params("pre")
    toks = common.eval_tokens(n_seqs=8, seq_len=seq_len, seed_step=6000)
    rows = [{
        "bench": "downstream", "policy": "full", "k_f": 1.0, "d_f": 1.0,
        "acc": common.decode_accuracy(params_plain, cfg, toks, prompt_len),
    }]
    for k_f, d_f in GRID:
        pcfg = common.policy_cfg("loki", k_f=k_f, d_f=d_f)
        rows.append({
            "bench": "downstream", "policy": "loki", "k_f": k_f, "d_f": d_f,
            "acc": common.decode_accuracy(params_loki, pcfg, toks,
                                          prompt_len),
        })
    for k_f in (0.25,):
        for policy in ("exact_topk", "h2o"):
            pcfg = common.policy_cfg(policy, k_f=k_f)
            rows.append({
                "bench": "downstream", "policy": policy, "k_f": k_f,
                "d_f": 1.0,
                "acc": common.decode_accuracy(params_plain, pcfg, toks,
                                              prompt_len),
            })
    return common.emit(rows, "downstream")


if __name__ == "__main__":
    run()
