"""Shared benchmark substrate.

All paper-table benchmarks run against the same artifact: a small LM of the
paper's family (Llama-2-like dense GQA) *briefly trained* on the structured
synthetic corpus so that its attention concentrates mass (the property Loki's
top-k selection exploits), plus PCA calibrations from several synthetic
"datasets" (different generator seeds/temperatures stand in for
WikiText-103 / C4 / BookCorpus in this offline container).

The trained model + calibrations are cached under experiments/bench_cache so
the full ``python -m benchmarks.run`` sweep is fast after the first build.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import pca as PCA
from repro.data.synthetic import DataConfig, SyntheticLM, jax_batch
from repro.models import lm
from repro.optim import adamw
from repro.training.step import TrainState, make_train_step

ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "experiments"))
BENCH_DIR = os.path.join(ROOT, "bench")
CACHE_DIR = os.path.join(ROOT, "bench_cache")

# the bench model: paper-family (dense, GQA-capable, RoPE, SwiGLU).
BENCH_CFG = ModelConfig(
    arch="bench-llama", family="dense", n_layers=4, d_model=256,
    n_heads=4, n_kv_heads=4, d_ff=512, vocab=512, mlp="swiglu",
    dtype="float32")

BENCH_DATA = DataConfig(vocab=512, seq_len=128, global_batch=8, seed=7,
                        n_states=32, temperature=0.22)

# stand-ins for the paper's calibration corpora (§6.3 generalizability)
CALIB_DATASETS: Dict[str, DataConfig] = {
    "synthA": BENCH_DATA,
    "synthB": DataConfig(vocab=512, seq_len=128, global_batch=8, seed=1234,
                         n_states=48, temperature=0.3),
    "synthC": DataConfig(vocab=512, seq_len=128, global_batch=8, seed=99,
                         n_states=24, temperature=0.2),
}

TRAIN_STEPS = 200


# --------------------------------------------------------------- caching

def _params_path() -> str:
    return os.path.join(CACHE_DIR, "bench_model.npz")


def _calib_path(name: str) -> str:
    return os.path.join(CACHE_DIR, f"calib_{name}.npz")


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten_like(tree, flat, prefix=""):
    if isinstance(tree, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}{k}/")
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_unflatten_like(v, flat, f"{prefix}{i}/")
                          for i, v in enumerate(tree))
    return jnp.asarray(flat[prefix[:-1]])


def trained_params(force: bool = False):
    """Train (or load) the bench model; returns (params, cfg)."""
    cfg = BENCH_CFG
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = _params_path()
    template = lm.init(jax.random.PRNGKey(0), cfg)
    if os.path.exists(path) and not force:
        flat = dict(np.load(path))
        return _unflatten_like(template, flat), cfg

    data = SyntheticLM(BENCH_DATA)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=10, total_steps=TRAIN_STEPS)
    state = TrainState(template, adamw.init_state(template))
    step = jax.jit(make_train_step(cfg, tcfg))
    t0 = time.time()
    for i in range(TRAIN_STEPS):
        state, m = step(state, jax_batch(data.batch_at(i)))
    print(f"[common] trained bench model {TRAIN_STEPS} steps in "
          f"{time.time() - t0:.0f}s final loss={float(m['loss']):.3f}")
    np.savez(path, **_flatten(state.params))
    return state.params, cfg


def calibration(dataset: str = "synthA", n_batches: int = 4,
                force: bool = False) -> PCA.PCACalibration:
    """PCA calibration of the bench model's keys on a synthetic corpus."""
    path = _calib_path(dataset)
    if os.path.exists(path) and not force:
        return PCA.PCACalibration.load(path)
    params, cfg = trained_params()
    data = SyntheticLM(CALIB_DATASETS[dataset])
    batches = [jnp.asarray(data.batch_at(1000 + i)["tokens"])
               for i in range(n_batches)]
    calib = PCA.calibrate_model(params, cfg, batches)
    os.makedirs(CACHE_DIR, exist_ok=True)
    calib.save(path)
    return calib


def loki_params(transform: str = "pre", dataset: str = "synthA"):
    params, cfg = trained_params()
    return PCA.install_projections(params, calibration(dataset), transform)


# ------------------------------------------------------- decode-path eval

def decode_nll(params, cfg: ModelConfig, tokens: np.ndarray,
               prompt_len: int, smax: Optional[int] = None) -> float:
    """Teacher-forced NLL through the *decode path* (prefill + per-token
    decode_step), so every policy's actual serving code is what's scored."""
    b, s = tokens.shape
    smax = smax or s + 8
    toks = jnp.asarray(tokens)
    lg, cache, pos = lm.prefill(params, cfg, toks[:, :prompt_len], smax,
                                cache_dtype=jnp.float32)

    @jax.jit
    def step(cache, tok, pos):
        return lm.decode_step(params, cfg, cache, tok, pos)

    rows = jnp.arange(b)
    nll, n = 0.0, 0
    logits = lg
    for t in range(prompt_len, s):
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll += float(-lp[rows, toks[:, t]].mean())
        n += 1
        logits, cache = step(cache, toks[:, t], pos)
        pos = pos + 1
    return nll / n


def decode_accuracy(params, cfg: ModelConfig, tokens: np.ndarray,
                    prompt_len: int) -> float:
    """Greedy next-token accuracy through the decode path (the downstream
    'task accuracy' proxy — top-1 agreement with the corpus)."""
    b, s = tokens.shape
    toks = jnp.asarray(tokens)
    lg, cache, pos = lm.prefill(params, cfg, toks[:, :prompt_len], s + 8,
                                cache_dtype=jnp.float32)

    @jax.jit
    def step(cache, tok, pos):
        return lm.decode_step(params, cfg, cache, tok, pos)

    hits, n = 0, 0
    logits = lg
    for t in range(prompt_len, s):
        hits += int((jnp.argmax(logits, -1) == toks[:, t]).sum())
        n += b
        logits, cache = step(cache, toks[:, t], pos)
        pos = pos + 1
    return hits / n


def eval_tokens(n_seqs: int = 8, seq_len: int = 96,
                seed_step: int = 5000) -> np.ndarray:
    data = SyntheticLM(BENCH_DATA)
    rows = []
    step = seed_step
    while sum(r.shape[0] for r in rows) < n_seqs:
        rows.append(data.batch_at(step)["tokens"][:, :seq_len])
        step += 1
    return np.concatenate(rows, axis=0)[:n_seqs]


def policy_cfg(policy: str, k_f: float = 0.25, d_f: float = 0.25,
               transform: str = "pre", **kw) -> ModelConfig:
    cfg = BENCH_CFG
    if policy == "full":
        return cfg
    return cfg.with_policy(policy, k_f=k_f, d_f=d_f, transform=transform,
                           **kw)


# ------------------------------------------------------------ timing/io

def time_fn(fn: Callable[[], None], *, repeats: int = 10,
            warmup: int = 2) -> float:
    """Median wall-seconds of fn() (fn must block_until_ready itself)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(rows: List[Dict], name: str) -> List[Dict]:
    """Print CSV rows and persist them under experiments/bench/."""
    os.makedirs(BENCH_DIR, exist_ok=True)
    with open(os.path.join(BENCH_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
    for r in rows:
        print(",".join(f"{k}={v:.6g}" if isinstance(v, float)
                       else f"{k}={v}" for k, v in r.items()))
    return rows
