"""Paper Table 2: perplexity of full / exact-top-k / H2O / Loki.

Scored through the decode path (prefill + per-token decode_step) so each
policy's real serving code is what's measured. Expected ordering (the paper's
quality claim): full <= exact-topk ~= loki < h2o, with loki within a small
delta of full.
"""
from __future__ import annotations

import math

from benchmarks import common


POLICIES = [
    ("full", {}),
    ("exact_topk", dict(k_f=0.25)),
    ("h2o", dict(k_f=0.25)),
    ("loki", dict(k_f=0.25, d_f=0.25)),
    ("loki", dict(k_f=0.125, d_f=0.5)),
    ("loki_block", dict(k_f=0.25, d_f=0.25, block_size=8)),
]


def run(prompt_len: int = 32, seq_len: int = 96) -> list:
    params_plain, cfg = common.trained_params()
    params_loki = common.loki_params("pre")
    toks = common.eval_tokens(n_seqs=8, seq_len=seq_len)
    rows = []
    for policy, kw in POLICIES:
        pcfg = common.policy_cfg(policy, **kw)
        params = params_loki if policy.startswith("loki") else params_plain
        nll = common.decode_nll(params, pcfg, toks, prompt_len)
        rows.append({
            "bench": "perplexity", "policy": policy,
            "k_f": kw.get("k_f", 1.0), "d_f": kw.get("d_f", 1.0),
            "nll": nll, "ppl": math.exp(nll),
        })
    base = rows[0]["ppl"]
    for r in rows:
        r["ppl_delta_vs_full"] = r["ppl"] - base
    return common.emit(rows, "perplexity")


if __name__ == "__main__":
    run()
