"""Paper Figure 6 (middle) + §6.3: calibration-dataset generalizability.

Loki's PCA transforms are calibrated on three different synthetic corpora
(different Markov generators standing in for WikiText-103 / C4 / BookCorpus)
and evaluated on the same held-out stream. The paper's claim: performance is
consistent across calibration datasets.
"""
from __future__ import annotations

import math

from benchmarks import common


def run(prompt_len: int = 32, seq_len: int = 96) -> list:
    params_plain, cfg = common.trained_params()
    toks = common.eval_tokens(n_seqs=8, seq_len=seq_len, seed_step=8000)
    rows = [{
        "bench": "generalization", "calib": "none(full)",
        "ppl": math.exp(common.decode_nll(params_plain, cfg, toks,
                                          prompt_len)),
    }]
    pcfg = common.policy_cfg("loki", k_f=0.25, d_f=0.25)
    ppls = []
    for ds in common.CALIB_DATASETS:
        params = common.loki_params("pre", ds)
        ppl = math.exp(common.decode_nll(params, pcfg, toks, prompt_len))
        ppls.append(ppl)
        rows.append({"bench": "generalization", "calib": ds, "ppl": ppl})
    rows.append({
        "bench": "generalization", "calib": "SPREAD",
        "ppl": max(ppls) - min(ppls),
    })
    return common.emit(rows, "generalization")


if __name__ == "__main__":
    run()
