"""(Ours, DESIGN.md §4) Chunk-local vs global Loki selection fidelity.

The distributed adaptation splits the KV cache into n_chunks sequence shards
and takes top-(k/n) per chunk, keeping every gather device-local. This
benchmark measures what that costs in selection quality on real captured
(q, K): overlap with global top-k, attention-mass recall, and the decode-NLL
delta through the model.
"""
from __future__ import annotations

import math

import numpy as np

from benchmarks import common
from benchmarks.bench_block_topk import mass_recall
from benchmarks.bench_jaccard import captured_qk


def run() -> list:
    qs, ks, cfg = captured_qk()
    calib = common.calibration("synthA")
    proj = calib.projections("pre")
    l_, b, s, n_kv, dim = ks.shape
    h = qs.shape[3]
    g = h // n_kv
    q = qs[:, :, -1].reshape(l_, b, n_kv, g, dim)
    k_hat = np.einsum("lbshd,lhde->lbshe", ks, proj)
    q_hat = np.einsum("lbhgd,lhde->lbhge", q, proj)
    exact = np.einsum("lbhgd,lbshd->lbhgs", q, ks)
    d = max(int(0.25 * dim), 8)
    approx = np.einsum("lbhgd,lbshd->lbhgs", q_hat[..., :d],
                       np.ascontiguousarray(k_hat[..., :d]))
    k_f = 0.25
    k_tot = int(k_f * s)

    glob = np.argsort(-approx, -1)[..., :k_tot]
    gmask = np.zeros_like(approx, bool)
    np.put_along_axis(gmask, glob, True, -1)

    rows = []
    params_loki = common.loki_params("pre")
    toks = common.eval_tokens(n_seqs=8, seq_len=96, seed_step=12000)
    nll_global = common.decode_nll(
        params_loki, common.policy_cfg("loki", k_f=0.25, d_f=0.25), toks, 32)
    rows.append({"bench": "chunked", "n_chunks": 0,
                 "overlap_with_global": 1.0,
                 "mass_recall": mass_recall(exact, gmask),
                 "decode_nll": nll_global})
    for nc in (2, 4, 8):
        if s % nc:
            continue
        sc = s // nc
        kpc = max(k_tot // nc, 1)
        ch = approx.reshape(*approx.shape[:-1], nc, sc)
        idx = np.argsort(-ch, -1)[..., :kpc]
        cmask = np.zeros_like(ch, bool)
        np.put_along_axis(cmask, idx, True, -1)
        cmask = cmask.reshape(*approx.shape[:-1], nc * sc)
        overlap = float((cmask & gmask).sum() / max(gmask.sum(), 1))
        pcfg = common.policy_cfg("loki", k_f=0.25, d_f=0.25, n_chunks=nc)
        nll = common.decode_nll(params_loki, pcfg, toks, 32)
        rows.append({"bench": "chunked", "n_chunks": nc,
                     "overlap_with_global": overlap,
                     "mass_recall": mass_recall(exact, cmask),
                     "decode_nll": nll})
    return common.emit(rows, "chunked")


if __name__ == "__main__":
    run()
