"""Paper Figure 6 (right) + Figure 7: attention computation time.

Measures the *attention step only* (the paper's microbenchmark: no KV-append
cost — our slot cache has none by construction) for vanilla full decode
attention vs Loki, across cache lengths. Wall-clock here is CPU-XLA, so we
report it alongside the hardware-independent quantities that determine TPU
time: bytes touched in the KV cache and matmul FLOPs. Loki's win in the
paper (up to 45%) is driven by the byte reduction, which we reproduce
exactly: loki reads d/D of K̂ for scoring + k/S of (K̂,V) for attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs.base import LokiConfig
from repro.core.attention import decode_full
from repro.core.loki import loki_decode


def _setup(b, h, s, dim, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, h, dim), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, dim), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, dim), jnp.float32)
    proj = jnp.broadcast_to(jnp.eye(dim), (h, dim, dim))
    return q, k, v, proj


def derived_bytes(s, dim, d, k, *, itemsize=2):
    """KV-cache bytes touched per head-row (TPU bf16)."""
    vanilla = 2 * s * dim * itemsize                 # read K + V fully
    loki = (s * d + 2 * k * dim) * itemsize          # d-slice + gathered K,V
    return vanilla, loki


def run() -> list:
    rows = []
    b, h, dim = 4, 8, 64
    for s in (1024, 2048, 4096):
        q, k, v, proj = _setup(b, h, s, dim)
        cur = jnp.full((b,), s, jnp.int32)
        cfg = LokiConfig(d_f=0.25, k_f=0.25, local_window=0, min_k=1)
        d = max(int(cfg.d_f * dim), 8)
        kk = max(int(cfg.k_f * s), 1)

        f_full = jax.jit(lambda q, k, v, c: decode_full(q, k, v, c))
        f_loki = jax.jit(
            lambda q, k, v, c, p: loki_decode(q, k, v, c, p, cfg))
        t_full = common.time_fn(
            lambda: f_full(q, k, v, cur).block_until_ready())
        t_loki = common.time_fn(
            lambda: f_loki(q, k, v, cur, proj).block_until_ready())
        vb, lb = derived_bytes(s, dim, d, kk)
        theory = 1.0 / (cfg.d_f / 2 + cfg.k_f)
        rows.append({
            "bench": "attention_time", "S": s, "B": b, "H": h, "D": dim,
            "t_full_ms": 1e3 * t_full, "t_loki_ms": 1e3 * t_loki,
            "cpu_speedup": t_full / t_loki,
            "bytes_full": vb * b * h, "bytes_loki": lb * b * h,
            "byte_reduction": vb / lb,
            "theory_speedup_eq5": theory,
        })
    return common.emit(rows, "attention_time")


if __name__ == "__main__":
    run()
