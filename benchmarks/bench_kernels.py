"""Paper Appendix C: kernel benchmark (ours vs SparQ-style vs dense).

The container is CPU-only, so Pallas kernels run in interpret mode — their
*correctness* is asserted against the pure-jnp oracle across a shape sweep,
and the performance comparison is made on the hardware-determining quantity:
HBM bytes each kernel design must move per decode step.

Designs modeled:
  dense      — full-D, full-S reads of K̂ and V (vanilla attention)
  sparq      — scattered column gather of r key dims: on TPU a strided
               column read pulls whole (8,128) VMEM tiles, so the score pass
               still moves ~full-D bytes; plus SparQ stores K twice (+50%
               cache footprint, paper §2.1)
  loki(ours) — contiguous leading-d slice (PCA ordering) => exactly d/D of
               the score-pass bytes, single K̂ copy; block-gathered exact
               pass moves k/S of K̂,V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels.ops import loki_decode_attention
from repro.kernels import ref


def correctness_sweep() -> list:
    rows = []
    for (bh, s, dim, bs) in [(4, 256, 64, 64), (2, 512, 128, 128),
                             (8, 256, 128, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(s + bh), 3)
        q = jax.random.normal(ks[0], (bh, dim), jnp.float32)
        k = jax.random.normal(ks[1], (bh, s, dim), jnp.float32)
        v = jax.random.normal(ks[2], (bh, s, dim), jnp.float32)
        cur = jnp.full((bh,), s, jnp.int32)
        d, k_blocks = dim // 4, max((s // bs) // 4, 1)
        got = loki_decode_attention(q, k, v, cur, d=d, k_blocks=k_blocks,
                                    block_size=bs, interpret=True)
        scale = dim ** -0.5
        blk = ref.block_max_scores_ref(q, k, cur, d=d, block_size=bs,
                                       scale=scale)
        _, bidx = jax.lax.top_k(blk, k_blocks)
        want = ref.block_sparse_attention_ref(q, k, v, bidx, cur,
                                              block_size=bs, scale=scale)
        err = float(jnp.abs(got - want).max())
        rows.append({"bench": "kernels", "case": f"bh{bh}_s{s}_d{dim}_bs{bs}",
                     "max_abs_err_vs_oracle": err, "pass": err < 1e-4})
    return rows


def bytes_model(s=4096, dim=128, d_f=0.25, k_f=0.25, itemsize=2) -> list:
    d = int(d_f * dim)
    k = int(k_f * s)
    dense = 2 * s * dim * itemsize
    # sparq: scattered r-column gather reads full tiles on TPU (column-major
    # slices of a (S,D) row-major cache touch every D-lane tile) + 2x K store
    sparq_score = s * dim * itemsize          # full-D tile traffic
    sparq_attn = 2 * k * dim * itemsize
    sparq = sparq_score + sparq_attn
    loki_score = s * d * itemsize             # contiguous leading-d slice
    loki_attn = 2 * k * dim * itemsize
    loki = loki_score + loki_attn
    return [{
        "bench": "kernels", "case": f"bytes_S{s}_D{dim}",
        "dense_bytes": dense, "sparq_bytes": sparq, "loki_bytes": loki,
        "loki_vs_dense": dense / loki, "loki_vs_sparq": sparq / loki,
        "sparq_extra_cache_copy": 1.5,
    }]


def vmem_tile_efficiency(dim=128, d=32, lane=128, sublane=8) -> list:
    """DESIGN.md §3.1: fraction of each staged VMEM tile that carries real
    data. Token-major (S, d) blocks pad the d columns to the 128-lane tile
    width; feature-major (d, S) blocks are lane-dense and only round d up to
    the 8-row sublane granule."""
    tm = d / lane                                   # lanes used / lane width
    fm = d / (-(-d // sublane) * sublane)           # sublane rounding only
    return [{
        "bench": "kernels", "case": f"vmem_tiles_d{d}",
        "token_major_tile_util": tm, "feature_major_tile_util": fm,
        "fm_advantage": fm / tm,
    }]


def run() -> list:
    rows = (correctness_sweep() + bytes_model() + bytes_model(s=32768)
            + vmem_tile_efficiency(d=16) + vmem_tile_efficiency(d=32))
    return common.emit(rows, "kernels")


if __name__ == "__main__":
    run()
