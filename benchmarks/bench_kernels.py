"""Paper Appendix C: kernel benchmark (ours vs SparQ-style vs dense), plus
the fused-decode comparison (fused vs two-pass vs jnp).

The container is CPU-only, so Pallas kernels run in interpret mode — their
*correctness* is asserted against the pure-jnp oracle across a shape sweep,
and the performance comparison is made on the hardware-determining quantity:
HBM bytes each kernel design must move per decode step. Wall-clock rows are
also emitted (flagged ``interpret`` when the kernel ran in the Python
interpreter — meaningful only relative to other interpret rows).

Designs modeled:
  dense      — full-D, full-S reads of K̂ and V (vanilla attention)
  sparq      — scattered column gather of r key dims: on TPU a strided
               column read pulls whole (8,128) VMEM tiles, so the score pass
               still moves ~full-D bytes; plus SparQ stores K twice (+50%
               cache footprint, paper §2.1)
  loki(ours) — contiguous leading-d slice (PCA ordering) => exactly d/D of
               the score-pass bytes, single K̂ copy; block-gathered exact
               pass moves k/S of K̂,V.

Fused-decode designs (DESIGN.md §4, ``--backend pallas``):
  jnp        — XLA reference: approx scores + block maxima materialize in
               HBM, per-head top_k + gather
  two_pass   — seed kernel pair, per query head: block-max kernel writes
               (BH, S/bs) maxima to HBM, host top_k, sparse-attention kernel
  two_kernel — GQA-batched fallback: fused score+select (scores stay in
               VMEM, only (B,Hkv,kb) indices cross HBM) + grouped attention
  fused      — single-pass kernel: nothing intermediate touches HBM, every
               cache byte read once per *group*

Results are written to ``BENCH_kernels.json`` at the repo root (the perf
trajectory future PRs regress against) and to experiments/bench/.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):                     # `python benchmarks/...py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (_root, os.path.join(_root, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs.base import LokiConfig
from repro.core.loki import loki_decode_block
from repro.kernels.ops import (loki_decode_attention, loki_decode_fused,
                               loki_decode_two_kernel)
from repro.kernels import ref

ROOT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kernels.json")


def correctness_sweep() -> list:
    rows = []
    for (bh, s, dim, bs) in [(4, 256, 64, 64), (2, 512, 128, 128),
                             (8, 256, 128, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(s + bh), 3)
        q = jax.random.normal(ks[0], (bh, dim), jnp.float32)
        k = jax.random.normal(ks[1], (bh, s, dim), jnp.float32)
        v = jax.random.normal(ks[2], (bh, s, dim), jnp.float32)
        cur = jnp.full((bh,), s, jnp.int32)
        d, k_blocks = dim // 4, max((s // bs) // 4, 1)
        got = loki_decode_attention(q, k, v, cur, d=d, k_blocks=k_blocks,
                                    block_size=bs, interpret=True)
        scale = dim ** -0.5
        blk = ref.block_max_scores_ref(q, k, cur, d=d, block_size=bs,
                                       scale=scale)
        _, bidx = jax.lax.top_k(blk, k_blocks)
        want = ref.block_sparse_attention_ref(q, k, v, bidx, cur,
                                              block_size=bs, scale=scale)
        err = float(jnp.abs(got - want).max())
        rows.append({"bench": "kernels", "case": f"bh{bh}_s{s}_d{dim}_bs{bs}",
                     "max_abs_err_vs_oracle": err, "pass": err < 1e-4})
    return rows


def bytes_model(s=4096, dim=128, d_f=0.25, k_f=0.25, itemsize=2) -> list:
    d = int(d_f * dim)
    k = int(k_f * s)
    dense = 2 * s * dim * itemsize
    # sparq: scattered r-column gather reads full tiles on TPU (column-major
    # slices of a (S,D) row-major cache touch every D-lane tile) + 2x K store
    sparq_score = s * dim * itemsize          # full-D tile traffic
    sparq_attn = 2 * k * dim * itemsize
    sparq = sparq_score + sparq_attn
    loki_score = s * d * itemsize             # contiguous leading-d slice
    loki_attn = 2 * k * dim * itemsize
    loki = loki_score + loki_attn
    return [{
        "bench": "kernels", "case": f"bytes_S{s}_D{dim}",
        "dense_bytes": dense, "sparq_bytes": sparq, "loki_bytes": loki,
        "loki_vs_dense": dense / loki, "loki_vs_sparq": sparq / loki,
        "sparq_extra_cache_copy": 1.5,
    }]


def vmem_tile_efficiency(dim=128, d=32, lane=128, sublane=8) -> list:
    """DESIGN.md §3.1: fraction of each staged VMEM tile that carries real
    data. Token-major (S, d) blocks pad the d columns to the 128-lane tile
    width; feature-major (d, S) blocks are lane-dense and only round d up to
    the 8-row sublane granule."""
    tm = d / lane                                   # lanes used / lane width
    fm = d / (-(-d // sublane) * sublane)           # sublane rounding only
    return [{
        "bench": "kernels", "case": f"vmem_tiles_d{d}",
        "token_major_tile_util": tm, "feature_major_tile_util": fm,
        "fm_advantage": fm / tm,
    }]


# ---------------------------------------------- fused decode comparison

def fused_bytes_model(s, dim, g, bs, d_f=0.25, k_f=0.25, itemsize=2) -> dict:
    """HBM bytes one decode step must move per KV group, by design."""
    d = max(int(d_f * dim), 8)
    nb = s // bs
    kb = max(int(k_f * nb), 1)
    score_read = s * d * itemsize                 # leading-d slice of K̂
    attn_read = 2 * kb * bs * dim * itemsize      # selected K̂ + V blocks
    q_bytes = g * dim * itemsize
    idx_bytes = kb * 4
    blkmax_bytes = nb * 4
    # jnp/XLA: full fp32 score row + block maxima round-trip HBM, per head
    jnp_bytes = (g * (score_read + attn_read + 2 * q_bytes)
                 + g * (s * 4 + blkmax_bytes) * 2 + g * idx_bytes * 2)
    # seed two-pass kernels: per query head; block maxima + indices via HBM
    two_pass = (g * (score_read + attn_read + 2 * q_bytes)
                + g * blkmax_bytes * 2 + g * idx_bytes * 2)
    # grouped two-kernel fallback: one score stream per group; only the tiny
    # index row crosses HBM between the kernels
    two_kernel = score_read + attn_read + 2 * q_bytes + idx_bytes * 2
    # fused single-pass: cache bytes once per group, nothing intermediate
    fused = score_read + attn_read + q_bytes
    return {"jnp_bytes": jnp_bytes, "two_pass_bytes": two_pass,
            "two_kernel_bytes": two_kernel, "fused_bytes": fused,
            "fused_vs_two_pass": two_pass / fused,
            "fused_vs_jnp": jnp_bytes / fused}


def fused_decode_sweep(backend: str = "pallas") -> list:
    """fused vs two-pass vs jnp: parity, tokens/s and bytes-moved."""
    rows = []
    shapes = [                                  # (b, hkv, g, s, dim, bs)
        (2, 2, 1, 1024, 64, 128),
        (2, 2, 4, 1024, 64, 128),
        (1, 2, 8, 2048, 128, 128),
    ]
    interpret = jax.default_backend() != "tpu"
    for b, hkv, g, s, dim, bs in shapes:
        ks = jax.random.split(jax.random.PRNGKey(s + g), 3)
        q = jax.random.normal(ks[0], (b, hkv * g, dim), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, hkv, dim), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, hkv, dim), jnp.float32)
        cur = jnp.full((b,), s, jnp.int32)
        proj = jnp.broadcast_to(jnp.eye(dim), (hkv, dim, dim))
        cfg = LokiConfig(enabled=True, d_f=0.25, k_f=0.25, block_size=bs,
                         local_window=0)
        d = max(int(cfg.d_f * dim), 8)
        kb = max(int(cfg.k_f * (s // bs)), 1)
        q_hat = q.reshape(b, hkv, g, dim)

        # jit over real arguments: a nullary closure would constant-fold
        # the whole computation and time only dispatch overhead
        oracle = jax.jit(lambda q_, k_, v_, c_: loki_decode_block(
            q_, k_, v_, c_, proj, cfg, group_select=True))
        want = np.asarray(oracle(q, k, v, cur)).reshape(b, hkv, g, dim)
        t_jnp = common.time_fn(
            lambda: jax.block_until_ready(oracle(q, k, v, cur)), repeats=5)
        row = {"bench": "kernels",
               "case": f"fused_b{b}_h{hkv}g{g}_s{s}_d{dim}_bs{bs}",
               "backend": backend, "interpret": interpret,
               "jnp_tok_s": b / t_jnp,
               **fused_bytes_model(s, dim, g, bs, itemsize=2)}
        if backend == "pallas":
            kw = dict(d=d, k_blocks=kb, block_size=bs, interpret=interpret)
            fused = loki_decode_fused(q_hat, k, v, cur, **kw)
            two = loki_decode_two_kernel(q_hat, k, v, cur, **kw)
            row["fused_max_err"] = float(
                jnp.abs(fused - want).max())
            row["two_kernel_max_err"] = float(jnp.abs(two - want).max())
            row["pass"] = (row["fused_max_err"] < 1e-4
                           and row["two_kernel_max_err"] < 1e-4)
            row["fused_tok_s"] = b / common.time_fn(
                lambda: jax.block_until_ready(
                    loki_decode_fused(q_hat, k, v, cur, **kw)),
                repeats=3, warmup=1)
            row["two_kernel_tok_s"] = b / common.time_fn(
                lambda: jax.block_until_ready(
                    loki_decode_two_kernel(q_hat, k, v, cur, **kw)),
                repeats=3, warmup=1)
        rows.append(row)
    return rows


def run(backend: str = "pallas") -> list:
    rows = (correctness_sweep() + bytes_model() + bytes_model(s=32768)
            + vmem_tile_efficiency(d=16) + vmem_tile_efficiency(d=32)
            + fused_decode_sweep(backend))
    if backend == "pallas":
        # the regression baseline carries kernel measurements; don't let a
        # bytes-model-only xla run clobber the last measured artifact
        with open(ROOT_JSON, "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print(f"[bench_kernels] wrote {ROOT_JSON}")
    return common.emit(rows, "kernels")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=["pallas", "xla"], default="pallas",
                    help="pallas: run + time the fused kernels "
                         "(interpret mode off-TPU); xla: bytes model only")
    out_rows = run(ap.parse_args().backend)
    # gate CI on kernel-vs-oracle parity, not just on having produced rows
    if not all(r.get("pass", True) for r in out_rows):
        sys.exit("[bench_kernels] parity FAILED (see pass=False rows)")
