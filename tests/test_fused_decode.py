"""Fused GQA-batched decode kernel: parity vs the jnp oracle, dispatch
routing, and the two-kernel fallback — all in interpret mode so CI runs on
CPU (on TPU the identical pallas_calls compile through Mosaic)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LokiConfig
from repro.core import dispatch
from repro.core.loki import loki_decode_block
from repro.kernels import tuning
from repro.kernels.fused_decode import fused_loki_decode, select_blocks
from repro.kernels.gather_attention import block_sparse_attention_grouped
from repro.kernels.ops import loki_decode_two_kernel


def _setup(b, hkv, g, s, dim, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, hkv * g, dim), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dim), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dim), dtype)
    return q, k, v


def _orthogonal(hkv, dim, seed=0):
    rng = np.random.RandomState(seed)
    mats = [np.linalg.qr(rng.randn(dim, dim))[0] for _ in range(hkv)]
    return jnp.asarray(np.stack(mats), jnp.float32)


def _grouped_q(q, proj, hkv):
    b, h, dim = q.shape
    qg = q.reshape(b, hkv, h // hkv, dim)
    return jnp.einsum("bhgd,hde->bhge", qg, proj.astype(q.dtype))


def _oracle(q, k_hat, v, cur, proj, cfg):
    want = loki_decode_block(q, k_hat, v, cur, proj, cfg, group_select=True)
    b, h, dim = q.shape
    hkv = proj.shape[0]
    return want.reshape(b, hkv, h // hkv, dim)


# ------------------------------------------------------------ fused kernel

@pytest.mark.parametrize("g", [1, 4, 8])
@pytest.mark.parametrize("b,hkv,s,dim,bs", [
    (2, 2, 256, 64, 32),
    (1, 2, 512, 128, 128),
    (3, 1, 384, 64, 64),          # non-pow2 batch, single kv head
])
def test_fused_matches_grouped_oracle(b, hkv, g, s, dim, bs):
    q, k, v = _setup(b, hkv, g, s, dim, seed=g + s)
    proj = _orthogonal(hkv, dim, seed=g)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jax.random.randint(jax.random.PRNGKey(7), (b,), 1, s + 1)
    cfg = LokiConfig(enabled=True, d_f=0.25, k_f=0.25, block_size=bs,
                     local_window=0)
    want = _oracle(q, k_hat, v, cur, proj, cfg)
    nb = s // bs
    got = fused_loki_decode(
        _grouped_q(q, proj, hkv), k_hat, v, cur,
        d=max(int(cfg.d_f * dim), 8), k_blocks=max(int(cfg.k_f * nb), 1),
        block_size=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_equals_per_head_oracle_when_g1():
    """At G=1, group-shared selection IS per-head selection: the fused
    kernel must match the unmodified loki_decode_block."""
    b, hkv, s, dim, bs = 2, 3, 256, 64, 32
    q, k, v = _setup(b, hkv, 1, s, dim, seed=11)
    proj = _orthogonal(hkv, dim, seed=3)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s, s // 3])
    cfg = LokiConfig(enabled=True, d_f=0.5, k_f=0.25, block_size=bs,
                     local_window=0)
    want = loki_decode_block(q, k_hat, v, cur, proj, cfg)
    got = fused_loki_decode(
        _grouped_q(q, proj, hkv), k_hat, v, cur,
        d=max(int(cfg.d_f * dim), 8),
        k_blocks=max(int(cfg.k_f * (s // bs)), 1),
        block_size=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(got).reshape(b, hkv, dim),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("cur_lens", [(1, 1), (1, 300), (17, 33)])
def test_fused_all_masked_blocks(cur_lens):
    """cur_len smaller than one block: most selected blocks are fully dead
    and must contribute exactly nothing (and never NaN)."""
    b, hkv, g, s, dim, bs = 2, 2, 4, 512, 64, 64
    q, k, v = _setup(b, hkv, g, s, dim, seed=5)
    proj = _orthogonal(hkv, dim, seed=5)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array(cur_lens, jnp.int32)
    cfg = LokiConfig(enabled=True, d_f=0.25, k_f=0.5, block_size=bs,
                     local_window=0)
    want = _oracle(q, k_hat, v, cur, proj, cfg)
    got = fused_loki_decode(
        _grouped_q(q, proj, hkv), k_hat, v, cur,
        d=16, k_blocks=max(int(0.5 * (s // bs)), 1),
        block_size=bs, interpret=True)
    assert bool(jnp.isfinite(got).all())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_selection_exhausted_no_double_count():
    """Fewer live blocks than k_blocks (2 live, k_blocks=4): exhausted
    selection rounds must contribute nothing — not re-select block 0 and
    double-count it in the online softmax (regression)."""
    b, hkv, g, s, dim, bs = 2, 2, 4, 512, 64, 64
    q, k, v = _setup(b, hkv, g, s, dim, seed=13)
    proj = _orthogonal(hkv, dim, seed=13)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([100, 90], jnp.int32)       # 2 of 8 blocks live
    cfg = LokiConfig(enabled=True, d_f=0.25, k_f=0.5, block_size=bs,
                     local_window=0)
    kb = max(int(cfg.k_f * (s // bs)), 1)
    assert kb == 4
    want = _oracle(q, k_hat, v, cur, proj, cfg)
    q_hat = _grouped_q(q, proj, hkv)
    kw = dict(d=16, k_blocks=kb, block_size=bs, interpret=True)
    fused = fused_loki_decode(q_hat, k_hat, v, cur, **kw)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    two = loki_decode_two_kernel(q_hat, k_hat, v, cur, **kw)
    np.testing.assert_allclose(np.asarray(two), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # and the selection marks the exhausted tail with -1
    sel = select_blocks(q_hat, k_hat, cur, d=16, k_blocks=kb,
                        block_size=bs, interpret=True)
    assert int((np.asarray(sel) == -1).sum()) == b * hkv * 2


def test_fused_bf16_inputs():
    b, hkv, g, s, dim, bs = 1, 2, 4, 256, 64, 64
    q, k, v = _setup(b, hkv, g, s, dim, seed=9, dtype=jnp.bfloat16)
    proj = _orthogonal(hkv, dim, seed=9)
    k_hat = jnp.einsum("bshd,hde->bshe", k.astype(jnp.float32),
                       proj).astype(jnp.bfloat16)
    cur = jnp.array([s], jnp.int32)
    cfg = LokiConfig(enabled=True, d_f=0.25, k_f=0.5, block_size=bs,
                     local_window=0)
    want = _oracle(q, k_hat, v, cur, proj, cfg)
    got = fused_loki_decode(
        _grouped_q(q, proj.astype(jnp.bfloat16), hkv), k_hat, v, cur,
        d=16, k_blocks=max(int(0.5 * (s // bs)), 1),
        block_size=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


# -------------------------------------------------- two-kernel fallback

@pytest.mark.parametrize("g", [1, 4])
def test_two_pass_matches_fused(g):
    b, hkv, s, dim, bs = 2, 2, 384, 64, 32
    q, k, v = _setup(b, hkv, g, s, dim, seed=21)
    proj = _orthogonal(hkv, dim, seed=2)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s, s // 2])
    q_hat = _grouped_q(q, proj, hkv)
    kw = dict(d=16, k_blocks=3, block_size=bs, interpret=True)
    fused = fused_loki_decode(q_hat, k_hat, v, cur, **kw)
    two = loki_decode_two_kernel(q_hat, k_hat, v, cur, **kw)
    np.testing.assert_allclose(np.asarray(two), np.asarray(fused),
                               rtol=2e-5, atol=2e-5)


def test_select_blocks_matches_topk():
    """The in-kernel argmax-and-suppress selection equals lax.top_k over the
    jnp group block maxima (including tie/order semantics)."""
    b, hkv, g, s, dim, bs = 2, 2, 4, 512, 64, 64
    q, k, v = _setup(b, hkv, g, s, dim, seed=31)
    cur = jnp.array([s, 200])
    proj = jnp.broadcast_to(jnp.eye(dim), (hkv, dim, dim))
    d, kb = 16, 3
    q_hat = _grouped_q(q, proj, hkv)
    got = select_blocks(q_hat, k, cur, d=d, k_blocks=kb, block_size=bs,
                        interpret=True)
    # jnp reference selection
    scale = dim ** -0.5
    approx = jnp.einsum("bhgd,bshd->bhgs", q_hat[..., :d] * scale,
                        k[..., :d], preferred_element_type=jnp.float32)
    approx = jnp.where(jnp.arange(s)[None, None, None] < cur[:, None, None,
                                                             None],
                       approx, -1e30)
    blk = approx.reshape(b, hkv, g, s // bs, bs).max(-1).max(2)
    _, want = jax.lax.top_k(blk, kb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_grouped_gather_matches_per_head_kernel():
    """block_sparse_attention_grouped == per-head block_sparse_attention run
    row by row with the shared selection."""
    from repro.kernels.gather_attention import block_sparse_attention
    b, hkv, g, s, dim, bs = 1, 2, 2, 256, 64, 32
    q, k, v = _setup(b, hkv, g, s, dim, seed=41)
    proj = jnp.broadcast_to(jnp.eye(dim), (hkv, dim, dim))
    q_hat = _grouped_q(q, proj, hkv)
    cur = jnp.array([s - 40])
    nb = s // bs
    blk_idx = jnp.stack([jnp.array([0, 3, 5]), jnp.array([1, 2, 7])])[None]
    got = block_sparse_attention_grouped(q_hat, k, v, blk_idx, cur,
                                         block_size=bs, interpret=True)
    for h in range(hkv):
        for gi in range(g):
            row = block_sparse_attention(
                q_hat[:, h, gi], jnp.swapaxes(k, 1, 2)[:, h],
                jnp.swapaxes(v, 1, 2)[:, h], blk_idx[:, h], cur,
                block_size=bs, interpret=True)
            np.testing.assert_allclose(np.asarray(got[:, h, gi]),
                                       np.asarray(row), rtol=2e-5,
                                       atol=2e-5)


# ------------------------------------------------- window semantics

@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("local_window,sliding_window", [
    (16, 0), (0, 96), (16, 96)])
def test_window_parity_across_backends(g, local_window, sliding_window):
    """Regression: the block paths used to silently ignore
    cfg.local_window and sliding_window that the token path honors. All
    three implementations (block reference, fused kernel, two-kernel
    fallback) must now agree with local_window/sliding_window set."""
    b, hkv, s, dim, bs = 2, 2, 256, 64, 32
    q, k, v = _setup(b, hkv, g, s, dim, seed=g + local_window)
    proj = _orthogonal(hkv, dim, seed=g)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s, 130], jnp.int32)
    cfg = LokiConfig(enabled=True, d_f=0.25, k_f=0.25, block_size=bs,
                     local_window=local_window)
    want = loki_decode_block(q, k_hat, v, cur, proj, cfg,
                             sliding_window=sliding_window,
                             group_select=True)
    want = want.reshape(b, hkv, g, dim)
    nb = s // bs
    kw = dict(d=max(int(cfg.d_f * dim), 8),
              k_blocks=max(int(cfg.k_f * nb), 1), block_size=bs,
              local_window=local_window, sliding_window=sliding_window,
              interpret=True)
    q_hat = _grouped_q(q, proj, hkv)
    fused = fused_loki_decode(q_hat, k_hat, v, cur, **kw)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    two = loki_decode_two_kernel(q_hat, k_hat, v, cur, **kw)
    np.testing.assert_allclose(np.asarray(two), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_block_full_budget_windows_match_token_path():
    """At full budget (k_f=1: every block selected) the block path with
    windows must equal the token-granular loki_decode — the semantic
    anchor tying the block windows to the paper's formulation."""
    from repro.core.loki import loki_decode
    b, hkv, g, s, dim, bs = 2, 2, 2, 128, 64, 32
    q, k, v = _setup(b, hkv, g, s, dim, seed=77)
    proj = _orthogonal(hkv, dim, seed=77)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s, 70], jnp.int32)
    cfg = LokiConfig(enabled=True, d_f=1.0, k_f=1.0, min_k=1,
                     block_size=bs, local_window=16)
    want = loki_decode(q, k_hat, v, cur, proj, cfg, sliding_window=48)
    got = loki_decode_block(q, k_hat, v, cur, proj, cfg, sliding_window=48)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------- paged (page-table) mode

def _paged_pool(k_hat, v, bs, ps, seed=0):
    """Scatter contiguous (B,S,Hkv,D) caches into a shuffled page pool.

    Returns (pool_k, pool_v, page_table) with page 0 left as trash."""
    b, s, hkv, dim = k_hat.shape
    mp = s // ps
    rng = np.random.RandomState(seed)
    perm = rng.permutation(b * mp) + 1              # physical pages, 1-based
    table = perm.reshape(b, mp).astype(np.int32)
    n_pages = b * mp + 1
    pool_k = np.zeros((n_pages * ps, hkv, dim), np.asarray(k_hat).dtype)
    pool_v = np.zeros_like(pool_k)
    kn, vn = np.asarray(k_hat), np.asarray(v)
    for i in range(b):
        for p in range(mp):
            rows = slice(table[i, p] * ps, table[i, p] * ps + ps)
            pool_k[rows] = kn[i, p * ps:(p + 1) * ps]
            pool_v[rows] = vn[i, p * ps:(p + 1) * ps]
    return (jnp.asarray(pool_k), jnp.asarray(pool_v),
            jnp.asarray(table))


@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("bs,ps", [(32, 32), (16, 32), (32, 64)])
def test_fused_paged_matches_contiguous(g, bs, ps):
    """The paged kernel (block DMA through the page table) must reproduce
    the contiguous kernel bit-for-bit on a shuffled pool, including ragged
    lengths and windows."""
    b, hkv, s, dim = 2, 2, 256, 64
    q, k, v = _setup(b, hkv, g, s, dim, seed=g + bs)
    proj = _orthogonal(hkv, dim, seed=bs)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s, 100], jnp.int32)
    pool_k, pool_v, table = _paged_pool(k_hat, v, bs, ps, seed=g)
    q_hat = _grouped_q(q, proj, hkv)
    kw = dict(d=16, k_blocks=3, block_size=bs, local_window=8,
              interpret=True)
    want = fused_loki_decode(q_hat, k_hat, v, cur, **kw)
    got = fused_loki_decode(q_hat, pool_k, pool_v, cur,
                            page_table=table, page_size=ps, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    two = loki_decode_two_kernel(q_hat, pool_k, pool_v, cur,
                                 page_table=table, page_size=ps, **kw)
    np.testing.assert_allclose(np.asarray(two), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_dispatch_paged_pallas_matches_oracle():
    """End-to-end dispatch with a page table: backend='pallas' (paged
    kernels) equals the group-shared jnp oracle gathering through the same
    table, and backend='xla' through the table equals the dense-cache
    reference (per-head selection)."""
    b, hkv, g, s, dim, bs = 2, 2, 4, 256, 64, 32
    q, k, v = _setup(b, hkv, g, s, dim, seed=91)
    proj = _orthogonal(hkv, dim, seed=91)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s, 77], jnp.int32)
    pool_k, pool_v, table = _paged_pool(k_hat, v, bs, bs, seed=3)
    cfg = LokiConfig(enabled=True, d_f=0.25, k_f=0.25, block_size=bs,
                     local_window=16)
    got = dispatch.loki_block_decode(
        q, pool_k, pool_v, cur, proj,
        dataclasses.replace(cfg, backend="pallas"),
        page_table=table, page_size=bs)
    want = loki_decode_block(q, pool_k, pool_v, cur, proj, cfg,
                             group_select=True, page_table=table,
                             page_size=bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # xla dispatch through the table == dense-cache reference
    via_table = dispatch.loki_block_decode(
        q, pool_k, pool_v, cur, proj,
        dataclasses.replace(cfg, backend="xla"),
        page_table=table, page_size=bs)
    dense = loki_decode_block(q, k_hat, v, cur, proj, cfg)
    np.testing.assert_allclose(np.asarray(via_table), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- dispatch

def test_resolve_backend():
    assert dispatch.resolve_backend("auto", "cpu") == "xla"
    assert dispatch.resolve_backend("auto", "tpu") == "pallas"
    assert dispatch.resolve_backend("pallas", "cpu") == "pallas"
    assert dispatch.resolve_backend("xla", "tpu") == "xla"
    with pytest.raises(ValueError):
        dispatch.resolve_backend("triton")


@pytest.mark.parametrize("g", [1, 4])
def test_dispatch_pallas_matches_xla_grouped(g):
    """End-to-end dispatch: backend='pallas' (interpret on CPU) equals the
    grouped jnp oracle across ragged lengths."""
    b, hkv, s, dim, bs = 2, 2, 256, 64, 32
    q, k, v = _setup(b, hkv, g, s, dim, seed=51)
    proj = _orthogonal(hkv, dim, seed=51)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s, 77])
    cfg = LokiConfig(enabled=True, d_f=0.25, k_f=0.25, block_size=bs,
                     local_window=0, backend="pallas")
    got = dispatch.loki_block_decode(q, k_hat, v, cur, proj, cfg)
    want = loki_decode_block(q, k_hat, v, cur, proj, cfg,
                             group_select=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_dispatch_xla_is_reference():
    b, hkv, g, s, dim, bs = 1, 2, 2, 128, 64, 32
    q, k, v = _setup(b, hkv, g, s, dim, seed=61)
    proj = _orthogonal(hkv, dim, seed=61)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s])
    cfg = LokiConfig(enabled=True, d_f=0.5, k_f=0.5, block_size=bs,
                     local_window=0, backend="xla")
    got = dispatch.loki_block_decode(q, k_hat, v, cur, proj, cfg)
    want = loki_decode_block(q, k_hat, v, cur, proj, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_dispatch_unplannable_shape_falls_back():
    """A cache length no candidate block size divides still decodes — the
    dispatcher falls back to the jnp path instead of asserting."""
    b, hkv, g, dim = 1, 2, 2, 64
    s = 105  # 3*5*7: neither the hint nor any pow2 candidate divides
    cfg = LokiConfig(enabled=True, d_f=0.5, k_f=0.5, block_size=8,
                     local_window=0, backend="pallas")
    assert tuning.plan_decode(s, dim, g, 32, 8) is None
    q, k, v = _setup(b, hkv, g, s, dim, seed=71)
    proj = _orthogonal(hkv, dim, seed=71)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    out = dispatch.loki_block_decode(q, k_hat, v, jnp.array([s]), proj, cfg)
    assert bool(jnp.isfinite(out).all())


def test_plan_decode_table_and_heuristic():
    p = tuning.plan_decode(32_768, 128, 8, 32, 128)
    assert p is not None and 32_768 % p.block_size == 0
    assert tuning.plan_decode(4096, 128, 4, 32, 128).variant == "fused"
    # indivisible cache length -> no plan
    assert tuning.plan_decode(300, 64, 2, 16, 128) is None
    # absurd scratch demand -> two-pass or refusal, never "fused"
    big = tuning.plan_decode(2 ** 21, 8192, 64, 2048, 128, itemsize=4)
    assert big is None or big.variant == "two_kernel"


def test_engine_backend_knob():
    """ServingEngine(backend=...) threads through to cfg.loki.backend."""
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serving.engine import ServingEngine
    cfg = get_smoke_config("qwen2.5-3b").with_policy(
        "loki_block", d_f=0.5, k_f=0.5, block_size=8, local_window=0)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, n_slots=1, smax=32, backend="xla")
    assert eng.cfg.loki.backend == "xla"
    assert cfg.loki.backend == "auto"  # caller's config untouched
