"""Serving engines: continuous batching, slot reuse, policy parity, the
paged KV-cache + chunked-prefill scheduler, and its edge cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving.engine import Request, ServingEngine
from repro.serving.paged_cache import PagePool
from repro.serving.scheduler import PagedServingEngine


def _model():
    cfg = get_smoke_config("qwen2.5-3b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _sequential_dense(params, cfg, prompts, max_new, smax,
                      admission="strict"):
    """Ground truth: each prompt served alone by the dense engine."""
    outs = []
    for p in prompts:
        eng = ServingEngine(params, cfg, n_slots=1, smax=smax,
                            admission=admission)
        r = Request(rid=0, prompt=p.copy(), max_new=max_new)
        eng.submit(r)
        eng.run_until_done(500)
        outs.append(r.out)
    return outs


def test_requests_complete_and_slots_recycle():
    params, cfg = _model()
    eng = ServingEngine(params, cfg, n_slots=2, smax=64)
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab, max_new=5)
            for i in range(5)]            # 5 requests > 2 slots
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_ticks=500)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)
    assert not eng.live.any()


def test_engine_matches_direct_decode():
    """A single request through the engine produces the same greedy tokens
    as manual prefill+decode."""
    params, cfg = _model()
    prompt = (np.arange(8) * 3 + 1) % cfg.vocab
    eng = ServingEngine(params, cfg, n_slots=1, smax=64)
    req = Request(rid=0, prompt=prompt, max_new=6)
    eng.submit(req)
    eng.run_until_done(max_ticks=100)

    toks = jnp.asarray(prompt[None].astype(np.int32))
    lg, cache, pos = lm.prefill(params, cfg, toks, smax=64,
                                cache_dtype=jnp.float32)
    out = []
    tok = jnp.argmax(lg, -1)
    for _ in range(6):
        out.append(int(tok[0]))
        lg, cache = lm.decode_step(params, cfg, cache, tok, pos)
        pos = pos + 1
        tok = jnp.argmax(lg, -1)
    assert req.out == out


def test_eos_stops_early():
    params, cfg = _model()
    # find the greedy first token and use it as eos
    prompt = np.arange(6) % cfg.vocab
    probe = ServingEngine(params, cfg, n_slots=1, smax=64)
    r0 = Request(rid=0, prompt=prompt.copy(), max_new=1)
    probe.submit(r0)
    probe.run_until_done(100)
    eos = r0.out[0]
    eng = ServingEngine(params, cfg, n_slots=1, smax=64, eos_id=eos)
    req = Request(rid=1, prompt=prompt.copy(), max_new=50)
    eng.submit(req)
    eng.run_until_done(200)
    assert req.done and len(req.out) == 1 and req.out[0] == eos


def test_ragged_batch_isolation():
    """Two concurrent requests with different prompts produce the same
    outputs as when served alone (per-slot positions keep them exact)."""
    params, cfg = _model()
    p1 = (np.arange(5) * 7 + 2) % cfg.vocab
    p2 = (np.arange(9) * 5 + 3) % cfg.vocab

    def alone(prompt):
        eng = ServingEngine(params, cfg, n_slots=1, smax=64)
        r = Request(rid=0, prompt=prompt.copy(), max_new=4)
        eng.submit(r)
        eng.run_until_done(100)
        return r.out

    solo1, solo2 = alone(p1), alone(p2)
    eng = ServingEngine(params, cfg, n_slots=2, smax=64)
    r1 = Request(rid=1, prompt=p1.copy(), max_new=4)
    r2 = Request(rid=2, prompt=p2.copy(), max_new=4)
    eng.submit(r1)
    eng.submit(r2)
    eng.run_until_done(200)
    assert r1.out == solo1
    assert r2.out == solo2


def test_late_admission_does_not_disturb_live_slot():
    """Prefilling a newly admitted request writes only its own slot: a
    request admitted mid-generation leaves the live slot's continuation
    bit-identical to serving it alone."""
    params, cfg = _model()
    p1 = (np.arange(6) * 7 + 2) % cfg.vocab
    p2 = (np.arange(11) * 5 + 3) % cfg.vocab

    eng_solo = ServingEngine(params, cfg, n_slots=1, smax=64)
    solo = Request(rid=0, prompt=p1.copy(), max_new=8)
    eng_solo.submit(solo)
    eng_solo.run_until_done(100)

    eng = ServingEngine(params, cfg, n_slots=2, smax=64)
    r1 = Request(rid=1, prompt=p1.copy(), max_new=8)
    eng.submit(r1)
    for _ in range(3):                 # r1 generates alone for a few ticks
        eng.tick()
    r2 = Request(rid=2, prompt=p2.copy(), max_new=4)
    eng.submit(r2)                     # admission prefills into slot 1 only
    eng.run_until_done(200)
    assert r1.out == solo.out
    assert r2.done


def test_overlong_prompt_truncates_instead_of_crashing():
    """A prompt longer than smax keeps the most recent context and still
    serves (lenient admission), instead of aborting the batched step with
    a shape error. (Strict admission — the default — FAILs it at submit
    instead; see tests/test_lifecycle.py.)"""
    params, cfg = _model()
    eng = ServingEngine(params, cfg, n_slots=1, smax=16,
                        admission="lenient")
    req = Request(rid=0, prompt=(np.arange(25) * 3 + 1) % cfg.vocab,
                  max_new=2)
    eng.submit(req)
    eng.run_until_done(50)
    assert req.done and len(req.out) >= 1


def test_overlong_prompt_still_generates_full_max_new():
    """Regression: truncation to smax itself left pos at smax-1, so the
    finish guard ended the request after ONE generated token. The fix
    reserves max_new rows of headroom (for max_new <= smax//2)."""
    params, cfg = _model()
    for n_slots, engine_cls, kw in [
            (1, ServingEngine, {}),
            (1, PagedServingEngine, dict(page_size=8, prefill_chunk=4))]:
        eng = engine_cls(params, cfg, n_slots=n_slots, smax=16,
                         admission="lenient", **kw)
        req = Request(rid=0, prompt=(np.arange(40) * 3 + 1) % cfg.vocab,
                      max_new=6)
        eng.submit(req)
        eng.run_until_done(100)
        assert req.done, engine_cls.__name__
        assert len(req.out) == 6, (engine_cls.__name__, req.out)


def test_rng_threads_through_run_until_done():
    """run_until_done(rng=...) must thread a *split* key per tick: the same
    seed reproduces a sampled stream, different seeds diverge (before the
    fix, rng was silently dropped and every tick reused PRNGKey(ticks))."""
    params, cfg = _model()
    prompt = (np.arange(6) * 5 + 1) % cfg.vocab

    def sampled(seed):
        eng = ServingEngine(params, cfg, n_slots=1, smax=64, greedy=False)
        r = Request(rid=0, prompt=prompt.copy(), max_new=8)
        eng.submit(r)
        eng.run_until_done(100, rng=jax.random.PRNGKey(seed))
        return r.out

    assert sampled(0) == sampled(0)          # deterministic given the key
    outs = {tuple(sampled(s)) for s in range(4)}
    assert len(outs) > 1                     # keys actually influence draws


# ===================================================================
# Paged engine (serving/scheduler.py + serving/paged_cache.py)
# ===================================================================


def test_paged_matches_sequential_dense_at_2x_concurrency():
    """Acceptance: 2x more concurrent requests than the dense engine's
    n_slots, greedy outputs identical to serving each prompt alone."""
    params, cfg = _model()
    n_slots = 2
    prompts = [(np.arange(5 + 3 * i) * 7 + i) % cfg.vocab
               for i in range(2 * n_slots)]
    truth = _sequential_dense(params, cfg, prompts, max_new=5, smax=64)
    eng = PagedServingEngine(params, cfg, n_slots=n_slots, smax=64,
                             page_size=16, prefill_chunk=4)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(500)
    for r, t in zip(reqs, truth):
        assert r.done and r.out == t, (r.rid, r.out, t)


def test_paged_more_queued_requests_than_pages():
    """A queue whose total footprint exceeds the pool drains via page
    recycling: 8 requests over a pool that fits ~2."""
    params, cfg = _model()
    prompts = [(np.arange(6 + i) * 5 + i) % cfg.vocab for i in range(8)]
    truth = _sequential_dense(params, cfg, prompts, max_new=4, smax=32)
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=4, n_pages=6)  # 5 usable pages
    total_pages_needed = sum(
        PagePool.pages_for(len(p) + 4, 8) for p in prompts)
    assert total_pages_needed > eng.pool.n_pages - 1
    reqs = [Request(rid=i, prompt=p.copy(), max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(1000)
    for r, t in zip(reqs, truth):
        assert r.done and r.out == t, (r.rid, r.out, t)


def test_paged_preemption_reproduces_greedy_outputs():
    """Memory pressure forces recompute-preemption mid-generation; the
    re-admitted requests must reproduce the identical continuation."""
    params, cfg = _model()
    prompts = [(np.arange(9 + i) * 5 + i) % cfg.vocab for i in range(4)]
    truth = _sequential_dense(params, cfg, prompts, max_new=14, smax=32)
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=4, n_pages=6)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=14)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(1000)
    assert eng.n_preempted > 0               # pressure actually materialized
    for r, t in zip(reqs, truth):
        assert r.done and r.out == t, (r.rid, r.out, t)


def test_paged_preemption_in_capacity_regime_keeps_context():
    """Regression: re-admission after preemption used to re-truncate the
    folded prompt when max_new > smax//2, making greedy output depend on
    preemption timing. The folded context must survive intact."""
    params, cfg = _model()
    prompts = [(np.arange(16) * 3 + i) % cfg.vocab for i in range(3)]
    truth = _sequential_dense(params, cfg, prompts, max_new=100, smax=32,
                              admission="lenient")
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=8, n_pages=6,
                             admission="lenient")
    reqs = [Request(rid=i, prompt=p.copy(), max_new=100)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(2000)
    assert eng.n_preempted > 0
    for r, t in zip(reqs, truth):
        assert r.done and r.out == t, (r.rid, r.out, t)


def test_paged_eos_mid_stream_frees_pages():
    """EOS mid-generation finishes the request early and returns its pages
    to the pool."""
    params, cfg = _model()
    prompt = np.arange(6) % cfg.vocab
    probe = PagedServingEngine(params, cfg, n_slots=1, smax=32, page_size=8,
                               prefill_chunk=4)
    r0 = Request(rid=0, prompt=prompt.copy(), max_new=1)
    probe.submit(r0)
    probe.run_until_done(100)
    eos = r0.out[0]
    eng = PagedServingEngine(params, cfg, n_slots=1, smax=32, page_size=8,
                             prefill_chunk=4, eos_id=eos,
                             admission="lenient")
    req = Request(rid=1, prompt=prompt.copy(), max_new=50)
    eng.submit(req)
    eng.run_until_done(300)
    assert req.done and req.out[-1] == eos and len(req.out) == 1
    assert eng.pool.free_pages == eng.pool.n_pages - 1   # everything freed
    assert not eng.live.any()


def test_paged_request_outliving_its_pages_finishes_at_cap():
    """A generation that would outgrow max_pages finishes gracefully at the
    logical capacity instead of corrupting the pool or hanging."""
    params, cfg = _model()
    prompt = (np.arange(5) * 3 + 2) % cfg.vocab
    eng = PagedServingEngine(params, cfg, n_slots=1, smax=32, page_size=8,
                             prefill_chunk=4, admission="lenient")
    req = Request(rid=0, prompt=prompt.copy(), max_new=1000)
    eng.submit(req)
    eng.run_until_done(500)
    assert req.done
    # prompt kept intact (reservation caps at smax//2), generation filled
    # the remaining capacity (pos walks from len(prompt)-1 up to smax-1)
    assert len(req.out) == 32 - len(prompt)
    assert eng.pool.free_pages == eng.pool.n_pages - 1


def test_chunked_prefill_matches_oneshot_logits():
    """Driving a prompt through fixed-size prefill chunks reproduces the
    one-shot prefill's last-token logits (the scheduler's admission path)."""
    params, cfg = _model()
    prompt = (np.arange(19) * 7 + 3) % cfg.vocab
    toks = jnp.asarray(prompt[None].astype(np.int32))
    lg_ref, _, _ = lm.prefill(params, cfg, toks, smax=32,
                              cache_dtype=jnp.float32)

    ps, n_pages = 8, 6
    cache = lm.init_paged_cache(cfg, n_pages, ps, jnp.float32)
    table = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    c = 4
    lg = None
    for start in range(0, len(prompt), c):
        nv = min(c, len(prompt) - start)
        chunk = np.zeros((1, c), np.int32)
        chunk[0, :nv] = prompt[start:start + nv]
        lg, cache = lm.prefill_chunk(params, cfg, cache,
                                     jnp.asarray(chunk), jnp.int32(start),
                                     jnp.int32(nv), table, ps)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               rtol=1e-5, atol=1e-5)


def test_chunked_prefill_last_chunk_overhangs_logical_length():
    """Regression: a padded final chunk whose window overhangs smax
    (pos_start + prefill_chunk > smax) used to clamp the fresh-score
    overwrite 'chunk' columns early, corrupting the prefix scores. The
    overhanging pad columns must be dropped instead."""
    params, cfg = _model()
    prompt = (np.arange(26) * 3 + 5) % cfg.vocab     # 25 prefill tokens
    truth = _sequential_dense(params, cfg, [prompt], max_new=4, smax=32)[0]
    # chunk=12: chunks at 0, 12, 24 -> last window [24, 36) overhangs 32
    eng = PagedServingEngine(params, cfg, n_slots=1, smax=32, page_size=8,
                             prefill_chunk=12)
    req = Request(rid=0, prompt=prompt.copy(), max_new=4)
    eng.submit(req)
    eng.run_until_done(200)
    assert req.done and req.out == truth, (req.out, truth)


def test_paged_rejects_unpageable_policies():
    params, cfg = _model()
    with pytest.raises(ValueError, match="paged"):
        PagedServingEngine(params, cfg.with_policy("h2o"), n_slots=1,
                           smax=32)
    with pytest.raises(ValueError, match="cannot hold"):
        PagedServingEngine(params, cfg, n_slots=1, smax=64, page_size=8,
                           n_pages=4)          # pool smaller than 1 request


def test_page_pool_alloc_free_cycle():
    pool = PagePool(6, 8)                      # page 0 reserved
    assert pool.free_pages == 5
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert a is not None and b is not None
    assert pool.alloc(1) is None               # exhausted, no partial grab
    assert pool.free_pages == 0
    pool.free(a)
    assert pool.free_pages == 3
    c = pool.alloc(3)
    assert sorted(c) == sorted(a)              # recycled
    assert PagePool.pages_for(0, 8) == 0
    assert PagePool.pages_for(1, 8) == 1
    assert PagePool.pages_for(17, 8) == 3
