"""Serving engine: continuous batching, slot reuse, policy parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving.engine import Request, ServingEngine


def _model():
    cfg = get_smoke_config("qwen2.5-3b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return params, cfg


def test_requests_complete_and_slots_recycle():
    params, cfg = _model()
    eng = ServingEngine(params, cfg, n_slots=2, smax=64)
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab, max_new=5)
            for i in range(5)]            # 5 requests > 2 slots
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_ticks=500)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)
    assert not eng.live.any()


def test_engine_matches_direct_decode():
    """A single request through the engine produces the same greedy tokens
    as manual prefill+decode."""
    params, cfg = _model()
    prompt = (np.arange(8) * 3 + 1) % cfg.vocab
    eng = ServingEngine(params, cfg, n_slots=1, smax=64)
    req = Request(rid=0, prompt=prompt, max_new=6)
    eng.submit(req)
    eng.run_until_done(max_ticks=100)

    toks = jnp.asarray(prompt[None].astype(np.int32))
    lg, cache, pos = lm.prefill(params, cfg, toks, smax=64,
                                cache_dtype=jnp.float32)
    out = []
    tok = jnp.argmax(lg, -1)
    for _ in range(6):
        out.append(int(tok[0]))
        lg, cache = lm.decode_step(params, cfg, cache, tok, pos)
        pos = pos + 1
        tok = jnp.argmax(lg, -1)
    assert req.out == out


def test_eos_stops_early():
    params, cfg = _model()
    # find the greedy first token and use it as eos
    prompt = np.arange(6) % cfg.vocab
    probe = ServingEngine(params, cfg, n_slots=1, smax=64)
    r0 = Request(rid=0, prompt=prompt.copy(), max_new=1)
    probe.submit(r0)
    probe.run_until_done(100)
    eos = r0.out[0]
    eng = ServingEngine(params, cfg, n_slots=1, smax=64, eos_id=eos)
    req = Request(rid=1, prompt=prompt.copy(), max_new=50)
    eng.submit(req)
    eng.run_until_done(200)
    assert req.done and len(req.out) == 1 and req.out[0] == eos


def test_ragged_batch_isolation():
    """Two concurrent requests with different prompts produce the same
    outputs as when served alone (per-slot positions keep them exact)."""
    params, cfg = _model()
    p1 = (np.arange(5) * 7 + 2) % cfg.vocab
    p2 = (np.arange(9) * 5 + 3) % cfg.vocab

    def alone(prompt):
        eng = ServingEngine(params, cfg, n_slots=1, smax=64)
        r = Request(rid=0, prompt=prompt.copy(), max_new=4)
        eng.submit(r)
        eng.run_until_done(100)
        return r.out

    solo1, solo2 = alone(p1), alone(p2)
    eng = ServingEngine(params, cfg, n_slots=2, smax=64)
    r1 = Request(rid=1, prompt=p1.copy(), max_new=4)
    r2 = Request(rid=2, prompt=p2.copy(), max_new=4)
    eng.submit(r1)
    eng.submit(r2)
    eng.run_until_done(200)
    assert r1.out == solo1
    assert r2.out == solo2


def test_late_admission_does_not_disturb_live_slot():
    """Prefilling a newly admitted request writes only its own slot: a
    request admitted mid-generation leaves the live slot's continuation
    bit-identical to serving it alone."""
    params, cfg = _model()
    p1 = (np.arange(6) * 7 + 2) % cfg.vocab
    p2 = (np.arange(11) * 5 + 3) % cfg.vocab

    eng_solo = ServingEngine(params, cfg, n_slots=1, smax=64)
    solo = Request(rid=0, prompt=p1.copy(), max_new=8)
    eng_solo.submit(solo)
    eng_solo.run_until_done(100)

    eng = ServingEngine(params, cfg, n_slots=2, smax=64)
    r1 = Request(rid=1, prompt=p1.copy(), max_new=8)
    eng.submit(r1)
    for _ in range(3):                 # r1 generates alone for a few ticks
        eng.tick()
    r2 = Request(rid=2, prompt=p2.copy(), max_new=4)
    eng.submit(r2)                     # admission prefills into slot 1 only
    eng.run_until_done(200)
    assert r1.out == solo.out
    assert r2.done


def test_overlong_prompt_truncates_instead_of_crashing():
    """A prompt longer than smax keeps the most recent smax tokens and still
    serves, instead of aborting the batched step with a shape error."""
    params, cfg = _model()
    eng = ServingEngine(params, cfg, n_slots=1, smax=16)
    req = Request(rid=0, prompt=(np.arange(25) * 3 + 1) % cfg.vocab,
                  max_new=2)
    eng.submit(req)
    eng.run_until_done(50)
    assert req.done and len(req.out) >= 1
