"""Checkpoint manager: atomicity, keep-N, corruption tolerance, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": [jnp.ones((2,)), jnp.zeros((3, 3))]},
    }


def _assert_tree_equal(x, y):
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), x, y)


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree()
    mgr.save(7, t, blocking=True)
    step, restored = mgr.restore_latest(_tree(seed=1))
    assert step == 7
    _assert_tree_equal(t, restored)


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    steps = mgr.steps()
    assert steps == [3, 4]


def test_restore_skips_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1), blocking=True)
    mgr.save(2, _tree(2), blocking=True)
    # corrupt the latest checkpoint's payload
    step_dir = None
    for d in sorted(os.listdir(tmp_path)):
        if "2" in d and not d.startswith("."):
            step_dir = os.path.join(tmp_path, d)
    assert step_dir is not None
    for f in os.listdir(step_dir):
        with open(os.path.join(step_dir, f), "wb") as fh:
            fh.write(b"garbage")
    step, restored = mgr.restore_latest(_tree(seed=9))
    assert step == 1, "should fall back to the previous intact checkpoint"
    _assert_tree_equal(_tree(1), restored)


def test_restore_empty_dir(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    step, restored = mgr.restore_latest(_tree(3))
    assert step is None
    _assert_tree_equal(_tree(3), restored)


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(5, _tree(5), blocking=False)
    mgr.wait()
    step, restored = mgr.restore_latest(_tree(0))
    assert step == 5
    _assert_tree_equal(_tree(5), restored)


def test_no_partial_checkpoint_visible(tmp_path):
    """Atomic rename: directory listing never shows a half-written step."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1), blocking=True)
    names = os.listdir(tmp_path)
    assert all(not n.startswith(("tmp", ".tmp")) for n in names), names
