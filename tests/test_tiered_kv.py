"""Tiered KV page pool: host-offloaded full-D pages with Loki-guided
async prefetch (DESIGN.md §13).

Locks the tier from five sides: greedy bit-identity of a tiered pool vs
the single-tier engine across families x Loki policies at the *minimum*
legal device pool (maximum demotion traffic); a context whose total page
footprint exceeds the device tier still completing; the
demote-before-preempt ordering (frame pressure demotes, never preempts);
the prefetch hit/miss and sync-fallback counters; and the PagePool tier
state machine itself (illegal transitions raise). The chaos run drives
the two tier fault sites — ``dma_timeout`` and ``hbm_oom_on_promote`` —
with the invariant auditor on every tick and DONE outputs bit-identical
to the fault-free run.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving import faults as FI
from repro.serving import paged_cache as PC
from repro.serving.engine import Request
from repro.serving.scheduler import PagedServingEngine


def _cfg(arch, policy="loki_block"):
    return get_smoke_config(arch).with_policy(
        policy, k_f=0.5, d_f=0.5, block_size=8, local_window=4, min_k=4)


def _stream(cfg, n=4, plen=18, max_new=10):
    rng = np.random.default_rng(11)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        size=plen).astype(np.int32),
                    max_new=max_new)
            for i in range(n)]


def _run(params, cfg, reqs, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("smax", 48)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("audit", True)
    eng = PagedServingEngine(params, cfg, **kw)
    for r in reqs:
        eng.submit(r)
    eng.drain(2000)
    assert all(r.done for r in reqs)
    return [r.out for r in reqs], eng


def _min_device_pages(eng):
    """Smallest legal device tier: one full request plus one frame."""
    return eng._req_pages_hard + 1


# ===================================================================
# bit-identity: tiered vs single-tier
# ===================================================================

@pytest.mark.parametrize("arch", ["llama2-7b", "mixtral-8x22b",
                                  "hymba-1.5b"])
@pytest.mark.parametrize("policy", ["loki", "loki_block"])
def test_tiered_greedy_bit_identity(arch, policy):
    """The minimum legal device pool — maximum demotion/promotion churn —
    must reproduce the single-tier stream token for token."""
    cfg = _cfg(arch, policy)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    base, e0 = _run(params, cfg, _stream(cfg))
    tiered, e1 = _run(params, cfg, _stream(cfg),
                      device_pages=_min_device_pages(e0), max_inflight=2)
    assert tiered == base, "tiered pool changed greedy outputs"
    st = e1.stats()["tiered"]
    assert st["n_demoted"] > 0, "minimum device pool never demoted"
    assert st["n_promoted"] > 0


def test_context_exceeding_device_pool_completes():
    """Total logical footprint well beyond the device tier (the
    'context larger than HBM' run): more slots than the device pool can
    hold resident at once still drains, bit-identically."""
    cfg = _cfg("llama2-7b", "loki")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    reqs = lambda: _stream(cfg, n=6, plen=20, max_new=12)
    base, e0 = _run(params, cfg, reqs(), n_slots=4, smax=64)
    dev = _min_device_pages(e0)
    tiered, e1 = _run(params, cfg, reqs(), n_slots=4, smax=64,
                      device_pages=dev, max_inflight=2)
    assert e1.pool.n_pages > dev, "pressure never materialized"
    assert tiered == base
    assert e1.stats()["tiered"]["n_demoted"] > 0


def test_per_layer_ranks_tiered_bit_identity():
    """Per-layer latent ranks (Loki §4.2) ride through the tiered pool:
    the sidecar keeps each layer's own rank and selection stays exact."""
    cfg = _cfg("llama2-7b", "loki_block")
    hd = cfg.resolved_head_dim
    cfg = cfg.with_ranks(tuple(hd if i % 2 == 0 else hd // 2
                               for i in range(cfg.n_layers)))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    base, e0 = _run(params, cfg, _stream(cfg))
    tiered, e1 = _run(params, cfg, _stream(cfg),
                      device_pages=_min_device_pages(e0))
    assert tiered == base
    assert e1.stats()["tiered"]["n_demoted"] > 0


# ===================================================================
# policy: demotion precedes preemption; prefetch counters
# ===================================================================

def test_demotion_before_preemption():
    """Frame pressure at the minimum device pool is absorbed entirely by
    demotion + deferral: the logical pool has room for every request, so
    nothing may be preempted (losing a frame costs one prefetch; losing
    a slot would cost a re-prefill and, under Loki, exactness)."""
    cfg = _cfg("llama2-7b", "loki")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    _, eng = _run(params, cfg, _stream(cfg, n=6), n_slots=4,
                  device_pages=7, smax=48)
    st = eng.stats()["tiered"]
    assert st["n_demoted"] > 0
    assert eng.n_preempted == 0, \
        "frame shortage must demote/defer, never preempt"


def test_prefetch_hit_and_miss_counters():
    """Counter semantics: a device pool covering every page scores pure
    hits; the minimum pool records misses, promotions through the fetch
    queue, and a hit rate strictly between 0 and 1."""
    cfg = _cfg("llama2-7b", "loki_block")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    _, full = _run(params, cfg, _stream(cfg),
                   device_pages=None)  # single-tier: no tiered stats
    assert "tiered" not in full.stats()

    _, roomy = _run(params, cfg, _stream(cfg),
                    device_pages=1 + 2 * full._req_pages_hard)
    st = roomy.stats()["tiered"]
    assert st["n_prefetch_hits"] > 0 and st["n_prefetch_misses"] == 0
    assert st["prefetch_hit_rate"] == 1.0
    assert st["n_sync_fetches"] == 0

    _, tight = _run(params, cfg, _stream(cfg),
                    device_pages=_min_device_pages(full))
    st = tight.stats()["tiered"]
    assert st["n_prefetch_misses"] > 0
    assert st["n_promoted"] > 0
    assert 0.0 < st["prefetch_hit_rate"] < 1.0


# ===================================================================
# PagePool tier state machine
# ===================================================================

def test_pool_tier_state_machine_raises():
    pool = PC.PagePool(8, 4, device_pages=5, max_inflight=2)
    pages = pool.alloc(4)
    assert pages is not None
    p = pages[0]
    assert pool.tier_of(p) == PC.RESIDENT

    frame = pool.demote(p)
    assert frame >= 0 and pool.tier_of(p) == PC.HOST
    with pytest.raises(ValueError, match="double-demote"):
        pool.demote(p)

    got = pool.promote_begin(p, faultable=False)
    assert got is not None and pool.tier_of(p) == PC.IN_FLIGHT
    with pytest.raises(ValueError, match="in-flight"):
        pool.free([p])
    pool.promote_complete(p)
    assert pool.tier_of(p) == PC.RESIDENT
    with pytest.raises(ValueError):
        pool.promote_begin(p)          # promote of a RESIDENT page
    with pytest.raises(ValueError):
        pool.promote_complete(p)       # complete without begin

    pool.pin(p)
    with pytest.raises(ValueError, match="pinned"):
        pool.demote(p)
    pool.unpin(p)
    with pytest.raises(ValueError, match="unpinned"):
        pool.unpin(p)

    q = pages[1]
    pool.demote(q)
    with pytest.raises(ValueError, match="non-resident"):
        pool.pin(q)

    # single-tier pools have no tier surface at all
    flat = PC.PagePool(8, 4)
    r = flat.alloc(1)[0]
    with pytest.raises(ValueError, match="single-tier"):
        flat.demote(r)
    with pytest.raises(ValueError, match="single-tier"):
        flat.promote_begin(r)


def test_pool_inflight_budget_bounds_fetches():
    pool = PC.PagePool(8, 4, device_pages=5, max_inflight=1)
    pages = pool.alloc(3)
    for p in pages:
        pool.demote(p)
    a = pool.promote_begin(pages[0], faultable=False)
    assert a is not None
    assert pool.promote_begin(pages[1], faultable=False) is None, \
        "max_inflight=1 must refuse a second outstanding fetch"
    pool.promote_complete(pages[0])
    assert pool.promote_begin(pages[1], faultable=False) is not None


# ===================================================================
# chaos: the tier fault sites
# ===================================================================

def test_tiered_chaos_fault_sites_bit_identical():
    """``dma_timeout`` (an in-flight fetch never lands -> sync fallback)
    and ``hbm_oom_on_promote`` (staging alloc fails -> retry/defer) under
    the per-tick auditor: every DONE output matches the fault-free
    tiered run bit for bit."""
    cfg = _cfg("llama2-7b", "loki_block")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    truth, e0 = _run(params, cfg, _stream(cfg, n=5), n_slots=3,
                     device_pages=9, smax=48)

    plan = FI.FaultPlan.parse(
        "seed=5,dma_timeout=0.5,hbm_oom_on_promote=0.5")
    rs = _stream(cfg, n=5)
    out, e1 = _run(params, cfg, rs, n_slots=3, device_pages=9, smax=48,
                   faults=plan)
    assert out == truth, "tier faults changed DONE outputs"
    assert plan.counts.get("dma_timeout", 0) > 0
    assert plan.counts.get("hbm_oom_on_promote", 0) > 0
    st = e1.stats()["tiered"]
    assert st["n_sync_fallbacks"] > 0, \
        "dma_timeout never forced the synchronous fallback"
