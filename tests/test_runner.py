"""Fault-tolerant training runner: crash, restart, bit-exact resume."""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.data.synthetic import DataConfig
from repro.training.runner import (FailureInjector, TrainRunner,
                                   run_with_restarts)


def _cfgs(tmp_path):
    cfg = get_smoke_config("llama2-7b")
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=20, seed=0)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=1)
    return cfg, tcfg, dcfg


def test_restart_resumes_bit_exact(tmp_path):
    cfg, tcfg, dcfg = _cfgs(tmp_path)

    # uninterrupted reference run
    ref = TrainRunner(cfg, tcfg, dcfg, str(tmp_path / "ref"), ckpt_every=5)
    ref_out = ref.run(12)
    ref_losses = [m["loss"] for m in ref_out["metrics"]]

    # crashed-and-restarted run
    def make():
        return TrainRunner(cfg, tcfg, dcfg, str(tmp_path / "crash"),
                           ckpt_every=5)

    out = run_with_restarts(make, 12, injector=FailureInjector(fail_at=7))
    # the second attempt resumed from step 5; losses from there must match
    resumed_losses = [m["loss"] for m in out["metrics"]]
    np.testing.assert_allclose(resumed_losses[-5:], ref_losses[-5:],
                               rtol=1e-5)


def test_injector_raises_once():
    inj = FailureInjector(fail_at=3)
    inj(2)
    try:
        inj(3)
        raised = False
    except RuntimeError:
        raised = True
    assert raised
    inj(3)  # second pass does not raise


def test_nan_skip_keeps_params_finite(tmp_path):
    """A poisoned batch must not destroy the parameters."""
    import jax.numpy as jnp
    from repro.models import lm
    from repro.optim import adamw
    from repro.training.step import TrainState, make_train_step

    cfg, tcfg, dcfg = _cfgs(tmp_path)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    state = TrainState(params, adamw.init_state(params))
    step = jax.jit(make_train_step(cfg, tcfg))
    bad = {"tokens": jnp.zeros((4, 16), jnp.int32),
           "labels": jnp.zeros((4, 16), jnp.int32),
           "mask": jnp.full((4, 16), jnp.nan)}
    new_state, metrics = step(state, bad)
    finite = all(bool(jnp.isfinite(l).all())
                 for l in jax.tree.leaves(new_state.params))
    assert finite, "nan_skip must keep parameters finite"
    # and the skipped step leaves params identical
    same = jax.tree.map(lambda a, b: bool((a == b).all()),
                        new_state.params, state.params)
    assert all(jax.tree.leaves(same))
