"""Family-generic paged serving (the CacheSpec registry, PR 4).

Cross-family greedy-identity matrix (hymba hybrid, xlstm ssm, whisper
encoder-decoder, mixtral SWA x full/loki/loki_block), chunked-prefill state
carry for the recurrent families, preemption exactness on a hybrid config,
the sliding-window page-budget bound, and the PagePool double-free guard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import lm
from repro.serving import cache_spec as CS
from repro.serving.engine import Request, ServingEngine
from repro.serving.paged_cache import PagePool
from repro.serving.scheduler import PagedServingEngine


def _cfg(arch, policy):
    cfg = get_smoke_config(arch)
    if policy != "full":
        cfg = cfg.with_policy(policy, k_f=0.5, d_f=0.5, block_size=8,
                              local_window=4, min_k=4)
    return cfg


def _frames(cfg, i):
    if not cfg.is_encoder_decoder:
        return None
    return np.asarray(jax.random.normal(jax.random.PRNGKey(100 + i),
                                        (cfg.enc_seq, cfg.d_model)),
                      np.float32)


def _sequential_dense(params, cfg, prompts, max_new, smax):
    """Ground truth: each prompt served alone by the dense engine."""
    outs = []
    for i, p in enumerate(prompts):
        eng = ServingEngine(params, cfg, n_slots=1, smax=smax)
        r = Request(rid=0, prompt=p.copy(), max_new=max_new,
                    frames=_frames(cfg, i))
        eng.submit(r)
        eng.run_until_done(800)
        outs.append(r.out)
    return outs


# ===================================================================
# Acceptance: every family in configs/ serves through PagedServingEngine
# with greedy output identical to the sequential dense engine
# ===================================================================

FAMILY_MATRIX = [
    ("hymba-1.5b", "full"), ("hymba-1.5b", "loki"),
    ("hymba-1.5b", "loki_block"),
    ("xlstm-125m", "full"),                  # no attention: policy is moot
    ("whisper-small", "full"), ("whisper-small", "loki"),
    ("whisper-small", "loki_block"),
    ("mixtral-8x22b", "full"), ("mixtral-8x22b", "loki"),
    ("mixtral-8x22b", "loki_block"),
]


@pytest.mark.parametrize("arch,policy", FAMILY_MATRIX,
                         ids=[f"{a}-{p}" for a, p in FAMILY_MATRIX])
def test_paged_matches_sequential_dense_across_families(arch, policy):
    cfg = _cfg(arch, policy)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompts = [(np.arange(5 + 3 * i) * 7 + i) % cfg.vocab for i in range(3)]
    truth = _sequential_dense(params, cfg, prompts, max_new=5, smax=48)
    # 3 requests > 2 slots: admission waits, slots recycle, chunked prefill
    # (chunk 4 < prompt lengths) carries StateSlot state across chunks
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=48, page_size=8,
                             prefill_chunk=4)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=5,
                    frames=_frames(cfg, i))
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(800)
    for r, t in zip(reqs, truth):
        assert r.done and r.out == t, (arch, policy, r.rid, r.out, t)
    assert eng.pool.free_pages == eng.pool.n_pages - 1   # everything freed


# ===================================================================
# Chunked-prefill state carry (StateSlot lifecycle)
# ===================================================================

def _chunked_logits(params, cfg, prompt, chunk, smax=32, ps=8):
    n_pages = smax // ps + 2
    cache = lm.init_paged_cache(cfg, n_pages, ps, jnp.float32, n_slots=1)
    table = jnp.arange(1, smax // ps + 1, dtype=jnp.int32)[None]
    lg = None
    for start in range(0, len(prompt), chunk):
        nv = min(chunk, len(prompt) - start)
        buf = np.zeros((1, chunk), np.int32)
        buf[0, :nv] = prompt[start:start + nv]
        lg, cache = lm.prefill_chunk(params, cfg, cache, jnp.asarray(buf),
                                     jnp.int32(start), jnp.int32(nv),
                                     table, ps, slot=jnp.int32(0))
    return lg


@pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
def test_chunked_prefill_carries_recurrent_state(arch):
    """Driving a prompt through fixed-size chunks (with a padded final
    chunk) reproduces the one-shot prefill's last-token logits: the mamba
    conv/ssm and m/s-LSTM states carried across chunks are exact, and pad
    tokens leave them untouched."""
    cfg = get_smoke_config(arch)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompt = (np.arange(19) * 7 + 3) % cfg.vocab
    toks = jnp.asarray(prompt[None].astype(np.int32))
    lg_ref, _, _ = lm.prefill(params, cfg, toks, smax=32,
                              cache_dtype=jnp.float32)
    lg = _chunked_logits(params, cfg, prompt, chunk=4)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               rtol=1e-5, atol=1e-5)


def test_whisper_chunked_prefill_matches_oneshot():
    """Decoder chunks attend the admission-written CrossAttnStatic K/V;
    chunked logits match the one-shot prefill (which writes cross inline)."""
    cfg = get_smoke_config("whisper-small")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompt = (np.arange(13) * 5 + 2) % cfg.vocab
    fr = jnp.asarray(_frames(cfg, 0))[None]
    toks = jnp.asarray(prompt[None].astype(np.int32))
    lg_ref, _, _ = lm.prefill(params, cfg, toks, smax=32, frames=fr,
                              cache_dtype=jnp.float32)

    ps, smax = 8, 32
    cache = lm.init_paged_cache(cfg, smax // ps + 2, ps, jnp.float32,
                                n_slots=1)
    ck, cv = lm.encode_cross_kv(params, cfg, fr)
    cache["layers"]["cross_k"] = ck.astype(jnp.float32)
    cache["layers"]["cross_v"] = cv.astype(jnp.float32)
    table = jnp.arange(1, smax // ps + 1, dtype=jnp.int32)[None]
    lg = None
    for start in range(0, len(prompt), 4):
        nv = min(4, len(prompt) - start)
        buf = np.zeros((1, 4), np.int32)
        buf[0, :nv] = prompt[start:start + nv]
        lg, cache = lm.prefill_chunk(params, cfg, cache, jnp.asarray(buf),
                                     jnp.int32(start), jnp.int32(nv),
                                     table, ps, slot=jnp.int32(0))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_ref),
                               rtol=1e-5, atol=1e-5)


# ===================================================================
# Preemption exactness on a hybrid config (StateSlot recompute)
# ===================================================================

def test_hybrid_preemption_reproduces_greedy_outputs():
    """Memory pressure forces recompute-preemption of hybrid requests whose
    mamba state cannot live in pages: re-admission resets the StateSlot and
    the masked chunked prefill rebuilds it, so the continuation is exact."""
    cfg = get_smoke_config("hymba-1.5b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompts = [(np.arange(9 + i) * 5 + i) % cfg.vocab for i in range(4)]
    truth = _sequential_dense(params, cfg, prompts, max_new=14, smax=32)
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=4, n_pages=6)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=14)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(2000)
    assert eng.n_preempted > 0               # pressure actually materialized
    for r, t in zip(reqs, truth):
        assert r.done and r.out == t, (r.rid, r.out, t)


def test_paged_mid_prefill_slot_state_protected_from_decode():
    """While one hybrid slot decodes, another is mid-prefill: the batched
    decode's ``live`` mask must not advance the prefilling slot's mamba
    state (its K/V already land in the trash page; state has no trash
    row). Staggered submission forces exactly that interleaving."""
    cfg = get_smoke_config("hymba-1.5b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    p1 = (np.arange(6) * 7 + 2) % cfg.vocab
    p2 = (np.arange(17) * 5 + 3) % cfg.vocab
    truth = _sequential_dense(params, cfg, [p1, p2], max_new=6, smax=48)

    eng = PagedServingEngine(params, cfg, n_slots=2, smax=48, page_size=8,
                             prefill_chunk=4)
    r1 = Request(rid=1, prompt=p1.copy(), max_new=6)
    eng.submit(r1)
    for _ in range(2):                 # r1 reaches decode alone
        eng.tick()
    r2 = Request(rid=2, prompt=p2.copy(), max_new=6)
    eng.submit(r2)                     # prefills over several decode ticks
    eng.run_until_done(400)
    assert r1.out == truth[0] and r2.out == truth[1]


# ===================================================================
# Acceptance: SWA page budget — at most ceil(window/page_size)+1 pages
# ===================================================================

def test_mixtral_swa_window_page_budget_and_identity():
    cfg = get_smoke_config("mixtral-8x22b")         # sliding_window=64
    assert cfg.sliding_window == 64
    params = lm.init(jax.random.PRNGKey(0), cfg)
    ps, smax, max_new = 16, 96, 85
    prompt = (np.arange(8) * 3 + 1) % cfg.vocab
    truth = _sequential_dense(params, cfg, [prompt], max_new, smax)[0]

    eng = PagedServingEngine(params, cfg, n_slots=1, smax=smax,
                             page_size=ps, prefill_chunk=8)
    budget = -(-cfg.sliding_window // ps) + 1       # ceil(w/ps)+1 = 5
    assert eng.req_budget == budget < eng.max_pages
    req = Request(rid=0, prompt=prompt.copy(), max_new=max_new)
    eng.submit(req)
    while eng._queue or eng._admit_order:
        eng.tick()
        held = sum(p is not None for p in eng.slot_pages[0])
        assert held <= budget, (eng.ticks, held)    # bound at every instant
    assert req.done and req.out == truth
    # generation walked well past the window: recycling actually happened,
    # and the slot peaked exactly at the spec-table bound, not max_pages
    assert eng.n_recycled_pages > 0
    assert eng.peak_slot_pages == budget
    assert eng.pool.free_pages == eng.pool.n_pages - 1
    # a window model's default pool is sized by the budget, not smax
    assert eng.pool.n_pages - 1 < eng.max_pages * eng.n_slots + 1


def test_swa_recycled_pages_freed_exactly_once():
    """Preempting / finishing a request that recycled pages must not free
    them again (PagePool raises on double-free): run a window model under
    pool pressure so both paths execute."""
    cfg = get_smoke_config("mixtral-8x22b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompts = [(np.arange(6 + i) * 5 + i) % cfg.vocab for i in range(4)]
    truth = _sequential_dense(params, cfg, prompts, max_new=30, smax=96)
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=96, page_size=16,
                             prefill_chunk=4, n_pages=8)   # 7 usable pages
    reqs = [Request(rid=i, prompt=p.copy(), max_new=30)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(3000)                 # double-free would raise here
    for r, t in zip(reqs, truth):
        assert r.done and r.out == t, (r.rid, r.out, t)
    assert eng.pool.free_pages == eng.pool.n_pages - 1


# ===================================================================
# PagePool + registry units
# ===================================================================

def test_page_pool_double_free_raises():
    pool = PagePool(6, 8)
    a = pool.alloc(3)
    pool.free(a[:1])
    with pytest.raises(ValueError, match="double-free"):
        pool.free(a[:1])                     # already back in the free list
    with pytest.raises(ValueError, match="trash"):
        pool.free([0])                       # reserved page
    with pytest.raises(ValueError, match="double-free"):
        pool.free([a[1], a[1]])              # duplicate within one call
    pool.free(a[1:])                         # the legitimate free still works
    assert pool.free_pages == 5


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_spec_registry_covers_every_arch(arch):
    cfg = get_smoke_config(arch)
    specs = CS.layer_specs(cfg)
    assert len(specs) == cfg.n_layers
    ok, _ = CS.pageable(cfg)
    assert ok                                # default policy always serves
    if CS.has_paged_attn(cfg):
        assert not CS.pageable(cfg.with_policy("h2o"))[0]
        assert not CS.pageable(cfg.with_policy("pcaattn"))[0]
    else:
        assert CS.request_page_budget(cfg, 64, 16) == 0
    table = CS.format_spec_table(cfg, 64, 16)
    assert cfg.arch in table and "layer" in table
    if cfg.sliding_window:
        assert CS.recycle_window(cfg) == cfg.sliding_window
        assert (CS.request_page_budget(cfg, 1 << 20, 16)
                == -(-cfg.sliding_window // 16) + 1)


@pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-125m"])
def test_dense_single_token_prompt_resets_stale_state(arch):
    """Regression: a 1-token prompt skips prefill, so the dense engine must
    reset the slot's recurrent state — otherwise the previous occupant's
    mamba/xlstm state leaks into the new request's decode."""
    cfg = get_smoke_config(arch)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    one_tok = np.array([7], np.int32)

    solo = ServingEngine(params, cfg, n_slots=1, smax=48)
    ref = Request(rid=0, prompt=one_tok.copy(), max_new=5)
    solo.submit(ref)
    solo.run_until_done(100)

    eng = ServingEngine(params, cfg, n_slots=1, smax=48)
    warm = Request(rid=1, prompt=(np.arange(12) * 5 + 3) % cfg.vocab,
                   max_new=6)
    eng.submit(warm)
    eng.run_until_done(100)               # leaves state behind in slot 0
    req = Request(rid=2, prompt=one_tok.copy(), max_new=5)
    eng.submit(req)
    eng.run_until_done(100)
    assert req.out == ref.out


def test_paged_engine_requires_frames_for_encdec():
    cfg = get_smoke_config("whisper-small")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = PagedServingEngine(params, cfg, n_slots=1, smax=32, page_size=8)
    with pytest.raises(ValueError, match="frames"):
        eng.submit(Request(rid=0, prompt=np.arange(4), max_new=2))
