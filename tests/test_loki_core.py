"""Core Loki invariants (paper Lemmas 4.1/4.2 + algorithm behaviour) and
property-based tests with hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.configs.base import LokiConfig
from repro.core import pca as PCA
from repro.core.attention import decode_full
from repro.core.baselines import exact_topk_decode, h2o_decode, h2o_init, H2OState
from repro.core.loki import loki_decode, loki_decode_block, loki_decode_chunked


def _setup(b=2, hkv=2, g=2, s=64, d=32, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    h = hkv * g
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    return q, k, v


def _orthogonal(hkv, d, seed=0):
    rng = np.random.RandomState(seed)
    mats = [np.linalg.qr(rng.randn(d, d))[0] for _ in range(hkv)]
    return jnp.asarray(np.stack(mats), jnp.float32)


class TestLemma41:
    """Attention in any orthogonal basis is exact (k_f = d_f = 1)."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_exact_in_rotated_basis(self, seed):
        q, k, v = _setup(seed=seed)
        b, s, hkv, d = k.shape
        proj = _orthogonal(hkv, d, seed)
        k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
        cur = jnp.array([s, s // 2])
        cfg = LokiConfig(d_f=1.0, k_f=1.0, local_window=0, min_k=1)
        got = loki_decode(q, k_hat, v, cur, proj, cfg)
        want = decode_full(q, k, v, cur)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_chunked_exact_at_full_budget(self):
        q, k, v = _setup(s=64)
        b, s, hkv, d = k.shape
        proj = _orthogonal(hkv, d)
        k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
        cur = jnp.array([s, s])
        cfg = LokiConfig(d_f=1.0, k_f=1.0, local_window=0, min_k=1,
                         n_chunks=4)
        got = loki_decode_chunked(q, k_hat, v, cur, proj, cfg)
        want = decode_full(q, k, v, cur)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestLemma42:
    """PCA leading-d scores approximate true scores better than random-d."""

    def test_pca_beats_random_projection(self):
        rng = np.random.RandomState(0)
        d, n = 64, 4096
        # low-rank-ish keys: 8 strong directions + noise
        basis = rng.randn(8, d)
        keys = rng.randn(n, 8) @ basis + 0.1 * rng.randn(n, d)
        cov = np.cov(keys.T)
        proj, eig = PCA.eig_projections(cov[None, None])
        p = proj[0, 0]                         # (d, d)
        q = rng.randn(d)
        true = keys @ q
        d_red = 16
        approx_pca = (keys @ p)[:, :d_red] @ (q @ p)[:d_red]
        r = np.linalg.qr(rng.randn(d, d))[0]
        approx_rand = (keys @ r)[:, :d_red] @ (q @ r)[:d_red]
        assert (np.linalg.norm(true - approx_pca)
                < 0.5 * np.linalg.norm(true - approx_rand))

    def test_rank_at_recovers_low_rank(self):
        rng = np.random.RandomState(1)
        d, n, true_rank = 64, 8192, 8
        keys = rng.randn(n, true_rank) @ rng.randn(true_rank, d)
        keys += 1e-3 * rng.randn(n, d)
        cov = np.cov(keys.T)
        _, eig = PCA.eig_projections(cov[None, None])
        r90 = PCA.rank_at(eig, 0.90)[0, 0]
        assert r90 <= true_rank + 1


class TestSelection:
    def test_loki_selects_planted_token(self):
        """A key identical to the query direction must be selected."""
        q, k, v = _setup(s=64)
        b, s, hkv, d = k.shape
        # plant: key 17 = 10x the query of head (0,0)
        k = k.at[:, 17, 0, :].set(10.0 * q[:, 0, :d])
        proj = jnp.stack([jnp.eye(d)] * hkv)
        cur = jnp.array([s, s])
        cfg = LokiConfig(d_f=0.5, k_f=0.25, local_window=0, min_k=4)
        out = loki_decode(q, k, v, cur, proj, cfg)
        # attention output for head 0 should be dominated by v[17]
        np.testing.assert_allclose(out[:, 0], v[:, 17, 0], rtol=0.2,
                                   atol=0.2)

    def test_exact_topk_upper_bound_consistency(self):
        q, k, v = _setup()
        b, s, hkv, d = k.shape
        cur = jnp.array([s, s])
        cfg = LokiConfig(k_f=1.0, min_k=1, local_window=0)
        got = exact_topk_decode(q, k, v, cur, cfg)
        want = decode_full(q, k, v, cur)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestH2O:
    def test_budget_respected_and_finite(self):
        b, hkv, g, d = 2, 2, 2, 16
        budget = 8
        st_ = h2o_init(b, budget, hkv, d, jnp.float32)
        key = jax.random.PRNGKey(0)
        for step in range(20):
            ks = jax.random.split(jax.random.fold_in(key, step), 3)
            q = jax.random.normal(ks[0], (b, hkv * g, d))
            kn = jax.random.normal(ks[1], (b, hkv, d))
            vn = jax.random.normal(ks[2], (b, hkv, d))
            out, st_ = h2o_decode(q, kn, vn, st_, jnp.full((b,), step))
            assert bool(jnp.isfinite(out).all())
        assert st_.k.shape[1] == budget
        assert int(st_.fill.max()) <= budget
        # all slots live after 20 > 8 steps
        assert bool((st_.pos >= 0).all())


@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([32, 64, 128]),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([16, 32]),
    kf=st.sampled_from([0.25, 0.5, 1.0]),
    df=st.sampled_from([0.25, 0.5, 1.0]),
    seed=st.integers(0, 10_000),
)
def test_property_loki_output_is_convex_combination(s, hkv, g, d, kf, df,
                                                    seed):
    """Loki's output per head lies in the convex hull of the values (modulo
    fp error): ||out|| <= max_s ||v_s|| and output is finite."""
    q, k, v = _setup(b=1, hkv=hkv, g=g, s=s, d=d, seed=seed % 64)
    proj = _orthogonal(hkv, d, seed % 17)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s])
    cfg = LokiConfig(d_f=df, k_f=kf, local_window=0, min_k=1)
    out = loki_decode(q, k_hat, v, cur, proj, cfg)
    assert bool(jnp.isfinite(out).all())
    vmax = float(jnp.abs(v).max())
    assert float(jnp.abs(out).max()) <= vmax + 1e-4


@settings(max_examples=20, deadline=None)
@given(
    nc=st.sampled_from([2, 4, 8]),
    s=st.sampled_from([64, 128]),
    seed=st.integers(0, 1000),
)
def test_property_chunked_equals_global_at_full_k(nc, s, seed):
    q, k, v = _setup(b=1, s=s, seed=seed % 32)
    b, _, hkv, d = k.shape
    proj = _orthogonal(hkv, d, seed % 7)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s])
    cfg = LokiConfig(d_f=1.0, k_f=1.0, local_window=0, min_k=1, n_chunks=nc)
    got = loki_decode_chunked(q, k_hat, v, cur, proj, cfg)
    want = decode_full(q, k, v, cur)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_pca_calibration_end_to_end():
    """Streaming covariance + eigh recovers orthogonal projections, and
    identity calibration matches the identity transform."""
    st_ = PCA.KeyStats.create(2, 2, 16)
    rng = np.random.RandomState(0)
    for _ in range(3):
        st_.update(rng.randn(2, 2, 8, 2, 16))
    cov = st_.covariance()
    proj, eig = PCA.eig_projections(cov)
    # columns orthonormal
    for l in range(2):
        for h in range(2):
            p = proj[l, h]
            np.testing.assert_allclose(p.T @ p, np.eye(16), atol=1e-4)
    assert eig.shape == (2, 2, 16)
    np.testing.assert_allclose(eig.sum(-1), 1.0, atol=1e-5)
    # descending
    assert (np.diff(eig) <= 1e-7).all()
