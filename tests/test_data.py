"""Synthetic data pipeline: determinism, host sharding, resume."""
import numpy as np

from _hyp import given, settings, st

from repro.data.synthetic import DataConfig, SyntheticLM


CFG = DataConfig(vocab=64, seq_len=32, global_batch=8, seed=3)


def test_deterministic_per_step():
    d1, d2 = SyntheticLM(CFG), SyntheticLM(CFG)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    b = SyntheticLM(CFG).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_steps_differ():
    d = SyntheticLM(CFG)
    assert not np.array_equal(d.batch_at(0)["tokens"],
                              d.batch_at(1)["tokens"])


def test_host_sharding_partitions_batch():
    d = SyntheticLM(CFG)
    h0 = d.batch_at(5, host=0, n_hosts=2)
    h1 = d.batch_at(5, host=1, n_hosts=2)
    assert h0["tokens"].shape[0] == CFG.global_batch // 2
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_iterator_resume_matches_batch_at():
    d = SyntheticLM(CFG)
    it = d.iterate(start_step=11)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], d.batch_at(11)["tokens"])


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000), seed=st.integers(0, 1000))
def test_property_tokens_in_vocab(step, seed):
    cfg = DataConfig(vocab=32, seq_len=16, global_batch=2, seed=seed)
    b = SyntheticLM(cfg).batch_at(step)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 32


def test_long_range_copy_structure():
    """Every `period` tokens the stream copies t-period — the structure that
    gives top-k selection signal."""
    cfg = DataConfig(vocab=512, seq_len=128, global_batch=4, seed=0)
    d = SyntheticLM(cfg)
    toks = d.batch_at(0)["tokens"]
    p = d.period
    hits = sum(int((toks[:, t] == toks[:, t - p]).mean() > 0.9)
               for t in range(p, cfg.seq_len, p))
    assert hits >= (cfg.seq_len - p) // p