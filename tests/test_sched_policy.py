"""Policy-driven scheduler phases (PR 5): FIFO vs priority admission,
priority preemption for a slot, per-tick prefill/decode token budgets,
and StateSlot snapshot-on-preemption (restore for pure-state families,
recompute fallback for hybrids)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving.engine import Request, ServingEngine
from repro.serving.policy import (FifoPolicy, PriorityPolicy, TickBudget,
                                  make_policy)
from repro.serving.scheduler import PagedServingEngine


def _model(arch="qwen2.5-3b"):
    cfg = get_smoke_config(arch)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _solo(params, cfg, prompt, max_new, smax=48):
    eng = ServingEngine(params, cfg, n_slots=1, smax=smax)
    r = Request(rid=0, prompt=prompt.copy(), max_new=max_new)
    eng.submit(r)
    eng.run_until_done(500)
    return r.out


def test_make_policy_and_keys():
    assert isinstance(make_policy("fifo"), FifoPolicy)
    assert isinstance(make_policy("priority"), PriorityPolicy)
    p = make_policy(PriorityPolicy())
    assert isinstance(p, PriorityPolicy)
    with pytest.raises(ValueError, match="unknown scheduler policy"):
        make_policy("lifo")
    hi = Request(rid=0, prompt=np.arange(3), max_new=1, priority=2)
    lo = Request(rid=1, prompt=np.arange(3), max_new=1, priority=0)
    assert p.sort_key(hi, 5) < p.sort_key(lo, 0)       # class beats arrival
    f = make_policy("fifo")
    assert f.sort_key(hi, 5) > f.sort_key(lo, 0)       # FIFO ignores class


def test_priority_admission_order_single_slot():
    """Both waiting before the first tick: priority admits the urgent one
    first even though it was submitted second; FIFO keeps arrival order.
    Outputs stay exact either way."""
    params, cfg = _model()
    p_lo = (np.arange(6) * 7 + 2) % cfg.vocab
    p_hi = (np.arange(9) * 5 + 3) % cfg.vocab
    solo_lo = _solo(params, cfg, p_lo, 4)
    solo_hi = _solo(params, cfg, p_hi, 4)

    firsts = {}
    for pol in ("fifo", "priority"):
        eng = PagedServingEngine(params, cfg, n_slots=1, smax=48,
                                 page_size=8, prefill_chunk=4, policy=pol)
        lo = Request(rid=0, prompt=p_lo.copy(), max_new=4, priority=0)
        hi = Request(rid=1, prompt=p_hi.copy(), max_new=4, priority=1)
        eng.submit(lo)
        eng.submit(hi)
        eng.run_until_done(400)
        assert lo.out == solo_lo and hi.out == solo_hi, pol
        firsts[pol] = (lo.t_first, hi.t_first)
    assert firsts["fifo"][0] < firsts["fifo"][1]       # arrival order
    assert firsts["priority"][1] < firsts["priority"][0]


def test_priority_preempts_running_lower_class_for_slot():
    """A strictly-more-urgent arrival takes the only slot mid-decode; the
    preempted request is folded, requeued and finishes exactly."""
    params, cfg = _model()
    p_lo = (np.arange(7) * 7 + 2) % cfg.vocab
    p_hi = (np.arange(5) * 5 + 3) % cfg.vocab
    solo_lo = _solo(params, cfg, p_lo, 10)
    solo_hi = _solo(params, cfg, p_hi, 4)

    eng = PagedServingEngine(params, cfg, n_slots=1, smax=48, page_size=8,
                             prefill_chunk=4, policy="priority")
    lo = Request(rid=0, prompt=p_lo.copy(), max_new=10, priority=0)
    eng.submit(lo)
    for _ in range(5):                   # lo reaches mid-decode
        eng.tick()
    assert lo.out and not lo.done
    hi = Request(rid=1, prompt=p_hi.copy(), max_new=4, priority=1)
    eng.submit(hi)
    eng.tick()
    assert eng.n_preempted >= 1
    assert eng.slot_req[0] is hi         # hi owns the slot now
    eng.run_until_done(500)
    assert hi.done and hi.out == solo_hi
    assert lo.done and lo.out == solo_lo
    assert hi.t_done < lo.t_done


def test_fifo_never_preempts_for_admission():
    params, cfg = _model()
    eng = PagedServingEngine(params, cfg, n_slots=1, smax=48, page_size=8,
                             prefill_chunk=4, policy="fifo")
    lo = Request(rid=0, prompt=(np.arange(6) * 7 + 2) % cfg.vocab,
                 max_new=8, priority=0)
    eng.submit(lo)
    for _ in range(4):
        eng.tick()
    hi = Request(rid=1, prompt=(np.arange(5) * 5 + 3) % cfg.vocab,
                 max_new=4, priority=9)
    eng.submit(hi)
    eng.run_until_done(400)
    assert eng.n_preempted == 0
    assert lo.t_done < hi.t_done         # arrival order held


# ===================================================================
# Per-tick token budgets
# ===================================================================

def test_prefill_budget_spends_multiple_chunks_per_tick():
    """budget >= whole prompt: admission + all chunks + first decode in
    one tick. The default budget (one chunk) takes several ticks."""
    params, cfg = _model()
    prompt = (np.arange(17) * 7 + 3) % cfg.vocab       # 16 prefill tokens

    eng = PagedServingEngine(params, cfg, n_slots=1, smax=48, page_size=8,
                             prefill_chunk=4, prefill_budget=16)
    assert eng.budget == TickBudget(prefill_tokens=16, decode_tokens=1)
    r = Request(rid=0, prompt=prompt.copy(), max_new=3)
    eng.submit(r)
    eng.tick()
    assert len(r.out) == 1               # prefilled AND decoded in tick 0
    eng.run_until_done(200)

    slow = PagedServingEngine(params, cfg, n_slots=1, smax=48, page_size=8,
                              prefill_chunk=4)        # legacy: one chunk
    r2 = Request(rid=1, prompt=prompt.copy(), max_new=3)
    slow.submit(r2)
    slow.tick()
    assert not r2.out and slow._prefill_at[0] == 4
    slow.run_until_done(200)
    assert r2.out == r.out               # schedule never changes tokens


def test_prefill_budget_shares_one_tick_across_waiting_prompts():
    params, cfg = _model()
    prompts = [(np.arange(9 + i) * 7 + i) % cfg.vocab for i in range(2)]
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=48, page_size=8,
                             prefill_chunk=8, prefill_budget=32)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=2)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.tick()                           # both prompts prefill this tick
    assert all(len(r.out) == 1 for r in reqs)
    eng.run_until_done(100)
    truth = [_solo(params, cfg, p, 2) for p in prompts]
    assert [r.out for r in reqs] == truth


def test_decode_budget_round_robins_and_stays_exact():
    """decode_tokens=1 with two live streams: slots alternate (neither
    starves) and per-slot positions keep both streams bit-exact."""
    params, cfg = _model()
    prompts = [(np.arange(5 + 3 * i) * 7 + i) % cfg.vocab for i in range(2)]
    truth = [_solo(params, cfg, p, 6) for p in prompts]
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=48, page_size=8,
                             prefill_chunk=8, decode_budget=1)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    done_tick = {}
    for _ in range(400):
        if all(r.done for r in reqs):
            break
        eng.tick()
        for r in reqs:
            if r.done and r.rid not in done_tick:
                done_tick[r.rid] = eng.ticks
    assert [r.out for r in reqs] == truth
    # 12 generated tokens at 1/tick: the streams alternate, so the two
    # requests finish within a couple of ticks of each other — a drain
    # that starved one slot until the other finished would leave a gap of
    # at least max_new ticks
    assert eng.ticks >= 12
    assert abs(done_tick[0] - done_tick[1]) <= 3, done_tick


# ===================================================================
# StateSlot snapshot-on-preemption (xlstm host-snapshot restore; hymba
# restores onto retained private pages), greedy-identity parity
# ===================================================================

def test_xlstm_priority_preemption_restores_snapshot():
    """Pure-state family: preemption snapshots the recurrent state to
    host; re-admission restores it instead of re-running the folded
    prompt, and the continuation is bit-identical."""
    params, cfg = _model("xlstm-125m")
    p_lo = (np.arange(13) * 7 + 2) % cfg.vocab
    p_hi = (np.arange(5) * 5 + 3) % cfg.vocab
    solo_lo = _solo(params, cfg, p_lo, 10)
    solo_hi = _solo(params, cfg, p_hi, 4)

    eng = PagedServingEngine(params, cfg, n_slots=1, smax=48, page_size=8,
                             prefill_chunk=4, policy="priority")
    lo = Request(rid=0, prompt=p_lo.copy(), max_new=10, priority=0)
    eng.submit(lo)
    for _ in range(6):                   # lo is mid-decode
        eng.tick()
    assert lo.out and not lo.done
    hi = Request(rid=1, prompt=p_hi.copy(), max_new=4, priority=1)
    eng.submit(hi)
    eng.run_until_done(500)
    assert eng.n_preempted >= 1
    assert eng.n_state_restores >= 1     # restore path actually ran
    assert hi.done and hi.out == solo_hi
    assert lo.done and lo.out == solo_lo


def test_xlstm_mid_prefill_preemption_restores_partial_state():
    """Preempting a slot that is still prefilling snapshots the state at
    its chunk boundary; re-admission resumes from that token, not from
    scratch — and stays exact."""
    params, cfg = _model("xlstm-125m")
    p_lo = (np.arange(21) * 7 + 2) % cfg.vocab         # 20 prefill tokens
    p_hi = (np.arange(4) * 5 + 3) % cfg.vocab
    solo_lo = _solo(params, cfg, p_lo, 5)
    solo_hi = _solo(params, cfg, p_hi, 3)

    eng = PagedServingEngine(params, cfg, n_slots=1, smax=48, page_size=8,
                             prefill_chunk=4, policy="priority")
    lo = Request(rid=0, prompt=p_lo.copy(), max_new=5, priority=0)
    eng.submit(lo)
    eng.tick()                           # one chunk in, still prefilling
    assert 0 in eng._prefill_at and not lo.out
    hi = Request(rid=1, prompt=p_hi.copy(), max_new=3, priority=1)
    eng.submit(hi)
    eng.run_until_done(500)
    assert eng.n_preempted >= 1 and eng.n_state_restores >= 1
    assert lo.done and lo.out == solo_lo
    assert hi.done and hi.out == solo_hi
    # restore resumed mid-prompt: the re-run never recomputed the tokens
    # the snapshot had already folded in
    assert eng.n_prefill_computed_tokens < 2 * (len(p_lo) - 1)


def test_hymba_priority_preemption_restores_retained_pages():
    """Hybrid (StateSlot + PagedAttn): preemption parks the slot's K/V
    pages as private pool entries alongside the state snapshot, so
    re-admission restores both instead of recomputing — and the
    continuation stays exact. (Pressure-driven retention is covered in
    tests/test_page_layout.py; this pins the priority-preemption path.)"""
    params, cfg = _model("hymba-1.5b")
    p_lo = (np.arange(9) * 7 + 2) % cfg.vocab
    p_hi = (np.arange(5) * 5 + 3) % cfg.vocab
    solo_lo = _solo(params, cfg, p_lo, 8)
    solo_hi = _solo(params, cfg, p_hi, 3)

    eng = PagedServingEngine(params, cfg, n_slots=1, smax=48, page_size=8,
                             prefill_chunk=4, policy="priority")
    lo = Request(rid=0, prompt=p_lo.copy(), max_new=8, priority=0)
    eng.submit(lo)
    for _ in range(5):
        eng.tick()
    hi = Request(rid=1, prompt=p_hi.copy(), max_new=3, priority=1)
    eng.submit(hi)
    eng.run_until_done(500)
    assert eng.n_preempted >= 1
    assert eng.n_state_restores >= 1     # restore, no longer recompute
    assert lo.done and lo.out == solo_lo
    assert hi.done and hi.out == solo_hi
