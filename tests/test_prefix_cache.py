"""Prefix caching over the refcounted PagePool (PR 5).

Greedy-identity matrix with the cache on/off across families × policies
(shareable dense llama2, auto-bypassed mixtral-SWA and hymba), COW
divergence at the shared tail page, LRU eviction of cached pages *before*
any preemption, hit-rate counters, preemption exactness under sharing,
and the PagePool refcount/accounting hardening."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving import cache_spec as CS
from repro.serving import paged_cache as PC
from repro.serving.engine import Request
from repro.serving.paged_cache import PagePool
from repro.serving.scheduler import PagedServingEngine


def _cfg(arch, policy):
    cfg = get_smoke_config(arch)
    if policy != "full":
        cfg = cfg.with_policy(policy, k_f=0.5, d_f=0.5, block_size=8,
                              local_window=4, min_k=4)
    return cfg


def _serve(params, cfg, prompts, *, cache, max_new=4, smax=64, n_slots=2,
           n_pages=None, **kw):
    eng = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                             page_size=8, prefill_chunk=8, n_pages=n_pages,
                             prefix_cache=cache, **kw)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(3000)
    assert all(r.done for r in reqs)
    return eng, [r.out for r in reqs]


# ===================================================================
# Acceptance: greedy outputs bit-identical with the cache on vs off
# (shareable families actually hit; unshareable families bypass)
# ===================================================================

MATRIX = [(a, p)
          for a in ("llama2-7b", "mixtral-8x22b", "hymba-1.5b")
          for p in ("full", "loki", "loki_block")]


@pytest.mark.parametrize("arch,policy", MATRIX,
                         ids=[f"{a}-{p}" for a, p in MATRIX])
def test_prefix_cache_identity_matrix(arch, policy):
    cfg = _cfg(arch, policy)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    base = (np.arange(24) * 11 + 3) % cfg.vocab        # shared system prompt
    prompts = [np.concatenate([base,
                               (np.arange(5 + i) * 7 + 2 + i) % cfg.vocab])
               for i in range(3)]
    eng_on, outs_on = _serve(params, cfg, prompts, cache=True)
    eng_off, outs_off = _serve(params, cfg, prompts, cache=False)
    assert outs_on == outs_off, (arch, policy, outs_on, outs_off)
    if CS.prefix_shareable(cfg)[0]:
        # 3 requests > 2 slots: the late admission sees the registered base
        assert eng_on.prefix_caching
        assert eng_on.n_prefix_hit_tokens >= 24
        assert (eng_on.n_prefill_computed_tokens
                < eng_off.n_prefill_computed_tokens)
    else:
        # hymba (StateSlot) / mixtral (WindowPagedAttn): transparent bypass
        assert not eng_on.prefix_caching
        assert eng_on.n_prefix_hit_tokens == 0
    assert eng_off.n_prefix_hit_tokens == 0


def test_unshareable_reasons_name_the_component():
    ok, _ = CS.prefix_shareable(get_smoke_config("llama2-7b"))
    assert ok
    for arch, frag in [("mixtral-8x22b", "WindowPagedAttn"),
                       ("hymba-1.5b", "StateSlot"),
                       ("whisper-small", "CrossAttnStatic"),
                       ("xlstm-125m", "no paged-attention")]:
        ok, why = CS.prefix_shareable(get_smoke_config(arch))
        assert not ok and frag in why, (arch, why)


# ===================================================================
# COW divergence at the shared tail page
# ===================================================================

def test_cow_divergence_at_tail_page():
    """B's prompt matches A's first 20 tokens: pages 0-1 fully, page 2
    only rows 0-3 (the partial tail). A is still decoding — it reads page
    2 every step — so B must copy-on-write it before prefilling its own
    tokens, leaving the donor intact: A's continuation is unchanged and a
    later rerun of A's prompt still full-hits A's registered pages."""
    cfg = _cfg("llama2-7b", "full")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    a = (np.arange(33) * 11 + 3) % cfg.vocab           # n_pre=32: 4 pages
    b = np.concatenate([a[:20], (np.arange(12) * 13 + 7) % cfg.vocab])
    solo_a = _serve(params, cfg, [a], cache=False, n_slots=1, max_new=16,
                    smax=64)[1][0]
    solo_b = _serve(params, cfg, [b], cache=False, n_slots=1, max_new=4,
                    smax=64)[1][0]

    eng = PagedServingEngine(params, cfg, n_slots=2, smax=64, page_size=8,
                             prefill_chunk=8, prefix_cache=True)
    ra = Request(rid=0, prompt=a.copy(), max_new=16)
    eng.submit(ra)
    while not eng.live.any():                          # a fully prefilled,
        eng.tick()                                     # pages registered
    rb = Request(rid=1, prompt=b.copy(), max_new=4)
    eng.submit(rb)                                     # shares live a's tail
    eng.run_until_done(400)
    assert ra.done and rb.done

    assert eng.n_cow_copies == 1                       # b diverged mid-page
    assert ra.out == solo_a                            # donor unperturbed
    assert rb.out == solo_b

    rerun = Request(rid=2, prompt=a.copy(), max_new=16)
    eng.submit(rerun)
    eng.run_until_done(400)
    assert rerun.out == solo_a
    assert eng.n_prefix_hit_tokens >= 20 + 32          # b's 20 + rerun's 32


def test_cow_sole_reader_takes_ownership_without_copy():
    """When the donor request already finished (the tail page is cached
    but nobody else references it), COW degenerates to taking ownership:
    the index entry is dropped, no copy is paid, and outputs stay exact."""
    cfg = _cfg("llama2-7b", "full")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    a = (np.arange(33) * 11 + 3) % cfg.vocab
    b = np.concatenate([a[:20], (np.arange(12) * 13 + 7) % cfg.vocab])

    eng = PagedServingEngine(params, cfg, n_slots=1, smax=64, page_size=8,
                             prefill_chunk=8, prefix_cache=True)
    outs = []
    for i, p in enumerate([a, b]):                     # sequential: a done
        r = Request(rid=i, prompt=p.copy(), max_new=4)
        eng.submit(r)
        eng.run_until_done(300)
        assert r.done
        outs.append(r.out)
    assert eng.n_cow_copies == 0                       # ownership, no copy
    assert eng.n_prefix_hit_tokens >= 20

    _, outs_off = _serve(params, cfg, [a, b], cache=False, n_slots=1)
    assert outs == outs_off


# ===================================================================
# Eviction ordering: LRU cached pages are reclaimed BEFORE preemption
# ===================================================================

def test_eviction_under_pressure_before_preemption():
    cfg = _cfg("llama2-7b", "full")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=8, n_pages=9,  # 8 usable pages
                             prefix_cache=True)
    warm = Request(rid=0, prompt=(np.arange(17) * 11 + 3) % cfg.vocab,
                   max_new=2)
    eng.submit(warm)
    eng.run_until_done(200)
    assert eng.pool.cached_pages >= 2                  # warm's full pages

    # two fresh-prefix requests that together need every usable page: the
    # pool must reclaim warm's cached pages, not preempt anybody
    prompts = [(np.arange(20) * 7 + 5 + i) % cfg.vocab for i in range(2)]
    reqs = [Request(rid=1 + i, prompt=p.copy(), max_new=12)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(400)
    assert all(r.done for r in reqs)
    assert eng.pool.n_evicted >= 1
    assert eng.n_preempted == 0

    _, outs_off = _serve(params, cfg, prompts, cache=False, max_new=12,
                         smax=32)
    assert [r.out for r in reqs] == outs_off


def test_preemption_with_shared_pages_stays_exact():
    """Tight pool + shared prefixes: preemption releases references and
    never frees shared pages out from under their other readers; greedy
    outputs match the cache-off run (which preempts too)."""
    cfg = _cfg("llama2-7b", "full")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    base = (np.arange(16) * 5 + 1) % cfg.vocab
    prompts = [np.concatenate([base, (np.arange(3 + i) * 7 + i) % cfg.vocab])
               for i in range(4)]
    eng_on, outs_on = _serve(params, cfg, prompts, cache=True, max_new=14,
                             smax=32, n_pages=6, admission="lenient")
    eng_off, outs_off = _serve(params, cfg, prompts, cache=False,
                               max_new=14, smax=32, n_pages=6,
                               admission="lenient")
    assert eng_on.n_preempted > 0 and eng_off.n_preempted > 0
    assert outs_on == outs_off
    # every reference was returned: nothing is still marked in use
    assert eng_on.pool.used_pages == 0
    assert (eng_on.pool.free_pages + eng_on.pool.cached_pages
            == eng_on.pool.n_pages - 1)


# ===================================================================
# Hit-rate counters
# ===================================================================

def test_hit_rate_counters_shared_system_prompt():
    cfg = _cfg("llama2-7b", "full")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    base = (np.arange(24) * 11 + 3) % cfg.vocab
    eng = PagedServingEngine(params, cfg, n_slots=1, smax=64, page_size=8,
                             prefill_chunk=8, prefix_cache=True)
    for i in range(3):                       # sequential: later ones hit
        tail = (np.arange(4) * 7 + i) % cfg.vocab
        r = Request(rid=i, prompt=np.concatenate([base, tail]), max_new=3)
        eng.submit(r)
        eng.run_until_done(200)
        assert r.done
    assert eng.pool.n_lookups == 3
    assert eng.pool.n_hits == 2                        # first one misses
    assert eng.n_prefix_hit_tokens >= 2 * 24
    assert 0.0 < eng.prefix_hit_rate() < 1.0
    assert eng.pool.used_pages == 0                    # all refs returned


# ===================================================================
# PagePool hardening: refcounts, empty spans, accounting, matching
# ===================================================================

def test_page_pool_refcount_hardening():
    pool = PagePool(6, 8)
    free0 = pool.free_pages
    assert pool.alloc(0) == [] and pool.free_pages == free0
    assert pool.acquire([]) == []
    a = pool.alloc(2)
    pool.acquire([a[0]])                               # refcount 2
    pool.release([a[0]])
    pool.release([a[0]])                               # back to the pool
    with pytest.raises(ValueError, match="double-free"):
        pool.release([a[0]])                           # below zero raises
    with pytest.raises(ValueError, match="double-free"):
        pool.release([a[1], a[1]])                     # underflow in one call
    with pytest.raises(ValueError, match="unheld"):
        pool.acquire([a[0]])                           # free page: no owner
    with pytest.raises(ValueError, match="trash"):
        pool.acquire([PC.TRASH_PAGE])
    pool.release([a[1]])


def test_page_pool_cached_accounting_and_lru_eviction():
    pool = PagePool(6, 4)                              # 5 usable pages
    held = pool.alloc(2)
    k0 = pool.register(held[0], PC.ROOT_KEY, np.arange(4))
    pool.register(held[1], k0, np.arange(4, 8))
    assert pool.used_pages == 2 and pool.cached_pages == 0
    pool.release(held)                                 # registered -> LRU
    assert pool.used_pages == 0
    assert pool.cached_pages == 2 and pool.free_pages == 3
    assert pool.available_pages == 5
    got = pool.alloc(4)                                # forces one eviction
    assert len(got) == 4 and pool.n_evicted == 1
    with pytest.raises(ValueError, match="full page"):
        pool.register(got[0], PC.ROOT_KEY, np.arange(3))


def test_page_pool_match_prefix_chain_and_partial_tail():
    pool = PagePool(8, 4)
    toks = np.arange(12, dtype=np.int32)
    held = pool.alloc(3)
    k = PC.ROOT_KEY
    for i, p in enumerate(held):
        k = pool.register(p, k, toks[4 * i:4 * i + 4])
    pool.release(held)

    pages, n, tail, _ = pool.match_prefix(toks, 12)    # exact full-page hit
    assert pages == held and n == 12 and not tail
    pool.release(pages)

    q = np.concatenate([toks[:10], [99, 98]]).astype(np.int32)
    pages, n, tail, _ = pool.match_prefix(q, 12)       # diverges mid-page 2
    assert pages == held and n == 10 and tail
    pool.release(pages)

    miss, n, tail, _ = pool.match_prefix(q + 1, 12)    # different page 0
    assert miss == [] and n == 0 and not tail
    assert pool.n_lookups == 3 and pool.n_hits == 2
