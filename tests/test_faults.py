"""Deterministic fault injection + the invariant auditor
(serving/faults.py): plan determinism, every fault site's degradation
path, load shedding under sustained pressure, and the chaos acceptance
matrix (DONE outputs bit-identical to a fault-free run)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import dispatch
from repro.models import lm
from repro.serving import faults as FI
from repro.serving import lifecycle as LC
from repro.serving.engine import Request
from repro.serving.lifecycle import Status
from repro.serving.scheduler import PagedServingEngine


def _model():
    cfg = get_smoke_config("qwen2.5-3b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _reqs(cfg, n, max_new, base=5, salt=0):
    return [Request(rid=i,
                    prompt=(np.arange(base + 2 * i) * 7 + i + salt)
                    % cfg.vocab,
                    max_new=max_new)
            for i in range(n)]


def _pool_at_baseline(eng):
    free = len(eng.pool.free_page_ids()) + len(eng.pool.lru_page_ids())
    return free == eng.pool.n_pages - 1


# ===================================================================
# FaultPlan (pure)
# ===================================================================


def test_fault_plan_is_deterministic_and_seeded():
    a = FI.FaultPlan(seed=3, rates={"alloc_fail": 0.3})
    b = FI.FaultPlan(seed=3, rates={"alloc_fail": 0.3})
    c = FI.FaultPlan(seed=4, rates={"alloc_fail": 0.3})
    fires = []
    for plan in (a, b, c):
        f = []
        for t in range(64):
            plan.advance(t)
            f.append(plan.hit("alloc_fail"))
        fires.append(f)
    assert fires[0] == fires[1]              # same seed: identical
    assert fires[0] != fires[2]              # seed matters
    assert 0 < sum(fires[0]) < 64            # rate neither 0 nor 1


def test_fault_plan_point_schedule_and_counts():
    plan = FI.FaultPlan(at={"nan_logits": {(5, 1)}, "kernel_fail": {7}})
    plan.advance(5)
    assert plan.hit("nan_logits", 1)
    assert plan.hit("nan_logits", 1)         # consulted twice...
    assert not plan.hit("nan_logits", 0)     # wrong unit
    plan.advance(7)
    assert plan.hit("kernel_fail")           # bare tick: any unit
    assert plan.hit("kernel_fail", 3)
    assert plan.counts["nan_logits"] == 1    # ...counted once
    assert plan.counts["kernel_fail"] == 2   # two distinct units


def test_fault_plan_parse_round_trip_and_validation():
    plan = FI.FaultPlan.parse("seed=9,nan_logits=0.05,slot_corrupt@17")
    assert plan.seed == 9
    assert plan.rates == {"nan_logits": 0.05}
    assert plan.at == {"slot_corrupt": {17}}
    assert FI.FaultPlan.parse(plan.describe()).describe() == plan.describe()
    with pytest.raises(ValueError, match="unknown fault site"):
        FI.FaultPlan(rates={"bogus": 0.5})
    with pytest.raises(ValueError, match="bad fault term"):
        FI.FaultPlan.parse("nan_logits")


# ===================================================================
# Auditor catches silent corruption (slot_corrupt site)
# ===================================================================


def test_auditor_catches_injected_slot_corruption():
    """slot_corrupt silently repoints a slot's tail page entry; nothing
    crashes on its own — the per-tick auditor must turn it into a loud
    AuditError at that very tick."""
    params, cfg = _model()
    plan = FI.FaultPlan(at={"slot_corrupt": {2}})
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=4, faults=plan, audit=True)
    for r in _reqs(cfg, 2, 8):
        eng.submit(r)
    with pytest.raises(FI.AuditError, match=r"invariant [BCE]"):
        eng.drain(max_ticks=50)
    assert plan.counts["slot_corrupt"] >= 1


def test_auditor_green_on_healthy_engine():
    params, cfg = _model()
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=4, audit=True)
    reqs = _reqs(cfg, 4, 6)
    for r in reqs:
        eng.submit(r)
    eng.drain(max_ticks=300)                 # audits every tick
    assert all(r.done for r in reqs)
    FI.audit_engine(eng)                     # and once more after drain


# ===================================================================
# NaN quarantine (nan_logits site)
# ===================================================================


def test_nan_logits_quarantines_one_slot_not_the_batch():
    """Poisoning one slot's logits FAILs that request alone; every other
    request finishes DONE with output bit-identical to a fault-free run."""
    params, cfg = _model()
    clean = _reqs(cfg, 4, 6)
    base = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                              prefill_chunk=4)
    for r in clean:
        base.submit(r)
    base.drain(max_ticks=300)
    truth = {r.rid: r.out for r in clean}

    plan = FI.FaultPlan(at={"nan_logits": {(3, 0)}})   # slot 0, tick 3
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=4, faults=plan, audit=True)
    reqs = _reqs(cfg, 4, 6)
    for r in reqs:
        eng.submit(r)
    eng.drain(max_ticks=300)
    failed = [r for r in reqs if r.status is Status.FAILED]
    assert len(failed) == 1
    assert "non-finite" in failed[0].detail
    assert eng.n_quarantined == 1
    for r in reqs:
        if r.done:
            assert r.out == truth[r.rid], r.rid
    assert sum(r.done for r in reqs) == 3
    assert _pool_at_baseline(eng)


def test_nan_guard_off_lets_poison_through():
    """nan_guard=False preserves the old behavior (the NaN row samples
    *something*) — the guard, not luck, is what contains the blast."""
    params, cfg = _model()
    plan = FI.FaultPlan(at={"nan_logits": {(3, 0)}})
    eng = PagedServingEngine(params, cfg, n_slots=1, smax=32, page_size=8,
                             prefill_chunk=4, faults=plan, nan_guard=False)
    req = _reqs(cfg, 1, 6)[0]
    eng.submit(req)
    eng.drain(max_ticks=100)
    assert req.done and eng.n_quarantined == 0


# ===================================================================
# Pool faults (alloc_fail / pool_exhaustion): degrade, don't corrupt
# ===================================================================


@pytest.mark.parametrize("site,rate", [("alloc_fail", 0.25),
                                       ("pool_exhaustion", 0.6)])
def test_pool_faults_degrade_gracefully(site, rate):
    """Transient allocation failures slow serving down (retries and
    preemptions) but every request still finishes DONE with bit-identical
    output, the auditor green throughout."""
    params, cfg = _model()
    clean = _reqs(cfg, 4, 8)
    base = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                              prefill_chunk=4)
    for r in clean:
        base.submit(r)
    base.drain(max_ticks=500)
    truth = {r.rid: r.out for r in clean}

    plan = FI.FaultPlan(seed=5, rates={site: rate})
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=4, faults=plan, audit=True)
    reqs = _reqs(cfg, 4, 8)
    for r in reqs:
        eng.submit(r)
    eng.drain(max_ticks=2000)
    assert plan.counts[site] >= 1, "fault never actually fired"
    for r in reqs:
        assert r.done and r.out == truth[r.rid], (r.rid, str(r.status))
    assert _pool_at_baseline(eng)


# ===================================================================
# Backend fallback (kernel_fail site)
# ===================================================================


def test_kernel_fail_falls_back_to_xla_and_keeps_serving():
    """A fused-Pallas decode failure disables the backend process-wide
    (core/dispatch.py), the engine re-jits onto the XLA path mid-stream,
    and the stream finishes with the outputs an all-XLA engine produces."""
    params, cfg = _model()
    dispatch.enable_backend("pallas")
    try:
        ref = PagedServingEngine(params, cfg, n_slots=2, smax=32,
                                 page_size=8, prefill_chunk=4,
                                 backend="xla")
        clean = _reqs(cfg, 3, 8)
        for r in clean:
            ref.submit(r)
        ref.drain(max_ticks=300)
        truth = {r.rid: r.out for r in clean}

        plan = FI.FaultPlan(at={"kernel_fail": {4}})
        eng = PagedServingEngine(params, cfg, n_slots=2, smax=32,
                                 page_size=8, prefill_chunk=4,
                                 backend="pallas", faults=plan, audit=True)
        reqs = _reqs(cfg, 3, 8)
        for r in reqs:
            eng.submit(r)
        eng.drain(max_ticks=300)
        assert eng.n_backend_fallbacks == 1
        assert dispatch.backend_disabled("pallas") is not None
        assert dispatch.resolve_backend("pallas") == "xla"
        for r in reqs:
            assert r.done and r.out == truth[r.rid], r.rid
        assert _pool_at_baseline(eng)
    finally:
        dispatch.enable_backend("pallas")    # don't leak into other tests


def test_disable_backend_validates():
    with pytest.raises(ValueError):
        dispatch.disable_backend("auto")
    with pytest.raises(ValueError):
        dispatch.disable_backend("bogus")
    assert dispatch.backend_disabled("xla") is None


# ===================================================================
# Load shedding under sustained pressure (shed_after)
# ===================================================================


def test_sustained_pressure_sheds_lowest_priority():
    """A pool too small for the stream churns preemptions; with
    shed_after set, the most-churned / least-urgent requests exit SHED
    with a retry-after hint instead of livelocking, and the rest DONE."""
    params, cfg = _model()
    prompts = [(np.arange(9 + i) * 5 + i) % cfg.vocab for i in range(4)]
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=4, n_pages=6, shed_after=2,
                             audit=True)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=14)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.drain(max_ticks=2000)
    shed = [r for r in reqs if r.status is Status.SHED]
    assert shed, "pressure never shed anybody"
    for r in shed:
        assert r.retry_after > 0 and "pool pressure" in r.detail
        assert r.n_preempts >= 2
    assert all(LC.is_terminal(r) for r in reqs)
    assert any(r.done for r in reqs)         # shedding unblocked the rest
    assert eng.n_shed == len(shed)
    assert eng.stats()["lifecycle"]["shed"] == len(shed)
    assert _pool_at_baseline(eng)


def test_no_shedding_without_shed_after():
    """shed_after=None (default) preserves PR 5 behavior exactly: the
    same pressured stream drains fully via recompute-preemption."""
    params, cfg = _model()
    prompts = [(np.arange(9 + i) * 5 + i) % cfg.vocab for i in range(4)]
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=4, n_pages=6, audit=True)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=14)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.drain(max_ticks=2000)
    assert all(r.done for r in reqs)
    assert eng.n_shed == 0


# ===================================================================
# Chaos acceptance matrix (the ISSUE's bar, in miniature)
# ===================================================================


def test_chaos_matrix_done_outputs_bit_identical():
    """Multiple fault sites at once, auditor on every tick: every request
    ends terminal, DONE outputs match the fault-free run bit-for-bit, and
    the pool drains back to baseline accounting."""
    params, cfg = _model()
    clean = _reqs(cfg, 6, 8)
    base = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                              prefill_chunk=4)
    for r in clean:
        base.submit(r)
    base.drain(max_ticks=1000)
    truth = {r.rid: r.out for r in clean}

    plan = FI.FaultPlan(seed=7, rates={"nan_logits": 0.03,
                                       "alloc_fail": 0.1,
                                       "pool_exhaustion": 0.05})
    eng = PagedServingEngine(params, cfg, n_slots=2, smax=32, page_size=8,
                             prefill_chunk=4, faults=plan, audit=True,
                             shed_after=8)
    reqs = _reqs(cfg, 6, 8)
    for r in reqs:
        eng.submit(r)
    eng.drain(max_ticks=5000)
    assert sum(plan.counts.values()) >= 3, "chaos too quiet to mean much"
    assert all(LC.is_terminal(r) for r in reqs)
    for r in reqs:
        if r.done:
            assert r.out == truth[r.rid], r.rid
    assert any(r.done for r in reqs)
    assert _pool_at_baseline(eng)
    st = eng.stats()
    assert st["faults"] == dict(plan.counts)
    assert sum(st["lifecycle"].values()) == len(reqs)
