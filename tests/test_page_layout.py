"""PageLayout API: latent-basis + quantized KV pages (DESIGN.md §10).

Locks the seam from four sides: the PageLayout dataclass itself
(parse/describe/footprint), the quantized page read-modify-write path
(token + chunk writes, dequantized logical views, COW of the sidecar
scales), the acceptance parity matrix (latent-basis storage at full rank
is greedy-identical to native pages across llama2 / mixtral / whisper ×
full / loki / loki_block), and the hybrid preemption path that now
retains its pages as private pool entries instead of recomputing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import PageLayout
from repro.models import lm
from repro.serving import paged_cache as PC
from repro.serving.engine import Engine, Request, ServingEngine
from repro.serving.scheduler import PagedServingEngine


def _cfg(arch, policy, layout=None):
    cfg = get_smoke_config(arch)
    if policy != "full":
        cfg = cfg.with_policy(policy, k_f=0.5, d_f=0.5, block_size=8,
                              local_window=4, min_k=4)
    return cfg.with_layout(layout) if layout else cfg


def _frames(cfg, i):
    if not cfg.is_encoder_decoder:
        return None
    return np.asarray(jax.random.normal(jax.random.PRNGKey(100 + i),
                                        (cfg.enc_seq, cfg.d_model)),
                      np.float32)


def _reqs(cfg, prompts, max_new):
    return [Request(rid=i, prompt=p.copy(), max_new=max_new,
                    frames=_frames(cfg, i))
            for i, p in enumerate(prompts)]


def _paged_outs(params, cfg, prompts, max_new=4, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("smax", 48)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    eng = PagedServingEngine(params, cfg, **kw)
    reqs = _reqs(cfg, prompts, max_new)
    for r in reqs:
        eng.submit(r)
    eng.drain(2000)
    assert all(r.done for r in reqs)
    return [r.out for r in reqs], eng


# ===================================================================
# PageLayout dataclass
# ===================================================================

def test_layout_parse_describe_roundtrip():
    for spec in ("fp16", "fp32:pca", "int8:pca:r=32", "fp8:native", "bf16"):
        lay = PageLayout.parse(spec)
        assert PageLayout.parse(lay.describe()) == lay
    assert PageLayout.parse("int8:pca:r=32") == PageLayout(
        dtype="int8", basis="pca", rank=32)
    # default layout is the pre-layout engine, bit for bit
    assert PageLayout.parse("") == PageLayout()
    assert PageLayout().describe() == "fp32:native"


def test_layout_rejects_bad_specs():
    with pytest.raises(ValueError):
        PageLayout.parse("int4")                 # unknown dtype
    with pytest.raises(ValueError):
        PageLayout.parse("fp16:wat")             # unknown token
    with pytest.raises(ValueError):
        PageLayout(dtype="fp16", rank=16)        # rank needs basis=pca
    with pytest.raises(ValueError):
        PageLayout(scale_granularity="tensor")   # only per-page scales


def test_layout_footprint_and_widths():
    hd, n_kv = 64, 4
    fp16 = PageLayout.parse("fp16")
    int8 = PageLayout.parse(f"int8:pca:r={hd // 2}")
    assert fp16.k_width(hd) == hd
    assert int8.k_width(hd) == hd // 2
    assert int8.k_width(16) == 16                # rank clamps to head_dim
    assert fp16.bytes_per_page_row(hd, n_kv) == 2 * n_kv * 2 * hd
    # the acceptance ratio: int8 latent at r=D/2 is >= 2x smaller
    ratio = fp16.bytes_per_page_row(hd, n_kv) / int8.bytes_per_page_row(
        hd, n_kv)
    assert ratio >= 2.0
    assert int8.quantized and int8.qmax == 127
    assert PageLayout.parse("fp8").qmax == 448
    assert not fp16.quantized


# ===================================================================
# Quantized page RMW: token writes, chunk writes, dequantized views
# ===================================================================

def _quant_pool(n_pages=4, ps=8, h=2, w=6, dtype=jnp.int8):
    pool = jnp.zeros((n_pages * ps, h, w), dtype)
    scales = jnp.full((n_pages,), PC.QUANT_EPS, jnp.float32)
    return pool, scales


@pytest.mark.parametrize("dtype,qmax", [(jnp.int8, 127.0),
                                        (jnp.float8_e4m3fn, 448.0)])
def test_token_write_roundtrip(dtype, qmax):
    """Sequential decode appends re-quantize the page's written prefix
    exactly: the dequantized view tracks the f32 reference within the
    step size of the page's final scale."""
    ps, h, w = 8, 2, 6
    pool, scales = _quant_pool(ps=ps, h=h, w=w, dtype=dtype)
    table = jnp.asarray([[1, 2]], jnp.int32)     # one slot, pages 1..2
    rng = np.random.default_rng(0)
    ref = jnp.asarray(rng.normal(size=(12, h, w)) *
                      np.linspace(0.5, 4.0, 12)[:, None, None],
                      jnp.float32)               # growing amax: RMW rescales
    for t in range(12):
        pool, scales = PC.write_token_rows_q(
            pool, scales, ref[t][None], table, jnp.asarray([t], jnp.int32),
            ps, qmax=qmax)
    view = PC.gather_logical_dq(pool, scales, table, ps)[0, :12]
    amax = float(jnp.max(jnp.abs(ref)))
    # each append re-quantizes the page's written prefix under the (grown)
    # scale, so early rows absorb up to a half-step per rescale: the bound
    # is ps half-steps of the final scale, not one
    tol = (amax / qmax * 0.51 * ps if dtype == jnp.int8
           else amax * 0.25)             # fp8 e4m3: 2^-4 relative/step
    np.testing.assert_allclose(np.asarray(view), np.asarray(ref), atol=tol)
    # both touched pages got real scales; untouched pages kept the floor
    s = np.asarray(scales)
    assert (s[1] > PC.QUANT_EPS) and (s[2] > PC.QUANT_EPS)
    assert s[3] == np.float32(PC.QUANT_EPS)


def test_chunk_write_roundtrip_with_padding():
    """A padded final chunk never writes rows at or past n_valid, and a
    spanned page receiving no valid row keeps its scale untouched."""
    ps, h, w = 8, 2, 4
    pool, scales = _quant_pool(ps=ps, h=h, w=w)
    table_row = jnp.asarray([1, 2, 3], jnp.int32)
    rng = np.random.default_rng(1)
    chunk = jnp.asarray(rng.normal(size=(8, h, w)) * 3.0, jnp.float32)
    # 5 valid rows at logical 6..10: spans pages 0 (rows 6,7) and 1
    pool, scales = PC.write_chunk_rows_q(pool, scales, chunk,
                                         table_row, 6, ps, n_valid=5,
                                         qmax=127.0)
    view = PC.gather_logical_dq(pool, scales, table_row[None], ps)[0]
    amax = float(jnp.max(jnp.abs(chunk[:5])))
    np.testing.assert_allclose(np.asarray(view[6:11]),
                               np.asarray(chunk[:5]),
                               atol=amax / 127 * 0.51)
    # logical 11.. (the padding) and page 3 (never spanned) stayed zero
    assert float(jnp.abs(view[11:]).max()) == 0.0
    assert np.asarray(scales)[3] == np.float32(PC.QUANT_EPS)


def test_cow_scale_divergence_keeps_donor_intact():
    """COW of a quantized page: the fork re-quantizes under its own scale
    as it appends, while the donor's codes AND scale stay byte-identical —
    the shared-prefix reader keeps dequantizing the same values."""
    ps, h, w = 8, 2, 4
    pool, scales = _quant_pool(ps=ps, h=h, w=w)
    table = jnp.asarray([[1]], jnp.int32)
    rng = np.random.default_rng(2)
    donor_rows = jnp.asarray(rng.normal(size=(5, h, w)), jnp.float32)
    for t in range(5):
        pool, scales = PC.write_token_rows_q(
            pool, scales, donor_rows[t][None], table,
            jnp.asarray([t], jnp.int32), ps, qmax=127.0)
    donor_codes = np.asarray(pool[ps:2 * ps]).copy()
    donor_scale = float(scales[1])

    # fork: copy page 1 -> page 2 (rows + scale), then diverge with a row
    # 50x larger than anything the donor holds (forces a rescale)
    pool = PC.copy_page_rows(pool, jnp.int32(1), jnp.int32(2), ps)
    scales = PC.copy_page_scale(scales, jnp.int32(1), jnp.int32(2))
    fork_table = jnp.asarray([[2]], jnp.int32)
    big = jnp.full((1, h, w), 50.0 * float(jnp.abs(donor_rows).max()),
                   jnp.float32)
    pool, scales = PC.write_token_rows_q(pool, scales, big, fork_table,
                                         jnp.asarray([5], jnp.int32), ps,
                                         qmax=127.0)
    # donor untouched, scale included
    assert np.array_equal(np.asarray(pool[ps:2 * ps]), donor_codes)
    assert float(scales[1]) == donor_scale
    assert float(scales[2]) > donor_scale        # fork rescaled for the row
    # the fork's shared prefix still dequantizes to the donor's values,
    # within the fork's (coarser) step size
    fork_view = PC.gather_logical_dq(pool, scales, fork_table, ps)[0, :5]
    np.testing.assert_allclose(np.asarray(fork_view),
                               np.asarray(donor_rows),
                               atol=float(scales[2]) * 0.51)


# ===================================================================
# Acceptance parity matrix: latent basis at full rank == native pages
# ===================================================================

PARITY = [(a, p)
          for a in ("llama2-7b", "mixtral-8x22b", "whisper-small")
          for p in ("full", "loki", "loki_block")]


@pytest.mark.parametrize("arch,policy", PARITY,
                         ids=[f"{a}-{p}" for a, p in PARITY])
def test_latent_full_rank_matches_native_pages(arch, policy):
    """basis=pca at r=D stores K rotated by an orthogonal P: scores are
    unchanged (Lemma 4.1), so greedy outputs must match the native-layout
    paged engine token for token — fp16 storage included (the acceptance
    layout)."""
    cfg = _cfg(arch, policy)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompts = [(np.arange(6 + 5 * i) * 7 + i) % cfg.vocab for i in range(2)]
    base, _ = _paged_outs(params, cfg, prompts)
    for spec in ("fp32:pca", "fp16:pca"):
        outs, _ = _paged_outs(params, cfg.with_layout(spec), prompts)
        assert outs == base, (arch, policy, spec, outs, base)


def test_quantized_latent_serves_and_frees_pool():
    """int8 latent pages at r=D/2 — approximate by design, so no parity
    assert; the engine must drain the stream, produce in-vocab tokens and
    return every page."""
    cfg = _cfg("llama2-7b", "loki_block",
               layout=f"int8:pca:r={get_smoke_config('llama2-7b').resolved_head_dim // 2}")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompts = [(np.arange(6 + 5 * i) * 7 + i) % cfg.vocab for i in range(2)]
    outs, eng = _paged_outs(params, cfg, prompts, prefix_cache=False)
    assert all(0 <= t < cfg.vocab for out in outs for t in out)
    assert eng.pool.free_pages == eng.pool.n_pages - 1
    assert eng.stats()["layout"].startswith("int8:pca")


def test_rank_truncation_divergence_is_bounded():
    """r < D drops trailing basis dims: chunked-prefill logits must move
    (the approximation is real) but stay bounded, while r = D stays
    numerically on top of the native layout."""
    cfg = _cfg("llama2-7b", "full")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompt = (np.arange(19) * 7 + 3) % cfg.vocab
    hd = cfg.resolved_head_dim

    def chunk_logits(c):
        ps, smax = 8, 32
        cache = lm.init_paged_cache(c, smax // ps + 2, ps, jnp.float32,
                                    n_slots=1)
        table = jnp.arange(1, smax // ps + 1, dtype=jnp.int32)[None]
        lg = None
        for start in range(0, len(prompt), 4):
            nv = min(4, len(prompt) - start)
            buf = np.zeros((1, 4), np.int32)
            buf[0, :nv] = prompt[start:start + nv]
            lg, cache = lm.prefill_chunk(params, c, cache,
                                         jnp.asarray(buf),
                                         jnp.int32(start), jnp.int32(nv),
                                         table, ps, slot=jnp.int32(0))
        return np.asarray(lg)

    ref = chunk_logits(cfg)
    full_rank = chunk_logits(cfg.with_layout("fp32:pca"))
    half_rank = chunk_logits(cfg.with_layout(f"fp32:pca:r={hd // 2}"))
    np.testing.assert_allclose(full_rank, ref, atol=1e-4)
    err = float(np.abs(half_rank - ref).max())
    assert np.isfinite(half_rank).all()
    assert err > 1e-4                    # truncation genuinely bites
    assert err < 50.0                    # ...but stays bounded


# ===================================================================
# Hybrid preemption retains its pages (satellite of DESIGN.md §10)
# ===================================================================

def test_hybrid_preemption_restores_retained_pages():
    """The tight-pool hymba stream from the recompute-era test, now pinned
    to the retention path: preemptions materialize, every re-admission
    restores the state snapshot onto its retained private pages (restores
    == preemptions would be too strict under eviction, but on this stream
    none are evicted), and greedy outputs still match the dense truth."""
    cfg = get_smoke_config("hymba-1.5b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    prompts = [(np.arange(9 + i) * 5 + i) % cfg.vocab for i in range(4)]
    truth = []
    for i, p in enumerate(prompts):
        eng = ServingEngine(params, cfg, n_slots=1, smax=32)
        r = Request(rid=0, prompt=p.copy(), max_new=14)
        eng.submit(r)
        eng.drain(800)
        truth.append(r.out)
    outs, eng = _paged_outs(params, cfg, prompts, max_new=14,
                            smax=32, n_pages=6)
    assert eng.n_preempted > 0
    assert eng.n_state_restores > 0      # retention, not recompute
    assert outs == truth
    assert eng.pool.free_pages == eng.pool.n_pages - 1


# ===================================================================
# Engine protocol
# ===================================================================

def test_both_engines_satisfy_protocol():
    cfg = get_smoke_config("llama2-7b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    dense = ServingEngine(params, cfg, n_slots=1, smax=32)
    paged = PagedServingEngine(params, cfg, n_slots=1, smax=32,
                               page_size=8)
    for eng, kind in ((dense, "dense"), (paged, "paged")):
        assert isinstance(eng, Engine)
        r = Request(rid=0, prompt=np.arange(5, dtype=np.int64) % cfg.vocab,
                    max_new=2)
        eng.submit(r)
        eng.drain(100)
        assert r.done
        st = eng.stats()
        assert st["engine"] == kind and st["ticks"] > 0
    assert paged.stats()["layout"] == "fp32:native"
