"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles.

Kernels run with interpret=True (Python execution of the kernel body on CPU);
on TPU hardware the identical pallas_call compiles through Mosaic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.approx_scores import block_max_scores
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gather_attention import block_sparse_attention
from repro.kernels.ops import loki_decode_attention


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,s,dim,bs,d", [
    (4, 256, 64, 32, 16),
    (2, 512, 128, 128, 32),
    (1, 128, 128, 64, 64),
    (3, 384, 256, 128, 32),     # gemma head_dim, non-pow2 BH
    (8, 256, 64, 64, 8),
])
def test_block_max_scores(bh, s, dim, bs, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (bh, dim), dtype)
    k = _rand(ks[1], (bh, s, dim), dtype)
    cur = jax.random.randint(ks[2], (bh,), 1, s + 1)
    got = block_max_scores(q, k, cur, d=d, block_size=bs, interpret=True)
    want = ref.block_max_scores_ref(q, k, cur, d=d, block_size=bs)
    np.testing.assert_allclose(got, want, rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,s,dim,bs,nsel", [
    (4, 256, 64, 32, 4),
    (2, 512, 128, 128, 2),
    (3, 384, 256, 128, 3),
    (1, 1024, 128, 128, 8),
])
def test_block_sparse_attention(bh, s, dim, bs, nsel, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = _rand(ks[0], (bh, dim), dtype)
    k = _rand(ks[1], (bh, s, dim), dtype)
    v = _rand(ks[2], (bh, s, dim), dtype)
    cur = jax.random.randint(ks[3], (bh,), bs, s + 1)
    nb = s // bs
    # random *distinct* block selection per row
    bidx = jnp.stack([
        jax.random.permutation(jax.random.fold_in(ks[4], i), nb)[:nsel]
        for i in range(bh)])
    got = block_sparse_attention(q, k, v, bidx, cur, block_size=bs,
                                 interpret=True)
    want = ref.block_sparse_attention_ref(q, k, v, bidx, cur, block_size=bs)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=5e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("bh,sq,sk,dim,bq,bk", [
    (2, 128, 128, 64, 32, 32),
    (1, 256, 256, 128, 128, 64),
    (3, 128, 128, 256, 64, 128),
])
def test_flash_attention(bh, sq, sk, dim, bq, bk, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (bh, sq, dim), dtype)
    k = _rand(ks[1], (bh, sk, dim), dtype)
    v = _rand(ks[2], (bh, sk, dim), dtype)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk, causal=causal,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 2e-5,
        atol=3e-2 if dtype == jnp.bfloat16 else 2e-5)


def test_full_pipeline_selects_all_blocks_equals_dense():
    """k_blocks = all blocks -> block-sparse flash == dense attention."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    bh, s, dim, bs = 2, 256, 64, 32
    q = _rand(ks[0], (bh, dim), jnp.float32)
    k = _rand(ks[1], (bh, s, dim), jnp.float32)
    v = _rand(ks[2], (bh, s, dim), jnp.float32)
    cur = jnp.array([s, s // 2])
    out = loki_decode_attention(q, k, v, cur, d=dim, k_blocks=s // bs,
                                block_size=bs, interpret=True)
    # dense reference
    sc = jnp.einsum("bd,bsd->bs", q, k) * dim ** -0.5
    sc = jnp.where(jnp.arange(s)[None] < cur[:, None], sc, -1e30)
    want = jnp.einsum("bs,bsd->bd", jax.nn.softmax(sc, -1), v)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_pipeline_matches_jnp_block_oracle():
    """Kernel pipeline == core.loki.loki_decode_block for a single head."""
    from repro.configs.base import LokiConfig
    from repro.core.loki import loki_decode_block
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    b, s, dim, bs = 2, 256, 64, 32
    q = _rand(ks[0], (b, 1, dim), jnp.float32)       # 1 head
    k = _rand(ks[1], (b, s, 1, dim), jnp.float32)
    v = _rand(ks[2], (b, s, 1, dim), jnp.float32)
    cur = jnp.array([s, s])
    cfg = LokiConfig(enabled=True, d_f=0.25, k_f=0.25, block_size=bs,
                     local_window=0)
    proj = jnp.eye(dim)[None]
    want = loki_decode_block(q[:, 0][:, None, :].reshape(b, 1, dim),
                             k, v, cur, proj, cfg)
    got = loki_decode_attention(
        q.reshape(b, dim), k.reshape(b, s, dim), v.reshape(b, s, dim),
        cur, d=16, k_blocks=max(int(0.25 * (s // bs)), 1),
        block_size=bs, interpret=True)
    np.testing.assert_allclose(got, want.reshape(b, dim), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------- feature-major variant

@pytest.mark.parametrize("bh,s,dim,bs,d", [
    (4, 256, 64, 64, 16), (2, 512, 128, 128, 32), (8, 256, 128, 64, 64),
    (1, 384, 64, 128, 8),
])
def test_block_max_scores_feature_major(bh, s, dim, bs, d):
    """The (D,S) sublane-slice kernel computes identical block maxima to the
    token-major kernel and the jnp oracle."""
    from repro.kernels.approx_scores_fm import block_max_scores_fm
    ks = jax.random.split(jax.random.PRNGKey(bh * s), 3)
    q = jax.random.normal(ks[0], (bh, dim), jnp.float32)
    k = jax.random.normal(ks[1], (bh, s, dim), jnp.float32)
    cur = jax.random.randint(ks[2], (bh,), s // 2, s + 1)
    scale = dim ** -0.5
    want = ref.block_max_scores_ref(q, k, cur, d=d, block_size=bs,
                                    scale=scale)
    got = block_max_scores_fm(q, jnp.swapaxes(k, 1, 2), cur, d=d,
                              block_size=bs, scale=scale, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_feature_major_pipeline_matches_token_major():
    from repro.kernels.ops import (loki_decode_attention,
                                   loki_decode_attention_fm)
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    bh, s, dim, bs = 4, 512, 64, 128
    q = jax.random.normal(ks[0], (bh, dim), jnp.float32)
    k = jax.random.normal(ks[1], (bh, s, dim), jnp.float32)
    v = jax.random.normal(ks[2], (bh, s, dim), jnp.float32)
    cur = jnp.full((bh,), s, jnp.int32)
    tm = loki_decode_attention(q, k, v, cur, d=16, k_blocks=2,
                               block_size=bs, interpret=True)
    fm = loki_decode_attention_fm(q, jnp.swapaxes(k, 1, 2), v, cur, d=16,
                                  k_blocks=2, block_size=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(tm), np.asarray(fm),
                               rtol=1e-5, atol=1e-5)
