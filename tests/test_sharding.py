"""Sharding rule engine: divisibility, padding pass, dedup, mesh filtering."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import axes as AX
from repro.sharding.rules import DEFAULT_RULES, spec_for


class FakeMesh:
    """Minimal stand-in exposing .axis_names / .shape like jax Mesh."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_mapping():
    sp = spec_for(("batch", "seq", "heads", None), (256, 128, 32, 64), MESH)
    assert sp == P("data", None, "model")


def test_divisible_fallback_replicates():
    # kv_heads=2 cannot take a 16-way axis
    sp = spec_for(("batch", "kv_seq", "kv_heads", None),
                  (128, 4096, 2, 128), MESH)
    assert sp == P("data", "model")


def test_padded_only_when_allowed():
    # 24 heads on 16: replicate for inputs, padded-shard for constraints
    sp_in = spec_for(("batch", "seq", "heads", None), (32, 64, 24, 64), MESH)
    assert sp_in == P("data")
    sp_c = spec_for(("batch", "seq", "heads", None), (32, 64, 24, 64), MESH,
                    allow_padded=True)
    assert sp_c == P("data", None, "model")


def test_padded_rejects_high_waste():
    # kv_heads=2 on 16-way: 8x padding waste — reject even when allowed
    sp = spec_for(("batch", None, "kv_heads", None), (32, 4, 2, 64), MESH,
                  allow_padded=True)
    assert sp == P("data")


def test_axis_dedup_first_divisible_wins():
    # expert=40 can't take model; expert_capacity=64 can
    sp = spec_for(("moe_group", "expert", "expert_capacity", None),
                  (256, 40, 64, 1536), MESH)
    assert sp == P("data", None, "model")
    # expert=8... on an 8-way model mesh it wins and capacity is deduped
    mesh8 = FakeMesh({"data": 2, "model": 8})
    sp2 = spec_for(("moe_group", "expert", "expert_capacity", None),
                   (256, 8, 64, 1536), mesh8)
    assert sp2 == P("data", "model")


def test_missing_mesh_axis_dropped():
    sp = spec_for(("batch",), (32,), MESH)           # 'pod' not in mesh
    assert sp == P("data")
    sp_mp = spec_for(("batch",), (32,), MESH_MP)
    assert sp_mp == P(("pod", "data"))


def test_logical_axes_longer_than_shape():
    # decode-path tensors reuse train constraints on squeezed shapes:
    # out-of-range logical axes must not shard (or crash on) anything
    sp = spec_for(("batch", "seq", "mlp"), (32, 256), MESH)
    assert sp == P("data")
    sp2 = spec_for(("batch", "seq", "mlp"), (8, 256), MESH)
    assert sp2 == P()                    # 8 % 16 != 0 -> replicated too


def test_param_axes_tree_matches_rank():
    import jax
    import jax.numpy as jnp
    shapes = {"layers": {"attn": {
        "wq": jax.ShapeDtypeStruct((4, 128, 256), jnp.float32),  # stacked
        "pca": jax.ShapeDtypeStruct((4, 2, 64, 64), jnp.float32),
    }}}
    axes = AX.param_axes_tree(shapes)
    assert axes["layers"]["attn"]["wq"] == (None, "embed", "qkv")
    assert axes["layers"]["attn"]["pca"] == (None, "kv_heads", None, None)


def test_cache_axes():
    import jax
    import jax.numpy as jnp
    shapes = {"layers": {"attn": {
        "k": jax.ShapeDtypeStruct((8, 1024, 4, 64), jnp.float32)}}}
    axes = AX.cache_axes_tree(shapes)
    assert axes["layers"]["attn"]["k"] == ("batch", "kv_seq", "kv_heads",
                                           None)
