"""Elastic restart: a checkpoint saved under one mesh restores onto a
different device count / mesh shape (the checkpoint stores full logical
arrays; resharding happens at load). Runs in a subprocess with forced host
devices."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.sharding import axes as AX
    from repro.sharding.rules import spec_for

    cfg = get_smoke_config("qwen2.5-3b")
    ckpt_dir = os.environ["CKPT_DIR"]

    def sharded_params(mesh):
        params = lm.init(jax.random.PRNGKey(0), cfg)
        axes = AX.param_axes_tree(jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params))
        def put(ax, arr):
            return jax.device_put(arr, NamedSharding(
                mesh, spec_for(ax, arr.shape, mesh)))
        return jax.tree.map(
            put, axes, params,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x)), axes

    # save under a (2, 2) mesh using 4 of 8 devices
    mesh_a = jax.make_mesh((2, 2), ("data", "model"),
                           devices=jax.devices()[:4])
    params_a, axes = sharded_params(mesh_a)
    mgr = CheckpointManager(ckpt_dir, keep=2)
    mgr.save(3, params_a, blocking=True)

    # restore under a (4, 2) mesh using all 8 devices
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    template, axes_b = sharded_params(mesh_b)
    shardings = jax.tree.map(
        lambda ax, arr: NamedSharding(
            mesh_b, spec_for(ax, arr.shape, mesh_b)),
        axes_b, jax.device_get(template),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    step, restored = mgr.restore_latest(template, shardings=shardings)
    assert step == 3, step

    # identical values, new sharding
    ok = jax.tree.map(lambda a, b: bool(jnp.allclose(
        jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32))),
        jax.device_get(params_a), jax.device_get(restored))
    assert all(jax.tree.leaves(ok))
    some = jax.tree.leaves(restored)[0]
    assert some.sharding.mesh.devices.size == 8
    print("ELASTIC_OK")
""")


def test_elastic_restore_across_meshes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["CKPT_DIR"] = str(tmp_path)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=540)
    assert "ELASTIC_OK" in r.stdout, r.stdout + "\n" + r.stderr
