"""All-policy paged decode: packed ticks and per-layer page-table groups.

Three seams locked (DESIGN.md §14):

* the paged ``full`` / ``exact_topk`` Pallas kernels against their jnp
  oracles — G in {1,4,8} x {fp32, int8, fp8} pca-basis pools x ragged
  page tables whose dead tail points at the trash page, interpret mode
  so CI runs on CPU;
* gather-packed decode: greedy outputs identical to the masked
  full-batch path for every paged policy, with packed ticks actually
  engaged (row savings counted, auditor on);
* per-layer page-table groups: on every tick each group's live pages
  stay within its spec-table hard bound, window groups recycle while
  the full-attention group pins — on mixtral-SWA and the hymba hybrid.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import LokiConfig
from repro.core import baselines
from repro.core.attention import decode_full
from repro.kernels import ops
from repro.models import lm
from repro.serving import cache_spec as CS
from repro.serving.engine import Request
from repro.serving.paged_cache import QUANT_EPS, gather_logical_dq
from repro.serving.scheduler import PAGED_POLICIES, PagedServingEngine


# ------------------------------------------------------------ helpers

def _setup(b, hkv, g, s, dim, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, hkv * g, dim), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dim), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dim), dtype)
    return q, k, v


def _orthogonal(hkv, dim, seed=0):
    rng = np.random.RandomState(seed)
    mats = [np.linalg.qr(rng.randn(dim, dim))[0] for _ in range(hkv)]
    return jnp.asarray(np.stack(mats), jnp.float32)


def _grouped_q(q, proj, hkv):
    b, h, dim = q.shape
    qg = q.reshape(b, hkv, h // hkv, dim)
    return jnp.einsum("bhgd,hde->bhge", qg, proj.astype(q.dtype))


def _paged_pool(k_hat, v, ps, seed=0):
    """Scatter contiguous (B,S,Hkv,D) caches into a shuffled page pool.

    Returns (pool_k, pool_v, page_table) with page 0 left as trash."""
    b, s, hkv, dim = k_hat.shape
    mp = s // ps
    rng = np.random.RandomState(seed)
    perm = rng.permutation(b * mp) + 1              # physical pages, 1-based
    table = perm.reshape(b, mp).astype(np.int32)
    n_pages = b * mp + 1
    pool_k = np.zeros((n_pages * ps, hkv, dim), np.asarray(k_hat).dtype)
    pool_v = np.zeros_like(pool_k)
    kn, vn = np.asarray(k_hat), np.asarray(v)
    for i in range(b):
        for p in range(mp):
            rows = slice(table[i, p] * ps, table[i, p] * ps + ps)
            pool_k[rows] = kn[i, p * ps:(p + 1) * ps]
            pool_v[rows] = vn[i, p * ps:(p + 1) * ps]
    return pool_k, pool_v, table


#: PageLayout dtype -> (storage dtype, qmax); None = unquantized fp32
LAYOUTS = {"fp32": (None, 0.0),
           "int8": (jnp.int8, 127.0),
           "fp8": (jnp.float8_e4m3fn, 448.0)}


def _quantize_pool(pool, ps, dtype, qmax):
    """Per-page amax quantization, the pool writers' scheme: one f32
    scale per page, codes = rows / scale (rounded+clipped for ints)."""
    arr = np.asarray(pool, np.float32)
    n_pages = arr.shape[0] // ps
    scales = np.zeros((n_pages,), np.float32)
    codes = np.zeros_like(arr)
    for p in range(n_pages):
        rows = arr[p * ps:(p + 1) * ps]
        scales[p] = max(np.abs(rows).max(), QUANT_EPS) / qmax
        y = rows / scales[p]
        if np.issubdtype(np.dtype(dtype), np.integer):
            y = np.clip(np.round(y), -qmax, qmax)
        codes[p * ps:(p + 1) * ps] = y
    return jnp.asarray(codes).astype(dtype), jnp.asarray(scales)


def _paged_case(g, layout, seed):
    """One parity cell: rotated (pca-basis) caches scattered into a
    shuffled pool, ragged lengths AND a ragged table (row 1's dead tail
    re-pointed at the trash page — the kernels must never read it)."""
    b, hkv, s, dim, bs, ps = 2, 2, 256, 64, 32, 32
    q, k, v = _setup(b, hkv, g, s, dim, seed=seed)
    proj = _orthogonal(hkv, dim, seed=seed)
    k_hat = jnp.einsum("bshd,hde->bshe", k, proj)
    cur = jnp.array([s, 100], jnp.int32)
    pool_k, pool_v, table = _paged_pool(k_hat, v, ps, seed=g)
    live1 = -(-100 // ps)
    table[1, live1:] = 0                            # dead tail -> trash page
    dtype, qmax = LAYOUTS[layout]
    if dtype is None:
        k_scale = v_scale = None
        pool_k, pool_v = jnp.asarray(pool_k), jnp.asarray(pool_v)
    else:
        pool_k, k_scale = _quantize_pool(pool_k, ps, dtype, qmax)
        pool_v, v_scale = _quantize_pool(pool_v, ps, dtype, qmax)
    q_hat = _grouped_q(q, proj, hkv)
    table = jnp.asarray(table)
    return (b, hkv, g, s, dim, bs, ps, q_hat, pool_k, pool_v, table, cur,
            k_scale, v_scale)


# ===================================================================
# Paged full / exact_topk kernels vs the jnp oracle
# ===================================================================

@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("g", [1, 4, 8])
def test_paged_full_decode_matches_oracle(g, layout):
    """Streaming paged full attention == dense softmax over the
    dequantized logical view gathered through the same table."""
    (b, hkv, g_, s, dim, bs, ps, q_hat, pool_k, pool_v, table, cur,
     k_scale, v_scale) = _paged_case(g, layout, seed=g + 17)
    got = ops.full_decode(q_hat, pool_k, pool_v, cur, block_size=bs,
                          page_table=table, page_size=ps,
                          k_scale=k_scale, v_scale=v_scale, interpret=True)
    k_dq = gather_logical_dq(pool_k, k_scale, table, ps).astype(jnp.float32)
    v_dq = gather_logical_dq(pool_v, v_scale, table, ps).astype(jnp.float32)
    h = hkv * g
    want = decode_full(q_hat.reshape(b, h, dim), k_dq, v_dq, cur)
    assert got.shape == (b, hkv, g, dim)
    np.testing.assert_allclose(np.asarray(got).reshape(b, h, dim),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("g", [1, 4, 8])
def test_paged_exact_topk_matches_oracle(g, layout):
    """Single-pass paged exact-top-k == the block-granular jnp baseline
    reading the pool through the same (ragged) table and scales."""
    (b, hkv, g_, s, dim, bs, ps, q_hat, pool_k, pool_v, table, cur,
     k_scale, v_scale) = _paged_case(g, layout, seed=g + 31)
    cfg = LokiConfig(enabled=False, k_f=0.25, block_size=bs, local_window=0)
    kb = max(int(cfg.k_f * (s // bs)), 1)
    got = ops.exact_topk_decode_fused(
        q_hat, pool_k, pool_v, cur, k_blocks=kb, block_size=bs,
        page_table=table, page_size=ps,
        k_scale=k_scale, v_scale=v_scale, interpret=True)
    h = hkv * g
    want = baselines.exact_topk_decode_block(
        q_hat.reshape(b, h, dim), pool_k, pool_v, cur, cfg,
        page_table=table, page_size=ps, k_scale=k_scale, v_scale=v_scale)
    assert got.shape == (b, hkv, g, dim)
    np.testing.assert_allclose(np.asarray(got).reshape(b, h, dim),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


# ===================================================================
# Gather-packed decode: greedy identity vs the masked path
# ===================================================================

@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen2.5-3b")
    return lm.init(jax.random.PRNGKey(0), cfg), cfg


def _policy(cfg, policy):
    if policy == "full":
        return cfg
    return cfg.with_policy(policy, k_f=0.5, d_f=0.5, block_size=8,
                           local_window=4, min_k=4)


@pytest.mark.parametrize("policy", PAGED_POLICIES)
def test_packed_matches_masked_greedy(policy, qwen):
    """At 50% occupancy the packed engine must emit the same greedy
    tokens as the masked full-batch engine, and must actually have run
    packed ticks (smaller buckets, rows saved)."""
    params, cfg0 = qwen
    cfg = _policy(cfg0, policy)

    def run(packed):
        eng = PagedServingEngine(params, cfg, n_slots=6, smax=64,
                                 page_size=8, prefill_chunk=8,
                                 packed=packed, audit=True)
        reqs = [Request(rid=i, prompt=(np.arange(5 + i) * 3 + i) % cfg.vocab,
                        max_new=6) for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done(400)
        assert all(r.done for r in reqs), [r.status for r in reqs]
        return [tuple(r.out) for r in reqs], eng

    masked, _ = run(packed=False)
    packed, eng = run(packed=True)
    assert masked == packed, (policy, masked, packed)
    st = eng.stats()["packed"]
    assert st["enabled"]
    assert st["n_packed_ticks"] > 0, st
    assert st["n_rows_saved"] > 0, st
    assert st["n_sealed_fallbacks"] == 0, st


# ===================================================================
# Per-layer page-table groups: hard bound held on every tick
# ===================================================================

def _run_bounded(cfg, *, n_slots, n_reqs, max_new, smax=128, page_size=8):
    """Serve a stream, asserting per tick that every group's live pages
    stay within its spec-table hard bound. Returns the engine."""
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = PagedServingEngine(params, cfg, n_slots=n_slots, smax=smax,
                             page_size=page_size, prefill_chunk=8,
                             audit=True, packed=True)
    reqs = [Request(rid=i, prompt=(np.arange(20 + 4 * i) * 3 + i) % cfg.vocab,
                    max_new=max_new) for i in range(n_reqs)]
    for r in reqs:
        eng.submit(r)
    for _ in range(800):
        if not eng._queue and not eng._admit_order:
            break
        eng.tick()
        for g in range(eng.n_groups):
            bound = eng._group_pages_hard[g]
            for slot in range(eng.n_slots):
                held = sum(p is not None for p in eng._group_pages(g)[slot])
                assert held <= bound, (g, slot, held, bound)
    assert all(r.done for r in reqs), [r.status for r in reqs]
    return eng


def test_mixtral_swa_group_budget_bound_per_tick():
    cfg = get_smoke_config("mixtral-8x22b").with_window_layers((16, 0))
    assert CS.group_windows(cfg) == (0, 16)
    eng = _run_bounded(cfg, n_slots=4, n_reqs=6, max_new=30)
    st = eng.stats()
    assert st["table_groups"]["n_groups"] == 2
    assert st["table_groups"]["group_windows"] == [0, 16]
    assert st["n_recycled_pages"] > 0, "window group never recycled"


def test_hymba_group_budget_bound_per_tick():
    """Hybrid family: attention runs in parallel with the SSM heads, so
    per-layer windows still form page-table groups over the attn specs."""
    cfg = get_smoke_config("hymba-1.5b").with_window_layers((0, 16))
    assert CS.group_windows(cfg) == (0, 16)
    eng = _run_bounded(cfg, n_slots=4, n_reqs=5, max_new=24)
    st = eng.stats()
    assert st["table_groups"]["n_groups"] == 2
    assert st["n_recycled_pages"] > 0, "window group never recycled"


def test_full_group_pins_while_window_group_recycles():
    """With mixed windows the full-attention table must never grow holes
    (no recycling) while the window group's table does."""
    cfg = get_smoke_config("mixtral-8x22b").with_window_layers((16, 0))
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = PagedServingEngine(params, cfg, n_slots=1, smax=128, page_size=8,
                             prefill_chunk=8, audit=True)
    req = Request(rid=0, prompt=(np.arange(40) * 5 + 1) % cfg.vocab,
                  max_new=40)
    eng.submit(req)
    saw_hole_main = saw_hole_aux = False
    for _ in range(400):
        if not eng._queue and not eng._admit_order:
            break
        eng.tick()
        if eng.slot_pages[0]:
            saw_hole_main |= any(p is None for p in eng.slot_pages[0])
            saw_hole_aux |= any(p is None for p in eng.aux_pages[0][0])
    assert req.done
    assert not saw_hole_main, "full-attention group recycled a page"
    assert saw_hole_aux, "window group never recycled"


def test_uniform_window_layers_is_single_group():
    """window_layers with one distinct window collapses to the single
    table engine: same groups, same greedy output."""
    cfg_u = get_smoke_config("mixtral-8x22b").replace(sliding_window=None)
    cfg_g = cfg_u.with_window_layers((0, 0))
    assert CS.n_table_groups(cfg_g) == 1
    params = lm.init(jax.random.PRNGKey(0), cfg_u)
    outs = []
    for cfg in (cfg_u, cfg_g):
        eng = PagedServingEngine(params, cfg, n_slots=2, smax=64,
                                 page_size=8, prefill_chunk=8, audit=True)
        reqs = [Request(rid=i, prompt=(np.arange(6 + i) * 3 + i) % cfg.vocab,
                        max_new=5) for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done(400)
        assert all(r.done for r in reqs)
        outs.append([tuple(r.out) for r in reqs])
    assert outs[0] == outs[1]
