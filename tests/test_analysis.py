"""repro.analysis: every lint rule, both contract passes and the
resource-flow dataflow must (a) pass on the real repo and (b) catch a
known-bad fixture — a checker that never fires is indistinguishable
from one that is broken."""
import ast
import pathlib

import numpy as np
import pytest

from repro.analysis import cli, kernel_contracts, lint, resource_flow
from repro.analysis.common import (annotated, fingerprint, iter_sources,
                                   load_baseline, repo_root, save_baseline)
from repro.analysis.trace_guard import (PageTableError, RetraceError,
                                        TraceGuard, sanitize_tables)
from repro.kernels import registry, tuning


def _src(path, code):
    return [(path, code, ast.parse(code))]


def _rules(findings):
    return sorted({f.rule for f in findings})


# ================================================== lint rule fixtures

class TestHostSync:
    BAD = """
import jax
import jax.numpy as jnp
import numpy as np

class Eng:
    def __init__(self):
        self.pos = jnp.zeros((4,))
        self.host_tbl = np.zeros((4,))

    def tick(self):
        n = int(self.pos[0])            # sync
        m = self.pos.sum().item()       # sync
        a = np.asarray(self.pos)        # sync
        b = np.asarray(self.host_tbl)   # host value: fine
        jax.device_get(self.pos)        # sync
"""

    GOOD = """
import jax
import jax.numpy as jnp

class Eng:
    def __init__(self):
        self.pos = jnp.zeros((4,))

    def tick(self):
        # host-sync: the one batched sync per tick
        n = jax.device_get(self.pos)

    def helper(self):
        # not tick-reachable: syncs here are out of scope
        return int(self.pos[0])
"""

    def test_bad(self):
        fs = lint.run(_src("serving/fake.py", self.BAD),
                      rules=("host-sync",))
        assert len(fs) == 4, [f.format() for f in fs]
        assert _rules(fs) == ["host-sync"]

    def test_good(self):
        assert lint.run(_src("serving/fake.py", self.GOOD),
                        rules=("host-sync",)) == []

    def test_sync_through_helper_method(self):
        code = """
import jax.numpy as jnp

class Eng:
    def __init__(self):
        self.pos = jnp.zeros((4,))

    def tick(self):
        self._step()

    def _step(self):
        return float(self.pos[0])
"""
        fs = lint.run(_src("serving/fake.py", code), rules=("host-sync",))
        assert len(fs) == 1 and fs[0].func == "_step"


class TestKernelOp:
    BAD = """
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _kernel(x_ref, o_ref):
    o_ref[...] = jnp.sort(x_ref[...])

def entry(x):
    return pl.pallas_call(_kernel, out_shape=None)(x)
"""

    GOOD = """
import jax.numpy as jnp
import functools
from jax.experimental import pallas as pl

def _kernel(x_ref, o_ref, *, d):
    o_ref[...] = jnp.max(x_ref[...][:, :d], axis=-1)

def entry(x, d):
    kernel = functools.partial(_kernel, d=d)
    return pl.pallas_call(kernel, out_shape=None)(x)
"""

    def test_bad(self):
        fs = lint.run(_src("kernels/fake.py", self.BAD),
                      rules=("kernel-op",))
        assert len(fs) == 1 and "jnp.sort" in fs[0].message

    def test_good(self):
        assert lint.run(_src("kernels/fake.py", self.GOOD),
                        rules=("kernel-op",)) == []

    def test_transitive_helper(self):
        code = """
import numpy as np
from jax.experimental import pallas as pl

def _helper(x):
    return np.argmax(x)

def _kernel(x_ref, o_ref):
    o_ref[...] = _helper(x_ref[...])

def entry(x):
    return pl.pallas_call(_kernel, out_shape=None)(x)
"""
        fs = lint.run(_src("kernels/fake.py", code), rules=("kernel-op",))
        assert len(fs) == 1 and "np.argmax" in fs[0].message


class TestTracerBranch:
    BAD = """
import jax
import jax.numpy as jnp

@jax.jit
def f(x):
    if jnp.sum(x) > 0:
        return x
    return -x
"""

    GOOD = """
import jax
import jax.numpy as jnp

@jax.jit
def f(x):
    return jnp.where(jnp.sum(x) > 0, x, -x)

def untraced(x):
    if jnp.sum(x) > 0:      # not jitted: concretizes fine
        return x
    return -x
"""

    def test_bad(self):
        fs = lint.run(_src("core/fake.py", self.BAD),
                      rules=("tracer-branch",))
        assert len(fs) == 1 and fs[0].func == "f"

    def test_good(self):
        assert lint.run(_src("core/fake.py", self.GOOD),
                        rules=("tracer-branch",)) == []


class TestWallClock:
    BAD = """
import time

def stamp():
    return time.time()
"""

    def test_bad_in_serving(self):
        fs = lint.run(_src("serving/fake.py", self.BAD),
                      rules=("wall-clock",))
        assert len(fs) == 1 and "time.time" in fs[0].message

    def test_same_code_outside_serving_ok(self):
        assert lint.run(_src("bench/fake.py", self.BAD),
                        rules=("wall-clock",)) == []

    def test_annotated_ok(self):
        code = """
import time

def stamp(clock=None):
    # wall-clock: default injected at the API boundary only
    return (clock or time.time)()
"""
        assert lint.run(_src("serving/fake.py", code),
                        rules=("wall-clock",)) == []


class TestFrozenMut:
    BAD = """
import dataclasses

@dataclasses.dataclass(frozen=True)
class Plan:
    x: int = 0

    def bump(self):
        self.x = self.x + 1

def poke():
    p = Plan()
    p.x = 5
"""

    def test_bad(self):
        fs = lint.run(_src("kernels/fake.py", self.BAD),
                      rules=("frozen-mut",))
        assert len(fs) == 2

    def test_post_init_ok(self):
        code = """
import dataclasses

@dataclasses.dataclass(frozen=True)
class Plan:
    x: int = 0

    def __post_init__(self):
        self.x = 1      # object.__setattr__ territory, but allowed site
"""
        assert lint.run(_src("kernels/fake.py", code),
                        rules=("frozen-mut",)) == []


class TestBufferDonation:
    BAD = """
import jax

def build(lm, cfg):
    return jax.jit(lambda p, c, t: lm.decode_step(p, cfg, c, t))
"""

    GOOD = """
import jax

def build(lm, cfg, wrap):
    a = jax.jit(lambda p, c, t: lm.decode_step(p, cfg, c, t),
                donate_argnums=(1,))
    b = jax.jit(wrap("decode_step",
                     lambda p, c, t: lm.decode_step(p, cfg, c, t)),
                donate_argnums=(1,))
    return a, b
"""

    def test_bad(self):
        fs = lint.run(_src("serving/fake.py", self.BAD),
                      rules=("buffer-donation",))
        assert len(fs) == 1 and "decode_step" in fs[0].message

    def test_good(self):
        assert lint.run(_src("serving/fake.py", self.GOOD),
                        rules=("buffer-donation",)) == []

    def test_wrapped_without_donation_still_caught(self):
        code = """
import jax

def build(lm, cfg, wrap):
    return jax.jit(wrap("prefill_chunk",
                        lambda p, c: lm.prefill_chunk(p, cfg, c)))
"""
        fs = lint.run(_src("serving/fake.py", code),
                      rules=("buffer-donation",))
        assert len(fs) == 1


# ============================================== resource-flow fixtures

class TestResourceLeak:
    def test_dropped_release_mutant(self):
        # known-bad mutant: the early-exit path forgets the pages
        code = """
class Sched:
    def grow(self, slot, need):
        pages = self.pool.alloc(need)
        if self.contended:
            return False
        self.slot_pages[slot].extend(pages)
        return True
"""
        fs = resource_flow.run(_src("serving/fake.py", code),
                               rules=("resource-leak",))
        assert len(fs) == 1 and "pages" in fs[0].message

    def test_release_on_all_paths_ok(self):
        code = """
class Sched:
    def grow(self, slot, need):
        pages = self.pool.alloc(need)
        if pages is None:
            return False
        if self.contended:
            self.pool.release(pages)
            return False
        self.slot_pages[slot].extend(pages)
        return True
"""
        assert resource_flow.run(_src("serving/fake.py", code),
                                 rules=("resource-leak",)) == []

    def test_discarded_acquire(self):
        code = """
class Sched:
    def leak(self):
        self.pool.alloc(1)
"""
        fs = resource_flow.run(_src("serving/fake.py", code),
                               rules=("resource-leak",))
        assert len(fs) == 1 and "discarded" in fs[0].message

    def test_repo_scheduler_clean(self):
        root = repo_root(pathlib.Path(__file__).resolve().parent)
        sources = iter_sources([root / "src" / "repro" / "serving"], root)
        assert sources, "serving sources not found"
        fs = resource_flow.run(sources, rules=("resource-leak",))
        assert fs == [], [f.format() for f in fs]


class TestLifecycleEdge:
    def test_missing_annotation(self):
        code = """
from repro.serving import lifecycle as LC

class Eng:
    def finish(self, req, status):
        LC.transition(req, status)
"""
        fs = resource_flow.run(_src("serving/fake.py", code),
                               rules=("lifecycle-edge",))
        assert len(fs) == 1 and "annotation" in fs[0].message

    def test_illegal_edge_mutant(self):
        # known-bad mutant: resurrecting a DONE request
        code = """
from repro.serving import lifecycle as LC

class Eng:
    def resurrect(self, req):
        # lifecycle: DONE -> QUEUED
        LC.transition(req, Status.QUEUED)
"""
        fs = resource_flow.run(_src("serving/fake.py", code),
                               rules=("lifecycle-edge",))
        assert len(fs) == 1 and "DONE->QUEUED" in fs[0].message

    def test_legal_edge_ok(self):
        code = """
from repro.serving import lifecycle as LC

class Eng:
    def admit(self, req):
        # lifecycle: QUEUED -> PREFILL
        LC.transition(req, Status.PREFILL)

    def finish(self, req, status):
        # lifecycle: live -> terminal
        LC.transition(req, status)
"""
        assert resource_flow.run(_src("serving/fake.py", code),
                                 rules=("lifecycle-edge",)) == []

    def test_literal_outside_declared_dst(self):
        code = """
from repro.serving import lifecycle as LC

class Eng:
    def admit(self, req):
        # lifecycle: QUEUED -> PREFILL
        LC.transition(req, Status.DONE)
"""
        fs = resource_flow.run(_src("serving/fake.py", code),
                               rules=("lifecycle-edge",))
        assert any("Status.DONE" in f.message for f in fs)


class TestPoolInternals:
    def test_bad(self):
        code = """
class Eng:
    def peek(self):
        return len(self.pool._free)
"""
        fs = resource_flow.run(_src("serving/fake.py", code),
                               rules=("pool-internals",))
        assert len(fs) == 1 and "_free" in fs[0].message

    def test_api_ok(self):
        code = """
class Eng:
    def peek(self):
        return self.pool.available_pages
"""
        assert resource_flow.run(_src("serving/fake.py", code),
                                 rules=("pool-internals",)) == []


# ============================================= kernel contract checking

class TestKernelContracts:
    def test_registry_covers_every_entry_point(self):
        entries = registry.load_all()
        assert set(entries) >= {
            "fused_loki_decode", "select_blocks",
            "block_sparse_attention", "block_sparse_attention_grouped",
            "block_max_scores", "block_max_scores_fm", "flash_attention"}
        for e in entries.values():
            assert e.contract.name and e.contract.module

    def test_full_matrix_clean(self):
        # every tuning plan x every PageLayout dtype (incl. int8/fp8)
        # x both stored-key widths must abstract-eval clean
        fs = kernel_contracts.check_all()
        assert fs == [], [f.format() for f in fs][:10]

    def test_bad_divisibility_caught(self):
        fs = kernel_contracts._check_cell(
            "t.py", {}, smax=1000, dim=128, g=8, bs_hint=128,
            variant="fused", bs=128, kdim=128, dtype_name="fp32",
            dtype=np.float32, itemsize=4,
            budget=tuning.VMEM_BUDGET)
        assert _rules(fs) == ["contract-divisibility"]

    def test_bad_lane_width_caught(self):
        fs = kernel_contracts._check_cell(
            "t.py", {}, smax=4096, dim=96, g=8, bs_hint=128,
            variant="fused", bs=128, kdim=96, dtype_name="fp32",
            dtype=np.float32, itemsize=4,
            budget=tuning.VMEM_BUDGET)
        assert "contract-lane" in _rules(fs)

    def test_vmem_budget_exceeded_caught(self):
        fs = kernel_contracts._check_cell(
            "t.py", {}, smax=524288, dim=128, g=8, bs_hint=128,
            variant="fused", bs=256, kdim=128, dtype_name="fp32",
            dtype=np.float32, itemsize=4, budget=4096)
        assert "contract-vmem" in _rules(fs)

    def test_sublane_granule_caught(self):
        # int8 needs 32-row sublane tiles; a 16-row block cannot pack
        fs = kernel_contracts._check_cell(
            "t.py", {}, smax=4096, dim=128, g=8, bs_hint=16,
            variant="fused", bs=16, kdim=128, dtype_name="int8",
            dtype=np.int8, itemsize=1, budget=tuning.VMEM_BUDGET)
        assert "contract-sublane" in _rules(fs)

    def test_vmem_model_tracks_plan_table(self):
        # every shipped plan must fit the budget it is tuned against
        for (smax, dim, g, bs_hint), (variant, bs) in tuning.TUNED.items():
            plan = tuning.KernelPlan(variant, bs)
            d = max(min(int(0.25 * dim), dim), 8)
            assert plan.vmem_bytes(smax=smax, d=d, kdim=dim, dim=dim,
                                   g=g) <= tuning.VMEM_BUDGET


# ==================================================== runtime sentinels

class TestTraceGuard:
    def test_retrace_after_seal_raises(self):
        import jax
        import jax.numpy as jnp
        guard = TraceGuard()
        fn = jax.jit(guard.wrap("decode_step", lambda x: x * 2))
        fn(jnp.zeros((4,)))
        fn(jnp.ones((4,)))                   # same shape: cached
        assert guard.traces["decode_step"] == 1
        guard.seal()
        fn(jnp.zeros((4,)))                  # still cached: fine
        with pytest.raises(RetraceError):
            fn(jnp.zeros((8,)))              # shape drift -> retrace

    def test_rebuild_reopens_window(self):
        import jax
        import jax.numpy as jnp
        guard = TraceGuard()
        fn = jax.jit(guard.wrap("prefill", lambda x: x + 1))
        fn(jnp.zeros((2,)))
        guard.seal()
        guard.rebuild()
        fn(jnp.zeros((16,)))                 # legitimate re-jit window
        assert guard.traces["prefill"] == 2

    def test_engine_integration(self):
        # the paged engine accepts a guard and decodes without retraces
        # after its warm-up tick
        import jax
        from repro.configs import get_smoke_config
        from repro.models import lm
        from repro.serving.engine import Request
        from repro.serving.scheduler import PagedServingEngine
        cfg = get_smoke_config("qwen2.5-3b")
        params = lm.init(jax.random.PRNGKey(0), cfg)
        guard = TraceGuard()
        eng = PagedServingEngine(params, cfg, n_slots=2, smax=64,
                                 backend="xla", trace_guard=guard)
        eng.submit(Request(rid=0, prompt=np.arange(8) % cfg.vocab,
                           max_new=3))
        eng.tick()
        eng.tick()
        guard.seal()
        eng.run_until_done(max_ticks=50)
        assert guard.sealed
        assert eng.stats()["lifecycle"].get("done") == 1


class TestSanitizeTables:
    def _clean(self):
        table = np.zeros((2, 4), np.int32)
        table[0, :2] = [3, 4]
        pos = np.array([130, 0], np.int32)
        live = np.array([True, False])
        return table, pos, live

    def test_clean_table_passes(self):
        table, pos, live = self._clean()
        assert sanitize_tables(table, pos, live,
                               page_size=128, n_pages=8) == []

    def test_out_of_range_page(self):
        table, pos, live = self._clean()
        table[0, 1] = 99
        with pytest.raises(PageTableError, match="outside"):
            sanitize_tables(table, pos, live, page_size=128, n_pages=8)

    def test_trash_page_under_live_pos(self):
        table, pos, live = self._clean()
        table[0, 1] = 0                      # pos 130 needs 2 live pages
        with pytest.raises(PageTableError, match="trash"):
            sanitize_tables(table, pos, live, page_size=128, n_pages=8)

    def test_slot_corrupt_alias_caught(self):
        table, pos, live = self._clean()
        table[1, 0] = 3                      # slot 1 aliases slot 0's page
        pos[1] = 5
        live[1] = True
        with pytest.raises(PageTableError, match="aliased"):
            sanitize_tables(table, pos, live, page_size=128, n_pages=8)

    def test_shared_page_allowed_with_refcount(self):
        table, pos, live = self._clean()
        table[1, 0] = 3
        pos[1] = 5
        live[1] = True
        probs = sanitize_tables(table, pos, live, page_size=128,
                                n_pages=8, shared_ok=lambda p: p == 3)
        assert probs == []


# ============================================== CLI + baseline workflow

class TestCli:
    BAD = """
import time

def stamp():
    return time.time()
"""

    def _repo(self, tmp_path):
        (tmp_path / "pyproject.toml").write_text("[project]\n")
        pkg = tmp_path / "src" / "repro" / "serving"
        pkg.mkdir(parents=True)
        (pkg / "fake.py").write_text(self.BAD)
        return tmp_path

    def test_strict_fails_then_baseline_accepts(self, tmp_path, capsys,
                                                monkeypatch):
        root = self._repo(tmp_path)
        monkeypatch.chdir(root)
        argv = [str(root / "src" / "repro"), "--no-contracts"]
        assert cli.main(argv + ["--strict"]) == 1
        assert "wall-clock" in capsys.readouterr().out
        assert cli.main(argv + ["--update-baseline"]) == 0
        assert cli.main(argv + ["--strict"]) == 0
        base = load_baseline(root / "analysis_baseline.json")
        assert len(base) == 1

    def test_fix_leaves_stale_baseline_harmless(self, tmp_path,
                                                monkeypatch):
        root = self._repo(tmp_path)
        monkeypatch.chdir(root)
        argv = [str(root / "src" / "repro"), "--no-contracts"]
        cli.main(argv + ["--update-baseline"])
        (root / "src" / "repro" / "serving" / "fake.py").write_text(
            "def stamp(clock):\n    return clock()\n")
        assert cli.main(argv + ["--strict"]) == 0

    def test_unknown_rule_is_usage_error(self, capsys):
        assert cli.main(["--rules", "no-such-rule", "--no-contracts"]) == 2

    def test_list_rules(self, capsys):
        assert cli.main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rule in ("host-sync", "resource-leak", "contract-vmem"):
            assert rule in out

    def test_repo_is_clean_under_strict(self):
        # the acceptance gate, minus the (slow) contract sweep that
        # test_full_matrix_clean already covers
        assert cli.main(["--strict", "--no-contracts"]) == 0


# ===================================================== shared plumbing

class TestCommon:
    def test_fingerprint_is_line_number_independent(self):
        a = fingerprint("host-sync", "p.py", "tick", "  x = 1  ")
        b = fingerprint("host-sync", "p.py", "tick", "x = 1")
        assert a == b
        assert fingerprint("host-sync", "p.py", "tick", "x = 2") != a

    def test_baseline_roundtrip(self, tmp_path):
        p = tmp_path / "b.json"
        save_baseline(p, ["a", "b", "a"])
        assert load_baseline(p) == {"a", "b"}
        assert load_baseline(tmp_path / "missing.json") == set()

    def test_annotation_walks_comment_block(self):
        lines = ["x = 1",
                 "# host-sync: the one batched sync of the tick",
                 "# -- continued explanation",
                 "y = jax.device_get(z)"]
        assert annotated(lines, 4, "host-sync")
        assert not annotated(lines, 1, "host-sync")
